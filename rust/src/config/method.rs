//! Optimization-method configurations (paper Table 3).
//!
//! The paper ablates three techniques incrementally:
//!
//! | technique                              | Baseline | A | B | C |
//! |----------------------------------------|----------|---|---|---|
//! | specialized expert layout (§4.2)       |          |   |   | x |
//! | efficient all-to-all (§4.2)            |          |   | x | x |
//! | communication-computation overlap (§4.3)|         | x | x | x |

/// Named method presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Standard expert parallelism: no Mozart technique enabled.
    Baseline,
    /// Baseline + communication-computation overlap (§4.3).
    MozartA,
    /// Mozart-A + efficient all-to-all (§3.3/§4.2).
    MozartB,
    /// Mozart-B + specialized expert layout (§4.2) — the full system.
    MozartC,
}

impl Method {
    /// All four ablation columns of Table 3, in increasing feature order.
    pub const ALL: [Method; 4] = [
        Method::Baseline,
        Method::MozartA,
        Method::MozartB,
        Method::MozartC,
    ];

    /// Display name as used in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Baseline => "Baseline",
            Method::MozartA => "Mozart-A",
            Method::MozartB => "Mozart-B",
            Method::MozartC => "Mozart-C",
        }
    }

    /// Parse a method from its paper name or the CLI shorthand
    /// (`baseline|a|b|c`, case-insensitive).
    pub fn from_name(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" => Some(Method::Baseline),
            "mozart-a" | "a" => Some(Method::MozartA),
            "mozart-b" | "b" => Some(Method::MozartB),
            "mozart-c" | "c" => Some(Method::MozartC),
            _ => None,
        }
    }

    /// Parse a comma-separated method list or `all` (case-insensitive,
    /// duplicates collapsed, order preserved) — the single source for the
    /// CLI `--methods` spelling that makes the method a searchable gene
    /// (`mozart explore --methods baseline,a,b,c|all`).
    pub fn parse_list(s: &str) -> Result<Vec<Method>, String> {
        if s.trim().eq_ignore_ascii_case("all") {
            return Ok(Method::ALL.to_vec());
        }
        let mut out: Vec<Method> = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let m = Method::from_name(part)
                .ok_or_else(|| format!("unknown method `{part}` (baseline|a|b|c|all)"))?;
            if !out.contains(&m) {
                out.push(m);
            }
        }
        if out.is_empty() {
            return Err("no methods given".to_string());
        }
        Ok(out)
    }

    /// The feature-toggle configuration of this preset.
    pub fn config(&self) -> MethodConfig {
        match self {
            Method::Baseline => MethodConfig::baseline(),
            Method::MozartA => MethodConfig::mozart_a(),
            Method::MozartB => MethodConfig::mozart_b(),
            Method::MozartC => MethodConfig::mozart_c(),
        }
    }
}

/// Feature toggles for one configuration (paper Table 3 columns).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MethodConfig {
    /// The preset these toggles came from.
    pub method: Method,
    /// §4.2 stage 1+2: collaboration-aware clustering + balanced allocation.
    pub expert_layout: bool,
    /// §4.2 / §3.3: co-location replica elision + in-network aggregation.
    pub efficient_a2a: bool,
    /// §4.3: streaming experts + streaming tokens overlap.
    pub overlap: bool,
}

impl MethodConfig {
    /// Standard expert parallelism (all features off).
    pub fn baseline() -> Self {
        MethodConfig {
            method: Method::Baseline,
            expert_layout: false,
            efficient_a2a: false,
            overlap: false,
        }
    }

    /// Overlap only (paper Table 3 column A).
    pub fn mozart_a() -> Self {
        MethodConfig {
            method: Method::MozartA,
            expert_layout: false,
            efficient_a2a: false,
            overlap: true,
        }
    }

    /// Overlap + efficient all-to-all (paper Table 3 column B).
    pub fn mozart_b() -> Self {
        MethodConfig {
            method: Method::MozartB,
            expert_layout: false,
            efficient_a2a: true,
            overlap: true,
        }
    }

    /// The full system (paper Table 3 column C).
    pub fn mozart_c() -> Self {
        MethodConfig {
            method: Method::MozartC,
            expert_layout: true,
            efficient_a2a: true,
            overlap: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_feature_matrix() {
        let b = MethodConfig::baseline();
        assert!(!b.expert_layout && !b.efficient_a2a && !b.overlap);
        let a = MethodConfig::mozart_a();
        assert!(!a.expert_layout && !a.efficient_a2a && a.overlap);
        let mb = MethodConfig::mozart_b();
        assert!(!mb.expert_layout && mb.efficient_a2a && mb.overlap);
        let c = MethodConfig::mozart_c();
        assert!(c.expert_layout && c.efficient_a2a && c.overlap);
    }

    #[test]
    fn features_are_monotone_along_the_ablation() {
        // Each step of the ablation only adds features.
        let cfgs: Vec<_> = Method::ALL.iter().map(|m| m.config()).collect();
        let count = |c: &MethodConfig| {
            c.expert_layout as u8 + c.efficient_a2a as u8 + c.overlap as u8
        };
        for w in cfgs.windows(2) {
            assert!(count(&w[0]) < count(&w[1]));
        }
    }

    #[test]
    fn name_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("b"), Some(Method::MozartB));
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn parse_list_spellings() {
        assert_eq!(Method::parse_list("all").unwrap(), Method::ALL.to_vec());
        assert_eq!(Method::parse_list("ALL").unwrap(), Method::ALL.to_vec());
        assert_eq!(
            Method::parse_list("baseline, c").unwrap(),
            vec![Method::Baseline, Method::MozartC]
        );
        assert_eq!(
            Method::parse_list("c,Mozart-C,c").unwrap(),
            vec![Method::MozartC],
            "duplicates collapse"
        );
        assert_eq!(
            Method::parse_list("b,a").unwrap(),
            vec![Method::MozartB, Method::MozartA],
            "order preserved"
        );
        assert!(Method::parse_list("").is_err());
        assert!(Method::parse_list(",,").is_err());
        assert!(Method::parse_list("a,bogus").is_err());
    }
}
