//! Hardware platform configuration (paper §5.2 + Table 2).
//!
//! The Mozart platform: 16 MoE (expert-cluster) chiplets in 4
//! switch-connected groups + 1 attention chiplet; each chiplet is a 3D
//! logic-on-SRAM stack; 6 HBM2 DRAM stacks (4 group channels + 2 attention
//! channels); a 2.5D NoP-tree interconnect whose per-link bandwidth is
//! 0.125 GB/s at a 50 µm bump pitch, with link counts derived from chiplet
//! perimeter.

/// Off-chip memory technology (paper Figure 6(c) sweeps HBM2 vs SSD).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DramKind {
    /// HBM2 stack, 256 GB/s per stack.
    Hbm2,
    /// Flash/SSD tier, 15.8 GB/s (paper cites SSD-workload characterization).
    Ssd,
}

impl DramKind {
    /// Peak bandwidth of one stack/channel in GB/s.
    pub fn bandwidth_gbps(&self) -> f64 {
        match self {
            DramKind::Hbm2 => 256.0,
            DramKind::Ssd => 15.8,
        }
    }

    /// Display name as used in the paper's figures ("HBM2" / "SSD").
    pub fn name(&self) -> &'static str {
        match self {
            DramKind::Hbm2 => "HBM2",
            DramKind::Ssd => "SSD",
        }
    }

    /// Parse a CLI/config spelling (`hbm2`, `hbm`, `ssd`, case-insensitive).
    /// The single source for every `--dram`-style option and the explorer's
    /// `dram` axis values.
    pub fn from_name(s: &str) -> Option<DramKind> {
        match s.to_ascii_lowercase().as_str() {
            "hbm2" | "hbm" => Some(DramKind::Hbm2),
            "ssd" => Some(DramKind::Ssd),
            _ => None,
        }
    }

    /// DRAM access energy per byte (pJ/B). HBM2 ≈ 3.9 pJ/bit; SSD path
    /// (controller + NAND) modeled at ~60 pJ/bit.
    pub fn energy_pj_per_byte(&self) -> f64 {
        match self {
            DramKind::Hbm2 => 3.9 * 8.0,
            DramKind::Ssd => 60.0 * 8.0,
        }
    }
}

/// One compute chiplet: a logic die (tiles of systolic arrays) stacked on an
/// SRAM die via hybrid bonding.
#[derive(Clone, Debug)]
pub struct ChipletSpec {
    /// Tiles on the logic die (paper: 36-100).
    pub tiles: usize,
    /// Systolic arrays per tile (paper: 16).
    pub sas_per_tile: usize,
    /// Processing elements per SA (paper: 256-576, i.e. 16x16 .. 24x24).
    pub pes_per_sa: usize,
    /// SRAM capacity per tile in MiB (Table 2: 2.265 MB).
    pub sram_per_tile_mib: f64,
    /// SRAM bandwidth per tile in GB/s (Table 2: 32 GB/s).
    pub sram_bw_gbps: f64,
    /// Die edge length in mm (used for NoP link-count derivation).
    pub edge_mm: f64,
}

impl ChipletSpec {
    /// Peak FP16 FLOP/s at `freq_ghz`: each PE does one MAC (2 FLOPs)/cycle.
    pub fn peak_flops(&self, freq_ghz: f64) -> f64 {
        self.tiles as f64 * self.sas_per_tile as f64 * self.pes_per_sa as f64 * 2.0 * freq_ghz
            * 1e9
    }

    /// Total SRAM capacity in bytes.
    pub fn sram_bytes(&self) -> f64 {
        self.tiles as f64 * self.sram_per_tile_mib * 1024.0 * 1024.0
    }
}

/// 2.5D NoP signaling parameters (Table 2).
#[derive(Clone, Debug)]
pub struct NopSpec {
    /// Bandwidth per link in GB/s (Table 2: 0.125).
    pub link_bw_gbps: f64,
    /// Bump pitch in µm (Table 2: 50).
    pub pitch_um: f64,
    /// Fraction of perimeter bumps usable for signaling.
    pub signal_fraction: f64,
    /// Energy per byte crossing a NoP link (pJ/B); ~0.5 pJ/bit at 28nm 2.5D.
    pub energy_pj_per_byte: f64,
}

impl NopSpec {
    /// Links available on one chiplet edge of length `edge_mm`.
    pub fn links_per_edge(&self, edge_mm: f64) -> usize {
        ((edge_mm * 1000.0 / self.pitch_um) * self.signal_fraction).floor() as usize
    }

    /// Aggregate ingress bandwidth for a chiplet that dedicates one edge to
    /// the NoP-tree uplink.
    pub fn edge_bw_gbps(&self, edge_mm: f64) -> f64 {
        self.links_per_edge(edge_mm) as f64 * self.link_bw_gbps
    }
}

/// Memory hierarchy parameters (Table 2).
#[derive(Clone, Debug)]
pub struct MemSpec {
    /// Off-chip memory technology (HBM2 or the SSD tier of Figure 6(c)).
    pub dram: DramKind,
    /// DRAM capacity per stack, MiB (Table 2: 8192).
    pub dram_cap_mib: f64,
    /// Number of DRAM stacks serving MoE groups (paper: 4, one per group).
    pub group_dram_stacks: usize,
    /// Number of DRAM stacks dedicated to the attention chiplet (paper: 2).
    pub attn_dram_stacks: usize,
    /// 3D hybrid-bonding bandwidth per link GB/s (Table 2: 0.125) and the
    /// number of vertical links (horizontal x vertical bump array).
    pub hb_link_bw_gbps: f64,
    /// Vertical hybrid-bonding link count per chiplet stack.
    pub hb_links: usize,
    /// SRAM access energy pJ/B (~0.15 pJ/bit at 28nm).
    pub sram_energy_pj_per_byte: f64,
}

impl MemSpec {
    /// Per-stack DRAM bandwidth in GB/s.
    pub fn dram_bw_gbps(&self) -> f64 {
        self.dram.bandwidth_gbps()
    }

    /// Vertical (3D) bandwidth between a logic die and its SRAM die.
    pub fn hb_bw_gbps(&self) -> f64 {
        self.hb_link_bw_gbps * self.hb_links as f64
    }
}

/// Calibration knobs for the discrete-event model (see DESIGN.md
/// §Calibration). These are the only free parameters; they are fit once to
/// the paper's anchors and held fixed across all experiments.
#[derive(Clone, Debug)]
pub struct CalibrationKnobs {
    /// Achievable fraction of peak DRAM bandwidth.
    pub dram_eff: f64,
    /// Achievable fraction of peak NoP link bandwidth.
    pub nop_eff: f64,
    /// Sustained MXU (systolic-array) utilization for large matmuls.
    pub mxu_util: f64,
    /// How many chiplets in a group can stream weights concurrently from the
    /// group's shared DRAM I/O (paper §4.3: accesses are serialized; the
    /// NoP-tree switch can interleave two chiplet streams).
    pub group_concurrency: usize,
    /// In-network aggregation factor at the switches for the combine stage
    /// (method >= B): outputs of up to this many co-located experts are
    /// reduced before crossing the tree.
    pub switch_agg_factor: f64,
    /// Per-transfer fixed overhead in microseconds (command/setup latency),
    /// applied to each streamed chunk.
    pub chunk_overhead_us: f64,
    /// Fraction of an all-to-all phase window during which the group-level
    /// NoP links are occupied by a2a traffic and unavailable for weight
    /// streaming (the a2a and the DRAM->chiplet stream share the chiplet
    /// ingress edges of the NoP tree).
    pub a2a_link_occupancy: f64,
    /// Optimizer-update DRAM traffic as a multiple of the fp16 weight
    /// bytes (near-memory SGD-momentum update: read momentum + write
    /// momentum + write weights, partially row-buffer coalesced).
    pub opt_traffic_factor: f64,
}

impl Default for CalibrationKnobs {
    fn default() -> Self {
        // Fit against: baseline Qwen3 seq-256 HBM2 ~ 4.87 s (paper Fig 6a),
        // Table 4 normalized latencies, and the SSD study of Fig 6(c).
        CalibrationKnobs {
            dram_eff: 0.82,
            nop_eff: 0.44,
            mxu_util: 0.62,
            group_concurrency: 3,
            switch_agg_factor: 2.0,
            chunk_overhead_us: 1.5,
            a2a_link_occupancy: 0.35,
            opt_traffic_factor: 1.5,
        }
    }
}

/// Identifier of one *continuous* calibration knob ([`CalibrationKnobs`]
/// field) that the design-space explorer can sweep as a sensitivity axis
/// (`--axes knob=name:lo:hi`). `group_concurrency` is excluded: it is an
/// integer schedule property, not a continuous calibration fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KnobId {
    /// `CalibrationKnobs::dram_eff` — achievable fraction of peak DRAM BW.
    DramEff,
    /// `CalibrationKnobs::nop_eff` — achievable fraction of peak NoP BW.
    NopEff,
    /// `CalibrationKnobs::mxu_util` — sustained systolic-array utilization.
    MxuUtil,
    /// `CalibrationKnobs::switch_agg_factor` — in-network aggregation factor.
    SwitchAggFactor,
    /// `CalibrationKnobs::chunk_overhead_us` — per-transfer fixed overhead.
    ChunkOverheadUs,
    /// `CalibrationKnobs::a2a_link_occupancy` — a2a share of ingress links.
    A2aLinkOccupancy,
    /// `CalibrationKnobs::opt_traffic_factor` — optimizer DRAM traffic ratio.
    OptTrafficFactor,
}

impl KnobId {
    /// Every sweepable knob, in [`CalibrationKnobs`] field order.
    pub const ALL: [KnobId; 7] = [
        KnobId::DramEff,
        KnobId::NopEff,
        KnobId::MxuUtil,
        KnobId::SwitchAggFactor,
        KnobId::ChunkOverheadUs,
        KnobId::A2aLinkOccupancy,
        KnobId::OptTrafficFactor,
    ];

    /// Stable CLI / JSON name — identical to the `knobs.*` key accepted by
    /// the `--config` file loader (`config::parse::KvConfig::apply_knobs`).
    pub fn name(&self) -> &'static str {
        match self {
            KnobId::DramEff => "dram_eff",
            KnobId::NopEff => "nop_eff",
            KnobId::MxuUtil => "mxu_util",
            KnobId::SwitchAggFactor => "switch_agg_factor",
            KnobId::ChunkOverheadUs => "chunk_overhead_us",
            KnobId::A2aLinkOccupancy => "a2a_link_occupancy",
            KnobId::OptTrafficFactor => "opt_traffic_factor",
        }
    }

    /// Parse a CLI spelling (case-insensitive [`KnobId::name`]).
    pub fn from_name(s: &str) -> Option<KnobId> {
        let lower = s.to_ascii_lowercase();
        KnobId::ALL.into_iter().find(|k| k.name() == lower)
    }

    /// Read the knob's current value from a knob set.
    pub fn get(&self, k: &CalibrationKnobs) -> f64 {
        match self {
            KnobId::DramEff => k.dram_eff,
            KnobId::NopEff => k.nop_eff,
            KnobId::MxuUtil => k.mxu_util,
            KnobId::SwitchAggFactor => k.switch_agg_factor,
            KnobId::ChunkOverheadUs => k.chunk_overhead_us,
            KnobId::A2aLinkOccupancy => k.a2a_link_occupancy,
            KnobId::OptTrafficFactor => k.opt_traffic_factor,
        }
    }

    /// Install a value for this knob into a knob set.
    pub fn set(&self, k: &mut CalibrationKnobs, v: f64) {
        match self {
            KnobId::DramEff => k.dram_eff = v,
            KnobId::NopEff => k.nop_eff = v,
            KnobId::MxuUtil => k.mxu_util = v,
            KnobId::SwitchAggFactor => k.switch_agg_factor = v,
            KnobId::ChunkOverheadUs => k.chunk_overhead_us = v,
            KnobId::A2aLinkOccupancy => k.a2a_link_occupancy = v,
            KnobId::OptTrafficFactor => k.opt_traffic_factor = v,
        }
    }

    /// Whether `v` is inside the knob's physically meaningful range — the
    /// single source of the continuous-knob bounds, which
    /// [`HwConfig::validate`] delegates to. Lets the axis parser reject a
    /// bad `knob=...` spec up front instead of panicking inside a worker
    /// thread.
    pub fn in_range(&self, v: f64) -> bool {
        if !v.is_finite() {
            return false;
        }
        match self {
            KnobId::DramEff | KnobId::NopEff | KnobId::MxuUtil => v > 0.0 && v <= 1.0,
            KnobId::A2aLinkOccupancy => (0.0..=1.0).contains(&v),
            KnobId::SwitchAggFactor => v >= 1.0,
            KnobId::ChunkOverheadUs | KnobId::OptTrafficFactor => v >= 0.0,
        }
    }
}

/// Canonical bit-level fingerprint of a platform, split into the fields
/// that shape the plan *topology* (chiplet/group counts, die geometry, the
/// byte model, DRAM technology — everything placements and the plan DAG
/// structure are derived from) and the fields that only *re-time* an
/// existing topology (the core clock and the calibration knobs, which
/// enter the simulation exclusively through the per-task duration
/// constants).
///
/// Two configs with equal `topo` words build byte-identical plan
/// structure, placements and byte/FLOP models; if their `timing` words
/// also match they describe the same platform. `f64` fields are encoded
/// via [`f64::to_bits`], so comparison is exact bit equality — the
/// fingerprint never conflates two platforms that could simulate
/// differently. This is the building block of the evaluation-cache key and
/// the delta re-timing detector in `coordinator::cache`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct HwFingerprint {
    /// Topology-shaping fields, canonically encoded.
    pub topo: Vec<u64>,
    /// Re-timing-only fields: `freq_ghz` plus every calibration knob.
    pub timing: Vec<u64>,
}

/// Complete hardware platform description.
#[derive(Clone, Debug)]
pub struct HwConfig {
    /// Number of MoE (expert-cluster) chiplets (paper: 16).
    pub n_moe_chiplets: usize,
    /// Number of switch-connected groups (paper: 4).
    pub n_groups: usize,
    /// MoE chiplet spec.
    pub moe_chiplet: ChipletSpec,
    /// Attention chiplet spec (memory-bound: fewer tiles, more DRAM BW).
    pub attn_chiplet: ChipletSpec,
    /// 2.5D NoP signaling parameters.
    pub nop: NopSpec,
    /// Memory-hierarchy parameters (DRAM stacks, 3D hybrid bonding, SRAM).
    pub mem: MemSpec,
    /// Core clock in GHz (paper: 1 GHz).
    pub freq_ghz: f64,
    /// Calibration knobs of the discrete-event model (fit once, held fixed).
    pub knobs: CalibrationKnobs,
}

/// One hardware design-space override: a single `HwConfig` field the
/// explorer (`coordinator::explore`) can vary. Each variant carries the
/// value to install; [`HwOverride::apply`] mutates a config in place and
/// [`HwConfig::with_overrides`] builds a derived config from a base point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HwOverride {
    /// Tiles on each MoE chiplet's logic die (paper range 36-100).
    MoeTiles(usize),
    /// NoP bandwidth per link in GB/s (Table 2 point: 0.125).
    NopLinkBw(f64),
    /// Off-chip memory technology.
    Dram(DramKind),
    /// DRAM stacks shared by the MoE groups (paper: 4).
    GroupDramStacks(usize),
    /// Vertical hybrid-bonding link count (Table 2 point: 102400).
    HbLinks(usize),
    /// Core clock in GHz (paper: 1.0).
    FreqGhz(f64),
    /// One calibration knob pinned to an explicit value — the explorer's
    /// `knob=name:lo:hi` sensitivity axes (how robust is a verdict to the
    /// calibration fit?).
    Knob(KnobId, f64),
}

impl HwOverride {
    /// The axis this override belongs to (stable CLI / JSON name).
    pub fn axis_name(&self) -> &'static str {
        match self {
            HwOverride::MoeTiles(_) => "tiles",
            HwOverride::NopLinkBw(_) => "nop_bw",
            HwOverride::Dram(_) => "dram",
            HwOverride::GroupDramStacks(_) => "group_stacks",
            HwOverride::HbLinks(_) => "hb_links",
            HwOverride::FreqGhz(_) => "freq",
            HwOverride::Knob(id, _) => id.name(),
        }
    }

    /// Human/JSON rendering of the override's value.
    pub fn value_label(&self) -> String {
        match self {
            HwOverride::MoeTiles(v) => v.to_string(),
            HwOverride::NopLinkBw(v) => format!("{v}"),
            HwOverride::Dram(d) => d.name().to_string(),
            HwOverride::GroupDramStacks(v) => v.to_string(),
            HwOverride::HbLinks(v) => v.to_string(),
            HwOverride::FreqGhz(v) => format!("{v}"),
            HwOverride::Knob(_, v) => format!("{v}"),
        }
    }

    /// `axis=value` label used in explorer reports.
    pub fn label(&self) -> String {
        format!("{}={}", self.axis_name(), self.value_label())
    }

    /// Install the override into `hw`.
    pub fn apply(&self, hw: &mut HwConfig) {
        match *self {
            HwOverride::MoeTiles(v) => hw.moe_chiplet.tiles = v,
            HwOverride::NopLinkBw(v) => hw.nop.link_bw_gbps = v,
            HwOverride::Dram(d) => hw.mem.dram = d,
            HwOverride::GroupDramStacks(v) => hw.mem.group_dram_stacks = v,
            HwOverride::HbLinks(v) => hw.mem.hb_links = v,
            HwOverride::FreqGhz(v) => hw.freq_ghz = v,
            HwOverride::Knob(id, v) => id.set(&mut hw.knobs, v),
        }
    }
}

/// One tenant's share of the wafer in a multi-tenant partition
/// (`coordinator::tenants`): a contiguous run of switch groups (so the
/// shape is a subtree of the NoP tree and no trunk link is shared across
/// tenants) plus the integer cut of the group-coupled resources. Feed to
/// [`HwConfig::carve`] to materialize the tenant's sub-platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSlice {
    /// First switch group owned (groups are the partition unit: a group's
    /// trunk link and DRAM channel cannot be split between tenants).
    pub start_group: usize,
    /// Number of consecutive switch groups owned (≥ 1).
    pub groups: usize,
    /// DRAM stacks owned out of the parent's `mem.group_dram_stacks`.
    pub group_dram_stacks: usize,
    /// Attention-chiplet tiles owned out of the parent's attention tiles
    /// (the root chiplet is space-shared among tenants).
    pub attn_tiles: usize,
}

/// Split `total` integer units into `weights.len()` shares proportional to
/// `weights` (plus an unreturned idle share of weight `idle_weight`), by
/// largest remainder with a floor of `min_each` per returned share. The
/// returned shares plus the implied idle remainder sum to `total` exactly;
/// ties break toward lower indices, so the split is deterministic — the
/// partition policies and the conservation property tests both lean on
/// that.
pub fn split_proportional(
    total: usize,
    weights: &[f64],
    min_each: usize,
    idle_weight: f64,
) -> Vec<usize> {
    assert!(!weights.is_empty(), "split needs at least one share");
    assert!(
        weights.iter().all(|&w| w.is_finite() && w > 0.0),
        "weights must be finite and > 0, got {weights:?}"
    );
    assert!(idle_weight >= 0.0, "idle weight must be >= 0");
    assert!(
        total >= min_each * weights.len(),
        "cannot give {} parts {min_each} of {total} units",
        weights.len()
    );
    let wsum: f64 = weights.iter().sum::<f64>() + idle_weight;
    let quotas: Vec<f64> = weights.iter().map(|&w| total as f64 * w / wsum).collect();
    let mut out: Vec<usize> = quotas.iter().map(|&q| q.floor() as usize).collect();
    // Largest remainder over the tenant parts only: the idle share absorbs
    // whatever the owned quotas leave behind.
    let owned_quota: f64 = quotas.iter().sum();
    let mut rem = (owned_quota.floor() as usize).saturating_sub(out.iter().sum::<usize>());
    // Distribute the integer remainder of the *owned* quota by descending
    // fractional part (stable: ties go to the lower index).
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    for &i in &order {
        if rem == 0 {
            break;
        }
        out[i] += 1;
        rem -= 1;
    }
    // Enforce the floor by taking from the largest share (deterministic:
    // first maximal index with room).
    loop {
        let Some(short) = out.iter().position(|&v| v < min_each) else {
            break;
        };
        let donor = out
            .iter()
            .enumerate()
            .filter(|&(i, &v)| i != short && v > min_each)
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .expect("total >= min_each * parts guarantees a donor");
        out[donor] -= 1;
        out[short] += 1;
    }
    out
}

impl HwConfig {
    /// The paper's wafer-scale platform (§5.2): 16 MoE chiplets in 4 groups,
    /// 1 attention chiplet, 6 HBM2 stacks, 1 GHz, 28nm.
    pub fn mozart_wafer(dram: DramKind) -> HwConfig {
        HwConfig {
            n_moe_chiplets: 16,
            n_groups: 4,
            moe_chiplet: ChipletSpec {
                tiles: 64,
                sas_per_tile: 16,
                pes_per_sa: 576, // 24x24
                sram_per_tile_mib: 2.265,
                sram_bw_gbps: 32.0,
                edge_mm: 16.0,
            },
            attn_chiplet: ChipletSpec {
                tiles: 100,
                sas_per_tile: 16,
                pes_per_sa: 256, // 16x16
                sram_per_tile_mib: 2.265,
                sram_bw_gbps: 32.0,
                edge_mm: 20.0,
            },
            nop: NopSpec {
                link_bw_gbps: 0.125,
                pitch_um: 50.0,
                signal_fraction: 0.8,
                energy_pj_per_byte: 0.5 * 8.0,
            },
            mem: MemSpec {
                dram,
                dram_cap_mib: 8192.0,
                group_dram_stacks: 4,
                attn_dram_stacks: 2,
                hb_link_bw_gbps: 0.125,
                hb_links: 102_400, // 320x320 vertical bump array at 50um
                sram_energy_pj_per_byte: 0.15 * 8.0,
            },
            freq_ghz: 1.0,
            knobs: CalibrationKnobs::default(),
        }
    }

    /// Per-model platform sizing (paper §5.2: "we adjust hardware
    /// configurations for all three algorithmic baselines"; Table 2 reports
    /// different total area/power per model). Tile counts stay within the
    /// paper's 36-100 range; they are fit so the `arch::area` analytic model
    /// reproduces Table 2's totals.
    pub fn paper_for_model(id: crate::config::ModelId, dram: DramKind) -> HwConfig {
        use crate::config::ModelId;
        let mut hw = HwConfig::mozart_wafer(dram);
        hw.moe_chiplet.tiles = match id {
            ModelId::Qwen3_30B_A3B => 81,
            ModelId::OlmoE_1B_7B => 56,
            ModelId::DeepSeekMoE_16B => 62,
            ModelId::TinyMoE => 36,
        };
        hw
    }

    /// Derive a variant of this platform with a set of design-space
    /// overrides applied (the explorer's grid-expansion primitive). The
    /// result is [`HwConfig::validate`]d; invalid combinations are a bug in
    /// the axis definitions, not a runtime condition, so this panics on
    /// violation just like the layout invariants in `run_experiment`.
    ///
    /// # Examples
    ///
    /// ```
    /// use mozart::config::{DramKind, HwConfig, HwOverride, KnobId};
    ///
    /// let base = HwConfig::mozart_wafer(DramKind::Hbm2);
    /// let variant = base.with_overrides(&[
    ///     HwOverride::MoeTiles(36),
    ///     HwOverride::Dram(DramKind::Ssd),
    ///     HwOverride::Knob(KnobId::DramEff, 0.9),
    /// ]);
    /// assert_eq!(variant.moe_chiplet.tiles, 36);
    /// assert_eq!(variant.mem.dram, DramKind::Ssd);
    /// assert_eq!(variant.knobs.dram_eff, 0.9);
    /// // the base platform is untouched
    /// assert_eq!(base.moe_chiplet.tiles, 64);
    /// assert_eq!(base.mem.dram, DramKind::Hbm2);
    /// ```
    pub fn with_overrides(&self, overrides: &[HwOverride]) -> HwConfig {
        let mut hw = self.clone();
        for ov in overrides {
            ov.apply(&mut hw);
        }
        hw.validate().expect("hardware variant invariants");
        hw
    }

    /// Structural / physical sanity of the platform description: positive
    /// counts and rates, a group-divisible chiplet count, calibration knobs
    /// inside their meaningful ranges. Every explorer variant passes through
    /// this before any simulation spends time on it.
    pub fn validate(&self) -> Result<(), String> {
        fn pos(v: f64, what: &str) -> Result<(), String> {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("{what} must be finite and > 0, got {v}"))
            }
        }
        if self.n_moe_chiplets == 0 || self.n_groups == 0 {
            return Err("chiplet/group counts must be > 0".to_string());
        }
        if self.n_moe_chiplets % self.n_groups != 0 {
            return Err(format!(
                "n_moe_chiplets {} not divisible by n_groups {}",
                self.n_moe_chiplets, self.n_groups
            ));
        }
        for (c, what) in [(&self.moe_chiplet, "moe"), (&self.attn_chiplet, "attn")] {
            if c.tiles == 0 || c.sas_per_tile == 0 || c.pes_per_sa == 0 {
                return Err(format!("{what} chiplet tile/SA/PE counts must be > 0"));
            }
            pos(c.sram_per_tile_mib, "sram_per_tile_mib")?;
            pos(c.sram_bw_gbps, "sram_bw_gbps")?;
            pos(c.edge_mm, "edge_mm")?;
        }
        pos(self.nop.link_bw_gbps, "nop.link_bw_gbps")?;
        pos(self.nop.pitch_um, "nop.pitch_um")?;
        if !(self.nop.signal_fraction > 0.0 && self.nop.signal_fraction <= 1.0) {
            return Err(format!(
                "nop.signal_fraction must be in (0, 1], got {}",
                self.nop.signal_fraction
            ));
        }
        if self.nop.links_per_edge(self.moe_chiplet.edge_mm) == 0 {
            return Err("NoP pitch leaves zero links on a MoE chiplet edge".to_string());
        }
        if self.mem.group_dram_stacks == 0 || self.mem.attn_dram_stacks == 0 {
            return Err("DRAM stack counts must be > 0".to_string());
        }
        if self.mem.hb_links == 0 {
            return Err("hb_links must be > 0".to_string());
        }
        pos(self.mem.dram_cap_mib, "dram_cap_mib")?;
        pos(self.mem.hb_link_bw_gbps, "hb_link_bw_gbps")?;
        pos(self.freq_ghz, "freq_ghz")?;
        let k = &self.knobs;
        // one source of truth for the continuous-knob bounds: the same
        // per-knob ranges the explorer's `knob=` axis parser checks
        for id in KnobId::ALL {
            let v = id.get(k);
            if !id.in_range(v) {
                return Err(format!(
                    "knob {} is outside its physical range, got {v}",
                    id.name()
                ));
            }
        }
        if k.group_concurrency == 0 {
            return Err("group_concurrency must be > 0".to_string());
        }
        Ok(())
    }

    /// Chiplets per switch group.
    pub fn chiplets_per_group(&self) -> usize {
        assert_eq!(self.n_moe_chiplets % self.n_groups, 0);
        self.n_moe_chiplets / self.n_groups
    }

    /// Effective DRAM bandwidth of one group channel (GB/s).
    pub fn group_dram_bw(&self) -> f64 {
        self.mem.dram_bw_gbps() * self.knobs.dram_eff
    }

    /// Effective DRAM bandwidth of the attention channel pair (GB/s).
    pub fn attn_dram_bw(&self) -> f64 {
        self.mem.dram_bw_gbps() * self.mem.attn_dram_stacks as f64 * self.knobs.dram_eff
    }

    /// Effective NoP ingress bandwidth of one MoE chiplet (GB/s): one edge
    /// of links toward the group switch.
    pub fn chiplet_nop_bw(&self) -> f64 {
        self.nop.edge_bw_gbps(self.moe_chiplet.edge_mm) * self.knobs.nop_eff
    }

    /// Effective NoP bandwidth between the attention chiplet and the tree
    /// (its 4 edges all carry traffic toward the 4 group switches).
    pub fn attn_nop_bw(&self) -> f64 {
        4.0 * self.nop.edge_bw_gbps(self.attn_chiplet.edge_mm) * self.knobs.nop_eff
    }

    /// Effective bandwidth of the serialized all-to-all path: the attention
    /// chiplet drives the tree trunks one group at a time, so the phase is
    /// paced by a single root edge's worth of links.
    pub fn a2a_root_bw(&self) -> f64 {
        self.attn_nop_bw() / self.n_groups as f64
    }

    /// Effective weight-streaming bandwidth into one group: limited by the
    /// shared DRAM channel and by how many chiplet ingress edges can be
    /// served concurrently.
    pub fn group_stream_bw(&self) -> f64 {
        let nop = self.chiplet_nop_bw() * self.knobs.group_concurrency as f64;
        self.group_dram_bw().min(nop)
    }

    /// Carve the sub-platform owned by one tenant of a multi-tenant
    /// partition (`coordinator::tenants`): a contiguous run of
    /// `slice.groups` switch groups with their chiplets, a proportional
    /// cut of the attention chiplet (tiles and NoP perimeter), and the
    /// slice's DRAM-stack share installed through the same
    /// [`HwConfig::with_overrides`] path the explorer uses — so the
    /// carved config passes [`HwConfig::validate`] or panics, exactly
    /// like an explorer variant.
    ///
    /// Invariants the partition oracle relies on:
    /// * chiplets-per-group, per-chiplet NoP edges, per-stack DRAM
    ///   bandwidth, hybrid-bonding links, clock and knobs are untouched —
    ///   those resources travel with the chiplets a tenant owns;
    /// * the attention chiplet is space-shared: its tile count comes from
    ///   the slice and its NoP edge shrinks by `groups / n_groups`, so a
    ///   tenant's per-trunk root bandwidth (`a2a_root_bw`) matches the
    ///   parent's, not the whole root edge;
    /// * the attention DRAM channel pair is root-shared (kept at the
    ///   parent's `attn_dram_stacks` — 2 stacks cannot split four ways);
    /// * a full-wafer slice (`groups == n_groups` with all stacks and
    ///   tiles) reproduces `self` bit-identically, which is what makes the
    ///   single-tenant partition indistinguishable from the un-partitioned
    ///   path.
    pub fn carve(&self, slice: &PartitionSlice) -> HwConfig {
        assert!(
            slice.groups >= 1 && slice.start_group + slice.groups <= self.n_groups,
            "slice [{}, +{}) outside the {}-group wafer",
            slice.start_group,
            slice.groups,
            self.n_groups
        );
        let mut hw = self.clone();
        hw.n_groups = slice.groups;
        hw.n_moe_chiplets = slice.groups * self.chiplets_per_group();
        hw.attn_chiplet.tiles = slice.attn_tiles;
        // Root-edge share: exact (no floor drift) when groups/n_groups is a
        // dyadic fraction, and *1.0 bit-identical on the full-wafer slice.
        let share = slice.groups as f64 / self.n_groups as f64;
        hw.attn_chiplet.edge_mm = self.attn_chiplet.edge_mm * share;
        hw.with_overrides(&[HwOverride::GroupDramStacks(slice.group_dram_stacks)])
    }

    /// Plan the per-tenant [`PartitionSlice`]s for a share vector
    /// (`shares[t]` = switch groups owned by tenant `t`, each ≥ 1, summing
    /// to at most `n_groups`; the remainder idles). Group-coupled resources
    /// (DRAM stacks, attention tiles) are split proportionally to the group
    /// shares by largest remainder with a floor of one unit per tenant, so
    /// the integer sums over tenants plus the idle remainder reconstruct
    /// the parent exactly — the conservation clause of
    /// `PartitionTrace::validate`.
    pub fn partition_slices(&self, shares: &[usize]) -> Result<Vec<PartitionSlice>, String> {
        if shares.is_empty() {
            return Err("partition needs at least one tenant".to_string());
        }
        let owned: usize = shares.iter().sum();
        if owned > self.n_groups {
            return Err(format!(
                "shares {shares:?} sum to {owned} > {} groups",
                self.n_groups
            ));
        }
        if shares.iter().any(|&s| s == 0) {
            return Err(format!("every tenant needs >= 1 group, got {shares:?}"));
        }
        if self.mem.group_dram_stacks < shares.len() {
            return Err(format!(
                "{} tenants need >= 1 DRAM stack each, wafer has {}",
                shares.len(),
                self.mem.group_dram_stacks
            ));
        }
        if self.attn_chiplet.tiles < shares.len() {
            return Err(format!(
                "{} tenants need >= 1 attention tile each, chiplet has {}",
                shares.len(),
                self.attn_chiplet.tiles
            ));
        }
        let weights: Vec<f64> = shares.iter().map(|&s| s as f64).collect();
        let idle = self.n_groups - owned;
        // Idle groups keep their pro-rata stacks/tiles (weight = idle group
        // count, no floor) so owned resources never exceed the owned share.
        let stacks = split_proportional(
            self.mem.group_dram_stacks,
            &weights,
            1,
            idle as f64,
        );
        let tiles = split_proportional(self.attn_chiplet.tiles, &weights, 1, idle as f64);
        let mut out = Vec::with_capacity(shares.len());
        let mut start = 0;
        for (t, &groups) in shares.iter().enumerate() {
            out.push(PartitionSlice {
                start_group: start,
                groups,
                group_dram_stacks: stacks[t],
                attn_tiles: tiles[t],
            });
            start += groups;
        }
        Ok(out)
    }

    /// Canonical [`HwFingerprint`] of this platform. Every field of the
    /// config is encoded exactly once; adding a field to [`HwConfig`]
    /// without extending this encoding is a bug (guarded by the exhaustive
    /// destructuring below, which fails to compile on a missed field).
    pub fn fingerprint(&self) -> HwFingerprint {
        // Exhaustive destructure: a new field breaks this statement until
        // the encoding below is told about it.
        let HwConfig {
            n_moe_chiplets,
            n_groups,
            moe_chiplet,
            attn_chiplet,
            nop,
            mem,
            freq_ghz,
            knobs,
        } = self;
        let mut topo = Vec::with_capacity(26);
        topo.push(*n_moe_chiplets as u64);
        topo.push(*n_groups as u64);
        for c in [moe_chiplet, attn_chiplet] {
            let ChipletSpec {
                tiles,
                sas_per_tile,
                pes_per_sa,
                sram_per_tile_mib,
                sram_bw_gbps,
                edge_mm,
            } = c;
            topo.push(*tiles as u64);
            topo.push(*sas_per_tile as u64);
            topo.push(*pes_per_sa as u64);
            topo.push(sram_per_tile_mib.to_bits());
            topo.push(sram_bw_gbps.to_bits());
            topo.push(edge_mm.to_bits());
        }
        let NopSpec {
            link_bw_gbps,
            pitch_um,
            signal_fraction,
            energy_pj_per_byte,
        } = nop;
        topo.push(link_bw_gbps.to_bits());
        topo.push(pitch_um.to_bits());
        topo.push(signal_fraction.to_bits());
        topo.push(energy_pj_per_byte.to_bits());
        let MemSpec {
            dram,
            dram_cap_mib,
            group_dram_stacks,
            attn_dram_stacks,
            hb_link_bw_gbps,
            hb_links,
            sram_energy_pj_per_byte,
        } = mem;
        topo.push(match dram {
            DramKind::Hbm2 => 0,
            DramKind::Ssd => 1,
        });
        topo.push(dram_cap_mib.to_bits());
        topo.push(*group_dram_stacks as u64);
        topo.push(*attn_dram_stacks as u64);
        topo.push(hb_link_bw_gbps.to_bits());
        topo.push(*hb_links as u64);
        topo.push(sram_energy_pj_per_byte.to_bits());

        let CalibrationKnobs {
            dram_eff,
            nop_eff,
            mxu_util,
            group_concurrency,
            switch_agg_factor,
            chunk_overhead_us,
            a2a_link_occupancy,
            opt_traffic_factor,
        } = knobs;
        let mut timing = Vec::with_capacity(9);
        timing.push(freq_ghz.to_bits());
        timing.push(dram_eff.to_bits());
        timing.push(nop_eff.to_bits());
        timing.push(mxu_util.to_bits());
        timing.push(*group_concurrency as u64);
        timing.push(switch_agg_factor.to_bits());
        timing.push(chunk_overhead_us.to_bits());
        timing.push(a2a_link_occupancy.to_bits());
        timing.push(opt_traffic_factor.to_bits());
        HwFingerprint { topo, timing }
    }

    /// Effective MoE-chiplet compute throughput (FLOP/s).
    pub fn moe_chiplet_flops(&self) -> f64 {
        self.moe_chiplet.peak_flops(self.freq_ghz) * self.knobs.mxu_util
    }

    /// Effective attention-chiplet compute throughput (FLOP/s).
    pub fn attn_chiplet_flops(&self) -> f64 {
        self.attn_chiplet.peak_flops(self.freq_ghz) * self.knobs.mxu_util
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_kinds_match_table2() {
        assert_eq!(DramKind::Hbm2.bandwidth_gbps(), 256.0);
        assert_eq!(DramKind::Ssd.bandwidth_gbps(), 15.8);
    }

    #[test]
    fn dram_name_roundtrip() {
        for d in [DramKind::Hbm2, DramKind::Ssd] {
            assert_eq!(DramKind::from_name(d.name()), Some(d));
        }
        assert_eq!(DramKind::from_name("hbm"), Some(DramKind::Hbm2));
        assert_eq!(DramKind::from_name("nvram"), None);
    }

    #[test]
    fn wafer_shape() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        assert_eq!(hw.n_moe_chiplets, 16);
        assert_eq!(hw.n_groups, 4);
        assert_eq!(hw.chiplets_per_group(), 4);
        assert_eq!(hw.mem.group_dram_stacks + hw.mem.attn_dram_stacks, 6);
    }

    #[test]
    fn nop_link_count_from_pitch() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        // 16 mm edge / 50 um pitch * 0.8 = 256 links -> 32 GB/s peak.
        assert_eq!(hw.nop.links_per_edge(16.0), 256);
        let bw = hw.nop.edge_bw_gbps(16.0);
        assert!((bw - 32.0).abs() < 1e-9, "bw={bw}");
    }

    #[test]
    fn peak_compute_order_of_magnitude() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        // 64 tiles * 16 SA * 576 PE * 2 flop * 1 GHz = 1.18 PFLOP/s peak.
        let pf = hw.moe_chiplet.peak_flops(1.0) / 1e15;
        assert!((pf - 1.179648).abs() < 1e-6, "pf={pf}");
    }

    #[test]
    fn stream_bw_is_min_of_dram_and_nop() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        // HBM2: NoP-bound (2 x 25.6 GB/s < 0.82 x 256).
        assert!(hw.group_stream_bw() < hw.group_dram_bw());
        let ssd = HwConfig::mozart_wafer(DramKind::Ssd);
        // SSD: DRAM-bound.
        assert!((ssd.group_stream_bw() - ssd.group_dram_bw()).abs() < 1e-9);
    }

    #[test]
    fn sram_capacity() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        let mib = hw.moe_chiplet.sram_bytes() / (1024.0 * 1024.0);
        assert!((mib - 64.0 * 2.265).abs() < 1e-9);
    }

    #[test]
    fn paper_points_validate() {
        for dram in [DramKind::Hbm2, DramKind::Ssd] {
            HwConfig::mozart_wafer(dram).validate().unwrap();
        }
        for id in crate::config::ModelId::PAPER_MODELS {
            HwConfig::paper_for_model(id, DramKind::Hbm2).validate().unwrap();
        }
    }

    #[test]
    fn overrides_apply_each_field() {
        let base = HwConfig::mozart_wafer(DramKind::Hbm2);
        let hw = base.with_overrides(&[
            HwOverride::MoeTiles(36),
            HwOverride::NopLinkBw(0.25),
            HwOverride::Dram(DramKind::Ssd),
            HwOverride::GroupDramStacks(8),
            HwOverride::HbLinks(51_200),
            HwOverride::FreqGhz(1.2),
        ]);
        assert_eq!(hw.moe_chiplet.tiles, 36);
        assert_eq!(hw.nop.link_bw_gbps, 0.25);
        assert_eq!(hw.mem.dram, DramKind::Ssd);
        assert_eq!(hw.mem.group_dram_stacks, 8);
        assert_eq!(hw.mem.hb_links, 51_200);
        assert_eq!(hw.freq_ghz, 1.2);
        // base untouched
        assert_eq!(base.moe_chiplet.tiles, 64);
        assert_eq!(base.mem.dram, DramKind::Hbm2);
    }

    #[test]
    fn override_labels_are_stable() {
        assert_eq!(HwOverride::MoeTiles(81).label(), "tiles=81");
        assert_eq!(HwOverride::NopLinkBw(0.125).label(), "nop_bw=0.125");
        assert_eq!(HwOverride::Dram(DramKind::Ssd).label(), "dram=SSD");
        assert_eq!(HwOverride::GroupDramStacks(4).label(), "group_stacks=4");
        assert_eq!(HwOverride::HbLinks(102_400).label(), "hb_links=102400");
        assert_eq!(HwOverride::FreqGhz(1.0).label(), "freq=1");
        assert_eq!(
            HwOverride::Knob(KnobId::MxuUtil, 0.5).label(),
            "mxu_util=0.5"
        );
    }

    #[test]
    fn knob_ids_roundtrip_and_access_every_field() {
        let mut knobs = CalibrationKnobs::default();
        for id in KnobId::ALL {
            assert_eq!(KnobId::from_name(id.name()), Some(id));
            // set then get round-trips through the right field
            let v = id.get(&knobs) * 0.5 + 0.1;
            id.set(&mut knobs, v);
            assert_eq!(id.get(&knobs), v, "knob {}", id.name());
        }
        assert_eq!(KnobId::from_name("DRAM_EFF"), Some(KnobId::DramEff));
        assert_eq!(KnobId::from_name("group_concurrency"), None);
        assert_eq!(KnobId::from_name("bogus"), None);
    }

    #[test]
    fn knob_ranges_match_validate() {
        assert!(KnobId::DramEff.in_range(0.8));
        assert!(!KnobId::DramEff.in_range(0.0));
        assert!(!KnobId::DramEff.in_range(1.5));
        assert!(!KnobId::DramEff.in_range(f64::NAN));
        assert!(KnobId::A2aLinkOccupancy.in_range(0.0));
        assert!(!KnobId::A2aLinkOccupancy.in_range(1.2));
        assert!(KnobId::SwitchAggFactor.in_range(1.0));
        assert!(!KnobId::SwitchAggFactor.in_range(0.9));
        assert!(KnobId::ChunkOverheadUs.in_range(0.0));
        assert!(!KnobId::OptTrafficFactor.in_range(-0.1));
        // every in-range knob override survives with_overrides' validate
        let base = HwConfig::mozart_wafer(DramKind::Hbm2);
        let hw = base.with_overrides(&[
            HwOverride::Knob(KnobId::NopEff, 0.6),
            HwOverride::Knob(KnobId::ChunkOverheadUs, 0.0),
        ]);
        assert_eq!(hw.knobs.nop_eff, 0.6);
        assert_eq!(hw.knobs.chunk_overhead_us, 0.0);
    }

    #[test]
    fn validate_rejects_bad_untracked_knobs() {
        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.knobs.chunk_overhead_us = -1.0;
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.knobs.opt_traffic_factor = f64::INFINITY;
        assert!(hw.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.n_moe_chiplets = 15; // not divisible by 4 groups
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.moe_chiplet.tiles = 0;
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.nop.link_bw_gbps = f64::NAN;
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.freq_ghz = -1.0;
        assert!(hw.validate().is_err());

        let mut hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        hw.knobs.mxu_util = 1.5;
        assert!(hw.validate().is_err());
    }

    #[test]
    fn fingerprint_equality_tracks_config_equality() {
        let base = HwConfig::mozart_wafer(DramKind::Hbm2);
        assert_eq!(base.fingerprint(), base.clone().fingerprint());
        // a topology override changes the topo words
        let tiles = base.with_overrides(&[HwOverride::MoeTiles(36)]);
        assert_ne!(base.fingerprint().topo, tiles.fingerprint().topo);
        assert_eq!(base.fingerprint().timing, tiles.fingerprint().timing);
        // DRAM technology is topology (it changes the byte/bandwidth model
        // the placements were sized for)
        let ssd = base.with_overrides(&[HwOverride::Dram(DramKind::Ssd)]);
        assert_ne!(base.fingerprint().topo, ssd.fingerprint().topo);
    }

    #[test]
    fn knob_and_freq_overrides_are_pure_retiming() {
        let base = HwConfig::mozart_wafer(DramKind::Hbm2);
        let fast = base.with_overrides(&[HwOverride::FreqGhz(1.2)]);
        assert_eq!(base.fingerprint().topo, fast.fingerprint().topo);
        assert_ne!(base.fingerprint().timing, fast.fingerprint().timing);
        for id in KnobId::ALL {
            let v = id.get(&base.knobs);
            let tweaked = base.with_overrides(&[HwOverride::Knob(id, v * 0.5 + 0.1)]);
            assert_eq!(
                base.fingerprint().topo,
                tweaked.fingerprint().topo,
                "knob {} must not be a topology field",
                id.name()
            );
            assert_ne!(
                base.fingerprint().timing,
                tweaked.fingerprint().timing,
                "knob {} missing from the timing words",
                id.name()
            );
        }
        let mut conc = base.clone();
        conc.knobs.group_concurrency = 2;
        assert_eq!(base.fingerprint().topo, conc.fingerprint().topo);
        assert_ne!(base.fingerprint().timing, conc.fingerprint().timing);
    }

    #[test]
    fn fingerprint_distinguishes_every_float_bit() {
        let base = HwConfig::mozart_wafer(DramKind::Hbm2);
        let mut tweaked = base.clone();
        tweaked.nop.signal_fraction = f64::from_bits(
            base.nop.signal_fraction.to_bits() + 1,
        );
        assert_ne!(base.fingerprint(), tweaked.fingerprint());
    }

    #[test]
    #[should_panic(expected = "hardware variant invariants")]
    fn with_overrides_panics_on_invalid_variant() {
        let _ = HwConfig::mozart_wafer(DramKind::Hbm2)
            .with_overrides(&[HwOverride::FreqGhz(0.0)]);
    }

    #[test]
    fn full_wafer_carve_is_bit_identical_to_the_parent() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        let slices = hw.partition_slices(&[hw.n_groups]).unwrap();
        assert_eq!(slices.len(), 1);
        assert_eq!(slices[0].groups, 4);
        assert_eq!(slices[0].group_dram_stacks, hw.mem.group_dram_stacks);
        assert_eq!(slices[0].attn_tiles, hw.attn_chiplet.tiles);
        let carved = hw.carve(&slices[0]);
        // the single-tenant partition must be indistinguishable from the
        // un-partitioned platform, down to every float bit
        assert_eq!(carved.fingerprint(), hw.fingerprint());
    }

    #[test]
    fn symmetric_halves_carve_identically_and_conserve_resources() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        let slices = hw.partition_slices(&[2, 2]).unwrap();
        assert_eq!(slices[0].groups, 2);
        assert_eq!(slices[1].start_group, 2);
        assert_eq!(
            slices.iter().map(|s| s.group_dram_stacks).sum::<usize>(),
            hw.mem.group_dram_stacks
        );
        assert_eq!(
            slices.iter().map(|s| s.attn_tiles).sum::<usize>(),
            hw.attn_chiplet.tiles
        );
        let a = hw.carve(&slices[0]);
        let b = hw.carve(&slices[1]);
        // halves differ only in placement, so their platforms are identical
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.n_moe_chiplets, 8);
        assert_eq!(a.n_groups, 2);
        assert_eq!(a.attn_chiplet.tiles, 50);
        // per-trunk root bandwidth matches the parent's: the root edge is
        // space-shared, not duplicated per tenant
        assert_eq!(a.a2a_root_bw().to_bits(), hw.a2a_root_bw().to_bits());
        // leaves keep their physical links
        assert_eq!(a.chiplet_nop_bw().to_bits(), hw.chiplet_nop_bw().to_bits());
        a.validate().expect("carved half validates");
    }

    #[test]
    fn partition_slices_reject_impossible_shares() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        assert!(hw.partition_slices(&[]).is_err());
        assert!(hw.partition_slices(&[3, 2]).is_err(), "5 > 4 groups");
        assert!(hw.partition_slices(&[2, 0]).is_err(), "zero share");
        // more tenants than DRAM stacks cannot each get a stack
        assert!(hw.partition_slices(&[1, 1, 1, 1]).is_ok());
        let mut small = hw.clone();
        small.mem.group_dram_stacks = 2;
        assert!(small.partition_slices(&[1, 1, 1]).is_err());
    }

    #[test]
    fn split_proportional_is_exact_and_deterministic() {
        // full coverage: shares sum to the total
        assert_eq!(split_proportional(4, &[2.0, 2.0], 1, 0.0), vec![2, 2]);
        assert_eq!(split_proportional(100, &[3.0, 1.0], 1, 0.0), vec![75, 25]);
        // floor: a tiny weight still gets one unit, taken from the largest
        let s = split_proportional(4, &[100.0, 1.0, 1.0], 1, 0.0);
        assert_eq!(s.iter().sum::<usize>(), 4);
        assert!(s.iter().all(|&v| v >= 1), "floor violated: {s:?}");
        // idle weight shrinks the owned share
        let with_idle = split_proportional(100, &[1.0, 1.0], 1, 2.0);
        assert_eq!(with_idle, vec![25, 25]);
    }
}
