//! MoE-LLM model configurations (paper Table 1).
//!
//! The shapes below are the real published architectures of the three
//! evaluation models; the derived parameter counts reproduce the paper's
//! Table 1 (total / activated parameters) and Figure 1 (routed-expert
//! parameter share >90%) from first principles.

#[allow(non_camel_case_types)]
/// The three evaluation models of the paper plus a tiny config used by the
/// real end-to-end training example.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelId {
    /// Qwen3-30B-A3B: 128 experts, top-8, 48 layers.
    Qwen3_30B_A3B,
    /// OLMoE-1B-7B-0924: 64 experts, top-8, 16 layers.
    OlmoE_1B_7B,
    /// deepseek-moe-16b-base: 64 routed + 2 shared experts, top-6.
    DeepSeekMoE_16B,
    /// Tiny model actually trained end-to-end through the PJRT runtime.
    TinyMoE,
}

impl ModelId {
    /// The three evaluation models of the paper (Table 1 order).
    pub const PAPER_MODELS: [ModelId; 3] = [
        ModelId::Qwen3_30B_A3B,
        ModelId::OlmoE_1B_7B,
        ModelId::DeepSeekMoE_16B,
    ];

    /// Published model name as used in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            ModelId::Qwen3_30B_A3B => "Qwen3-30B-A3B",
            ModelId::OlmoE_1B_7B => "OLMoE-1B-7B-0924",
            ModelId::DeepSeekMoE_16B => "deepseek-moe-16b-base",
            ModelId::TinyMoE => "tiny-moe",
        }
    }

    /// Fuzzy name lookup (`qwen3`, `olmoe`, `deepseek`, `tiny`,
    /// case-insensitive substring match).
    pub fn from_name(s: &str) -> Option<ModelId> {
        let t = s.to_ascii_lowercase();
        if t.contains("qwen") {
            Some(ModelId::Qwen3_30B_A3B)
        } else if t.contains("olmoe") {
            Some(ModelId::OlmoE_1B_7B)
        } else if t.contains("deepseek") {
            Some(ModelId::DeepSeekMoE_16B)
        } else if t.contains("tiny") {
            Some(ModelId::TinyMoE)
        } else {
            None
        }
    }
}

/// Decoder-only MoE transformer shape.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Preset identity.
    pub id: ModelId,
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden (model) dimension.
    pub hidden: usize,
    /// Total decoder layers.
    pub n_layers: usize,
    /// Layers that use a dense FFN instead of MoE (DeepSeek-MoE layer 0).
    pub n_dense_layers: usize,
    /// Dense-FFN intermediate size (only for the dense layers).
    pub dense_intermediate: usize,
    /// Attention query heads.
    pub n_heads: usize,
    /// Attention key/value heads (GQA when < `n_heads`).
    pub n_kv_heads: usize,
    /// Dimension per attention head.
    pub head_dim: usize,
    /// Routed experts per MoE layer.
    pub n_experts: usize,
    /// Shared (always-active) experts per MoE layer.
    pub n_shared_experts: usize,
    /// Per-expert gated-FFN intermediate size.
    pub expert_intermediate: usize,
    /// top-k routing fanout.
    pub top_k: usize,
    /// Bytes per parameter / activation element (FP16 = 2).
    pub bytes_per_param: usize,
}

impl ModelConfig {
    /// The published architecture of `id` (reproduces Table 1).
    pub fn preset(id: ModelId) -> ModelConfig {
        match id {
            ModelId::Qwen3_30B_A3B => ModelConfig {
                id,
                vocab: 151_936,
                hidden: 2048,
                n_layers: 48,
                n_dense_layers: 0,
                dense_intermediate: 0,
                n_heads: 32,
                n_kv_heads: 4,
                head_dim: 128,
                n_experts: 128,
                n_shared_experts: 0,
                expert_intermediate: 768,
                top_k: 8,
                bytes_per_param: 2,
            },
            ModelId::OlmoE_1B_7B => ModelConfig {
                id,
                vocab: 50_304,
                hidden: 2048,
                n_layers: 16,
                n_dense_layers: 0,
                dense_intermediate: 0,
                n_heads: 16,
                n_kv_heads: 16,
                head_dim: 128,
                n_experts: 64,
                n_shared_experts: 0,
                expert_intermediate: 1024,
                top_k: 8,
                bytes_per_param: 2,
            },
            ModelId::DeepSeekMoE_16B => ModelConfig {
                id,
                vocab: 102_400,
                hidden: 2048,
                n_layers: 28,
                n_dense_layers: 1,
                dense_intermediate: 10_944,
                n_heads: 16,
                n_kv_heads: 16,
                head_dim: 128,
                n_experts: 64,
                n_shared_experts: 2,
                expert_intermediate: 1408,
                top_k: 6,
                bytes_per_param: 2,
            },
            ModelId::TinyMoE => ModelConfig {
                id,
                vocab: 512,
                hidden: 128,
                n_layers: 4,
                n_dense_layers: 0,
                dense_intermediate: 0,
                n_heads: 4,
                n_kv_heads: 4,
                head_dim: 32,
                n_experts: 16,
                n_shared_experts: 0,
                expert_intermediate: 256,
                top_k: 2,
                bytes_per_param: 2,
            },
        }
    }

    /// Number of MoE layers.
    pub fn n_moe_layers(&self) -> usize {
        self.n_layers - self.n_dense_layers
    }

    /// Parameters in one routed expert (gated FFN: gate + up + down).
    pub fn params_per_expert(&self) -> u64 {
        3 * self.hidden as u64 * self.expert_intermediate as u64
    }

    /// Attention parameters per layer (q, k, v, o projections).
    pub fn attn_params_per_layer(&self) -> u64 {
        let h = self.hidden as u64;
        let q = h * (self.n_heads * self.head_dim) as u64;
        let kv = 2 * h * (self.n_kv_heads * self.head_dim) as u64;
        let o = (self.n_heads * self.head_dim) as u64 * h;
        q + kv + o
    }

    /// Router (gating) parameters per MoE layer.
    pub fn router_params_per_layer(&self) -> u64 {
        self.hidden as u64 * self.n_experts as u64
    }

    /// All routed-expert parameters in the model.
    pub fn routed_expert_params(&self) -> u64 {
        self.n_moe_layers() as u64 * self.n_experts as u64 * self.params_per_expert()
    }

    /// Shared-expert parameters in the model.
    pub fn shared_expert_params(&self) -> u64 {
        self.n_moe_layers() as u64 * self.n_shared_experts as u64 * self.params_per_expert()
    }

    /// Dense-FFN parameters (DeepSeek's first layer).
    pub fn dense_ffn_params(&self) -> u64 {
        3 * self.n_dense_layers as u64 * self.hidden as u64 * self.dense_intermediate as u64
    }

    /// Embedding + (untied) LM-head parameters.
    pub fn embedding_params(&self) -> u64 {
        2 * self.vocab as u64 * self.hidden as u64
    }

    /// Total parameters.
    pub fn total_params(&self) -> u64 {
        self.routed_expert_params()
            + self.shared_expert_params()
            + self.dense_ffn_params()
            + self.n_layers as u64 * self.attn_params_per_layer()
            + self.n_moe_layers() as u64 * self.router_params_per_layer()
            + self.embedding_params()
    }

    /// Activated parameters per token (top-k experts + shared + attention +
    /// dense layers + embeddings), the quantity Table 1 reports.
    pub fn activated_params(&self) -> u64 {
        self.n_moe_layers() as u64 * self.top_k as u64 * self.params_per_expert()
            + self.shared_expert_params()
            + self.dense_ffn_params()
            + self.n_layers as u64 * self.attn_params_per_layer()
            + self.n_moe_layers() as u64 * self.router_params_per_layer()
            + self.embedding_params()
    }

    /// Fraction of total parameters held in routed experts (paper Figure 1:
    /// >90% across all three models).
    pub fn routed_expert_fraction(&self) -> f64 {
        self.routed_expert_params() as f64 / self.total_params() as f64
    }

    /// Bytes of routed-expert weights in one MoE layer (the per-layer DRAM
    /// weight-streaming payload).
    pub fn expert_layer_bytes(&self) -> u64 {
        self.n_experts as u64 * self.params_per_expert() * self.bytes_per_param as u64
    }

    /// Bytes of one routed expert's weights.
    pub fn expert_bytes(&self) -> u64 {
        self.params_per_expert() * self.bytes_per_param as u64
    }

    /// Bytes of attention (+ router + shared + dense) weights in one layer.
    pub fn attn_layer_bytes(&self) -> u64 {
        (self.attn_params_per_layer()
            + self.router_params_per_layer()
            + self.n_shared_experts as u64 * self.params_per_expert())
            * self.bytes_per_param as u64
    }

    /// FLOPs of one token through one routed expert (fwd): 3 matmuls.
    pub fn flops_per_token_per_expert(&self) -> u64 {
        2 * 3 * self.hidden as u64 * self.expert_intermediate as u64
    }

    /// FLOPs of one token through attention in one layer (fwd),
    /// including the O(seq) score/value terms.
    pub fn attn_flops_per_token(&self, seq_len: usize) -> u64 {
        let proj = 2 * self.attn_params_per_layer();
        let qk = 2 * (self.n_heads * self.head_dim) as u64 * seq_len as u64;
        let av = 2 * (self.n_heads * self.head_dim) as u64 * seq_len as u64;
        proj + qk + av
    }

    /// Activation bytes a token must carry through all-to-all (hidden
    /// vector in FP16).
    pub fn token_activation_bytes(&self) -> u64 {
        self.hidden as u64 * self.bytes_per_param as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(actual: u64, expect_b: f64, tol: f64) -> bool {
        let a = actual as f64 / 1e9;
        (a - expect_b).abs() / expect_b < tol
    }

    #[test]
    fn qwen3_matches_table1() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        assert!(
            close(m.total_params(), 30.5, 0.03),
            "total={}",
            m.total_params()
        );
        assert!(
            close(m.activated_params(), 3.3, 0.05),
            "active={}",
            m.activated_params()
        );
    }

    #[test]
    fn olmoe_matches_table1() {
        let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
        assert!(close(m.total_params(), 6.92, 0.03), "total={}", m.total_params());
        assert!(
            close(m.activated_params(), 1.3, 0.05),
            "active={}",
            m.activated_params()
        );
    }

    #[test]
    fn deepseek_matches_table1() {
        let m = ModelConfig::preset(ModelId::DeepSeekMoE_16B);
        assert!(close(m.total_params(), 16.4, 0.03), "total={}", m.total_params());
        assert!(
            close(m.activated_params(), 2.7, 0.06),
            "active={}",
            m.activated_params()
        );
    }

    #[test]
    fn figure1_routed_share_over_90pct() {
        for id in ModelId::PAPER_MODELS {
            let m = ModelConfig::preset(id);
            assert!(
                m.routed_expert_fraction() > 0.90,
                "{}: {}",
                id.name(),
                m.routed_expert_fraction()
            );
        }
    }

    #[test]
    fn expert_layer_bytes_qwen3() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        // 128 experts x 3*2048*768 params x 2 B = ~1.21 GB
        let gb = m.expert_layer_bytes() as f64 / 1e9;
        assert!((gb - 1.208).abs() < 0.01, "gb={gb}");
    }

    #[test]
    fn name_roundtrip() {
        for id in ModelId::PAPER_MODELS {
            assert_eq!(ModelId::from_name(id.name()), Some(id));
        }
        assert_eq!(ModelId::from_name("tiny"), Some(ModelId::TinyMoE));
        assert_eq!(ModelId::from_name("gpt-5"), None);
    }

    #[test]
    fn moe_layer_count() {
        assert_eq!(ModelConfig::preset(ModelId::DeepSeekMoE_16B).n_moe_layers(), 27);
        assert_eq!(ModelConfig::preset(ModelId::Qwen3_30B_A3B).n_moe_layers(), 48);
    }
}
