//! Configuration system: MoE model shapes (paper Table 1), hardware platform
//! parameters (paper Table 2 / §5.2), and optimization-method feature
//! matrices (paper Table 3), plus a small key=value config-file loader so
//! deployments can override any knob without recompiling.

pub mod hw;
pub mod method;
pub mod model;
pub mod parse;

pub use hw::{
    split_proportional, CalibrationKnobs, ChipletSpec, DramKind, HwConfig, HwFingerprint,
    HwOverride, KnobId, MemSpec, NopSpec, PartitionSlice,
};
pub use method::{Method, MethodConfig};
pub use model::{ModelConfig, ModelId};
// Re-exported here because the scheduling policy is part of a fully
// specified experiment, like the method and the fault scenario.
pub use crate::sim::sched::SchedPolicy;

/// A fully-specified experiment: which model, which hardware, which method,
/// and the workload parameters the paper sweeps.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model shape under evaluation (paper Table 1 presets).
    pub model: ModelConfig,
    /// Hardware platform description (paper Table 2 / §5.2).
    pub hw: HwConfig,
    /// Optimization-method feature toggles (paper Table 3 columns).
    pub method: MethodConfig,
    /// Sequence length per sample (paper sweeps 128/256/512).
    pub seq_len: usize,
    /// Samples per training step (paper: 32).
    pub batch_size: usize,
    /// Micro-batch size for streaming tokens (paper: 8).
    pub micro_batch: usize,
    /// Number of simulated training iterations to average over.
    pub iters: usize,
    /// RNG seed for the routing-trace generator.
    pub seed: u64,
    /// Injected fault scenario (the empty scenario is the healthy platform
    /// and is bit-identical to the pre-fault-model simulation path).
    pub fault: crate::comm::FaultScenario,
    /// DAG scheduling policy the simulator dispatches tasks with
    /// (`streaming` is the paper's schedule and bit-identical to the
    /// pre-trait engine; tie-break seeds derive from `seed`).
    pub sched: SchedPolicy,
}

impl ExperimentConfig {
    /// The paper's default workload: 32 samples/step in 4 micro-batches of 8,
    /// sequence length 256, HBM2, averaged over a reduced iteration count
    /// (the paper averages 1k iterations; the trace is stationary so a
    /// smaller average converges to the same mean).
    pub fn paper_default(model: ModelConfig, method: MethodConfig) -> Self {
        ExperimentConfig {
            model,
            hw: HwConfig::mozart_wafer(DramKind::Hbm2),
            method,
            seq_len: 256,
            batch_size: 32,
            micro_batch: 8,
            iters: 32,
            seed: 0x4D6F_7A61, // "Moza"
            fault: crate::comm::FaultScenario::none(),
            sched: SchedPolicy::Streaming,
        }
    }

    /// Tokens per training step.
    pub fn tokens_per_step(&self) -> usize {
        self.batch_size * self.seq_len
    }

    /// Number of micro-batches per step.
    pub fn n_micro_batches(&self) -> usize {
        assert_eq!(self.batch_size % self.micro_batch, 0);
        self.batch_size / self.micro_batch
    }

    /// Tokens per micro-batch.
    pub fn tokens_per_micro_batch(&self) -> usize {
        self.micro_batch * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_workload() {
        let c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::Qwen3_30B_A3B),
            MethodConfig::mozart_c(),
        );
        assert_eq!(c.batch_size, 32);
        assert_eq!(c.n_micro_batches(), 4);
        assert_eq!(c.tokens_per_step(), 32 * 256);
        assert_eq!(c.tokens_per_micro_batch(), 8 * 256);
    }
}
