//! Key=value config-file loader (a TOML subset; the `toml`/`serde` crates
//! are not available offline). Supports `[section]` headers, `key = value`
//! pairs, `#` comments, strings, numbers, and booleans. Used by the CLI's
//! `--config file` option to override any calibration knob or workload
//! parameter without recompiling.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Flat parsed config: `section.key -> raw string value`.
#[derive(Clone, Debug, Default)]
pub struct KvConfig {
    /// Parsed entries, keyed `section.key` (or bare `key` outside sections).
    pub entries: BTreeMap<String, String>,
}

impl KvConfig {
    /// Parse config text (`[section]` headers, `key = value`, `#` comments).
    pub fn parse(text: &str) -> Result<KvConfig> {
        let mut out = KvConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                if key.ends_with('.') || key.starts_with('.') || k.trim().is_empty() {
                    bail!("line {}: empty key", lineno + 1);
                }
                let mut val = v.trim().to_string();
                if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                    val = val[1..val.len() - 1].to_string();
                }
                out.entries.insert(key, val);
            } else {
                bail!("line {}: expected `key = value` or `[section]`", lineno + 1);
            }
        }
        Ok(out)
    }

    /// Read and parse a config file.
    pub fn load(path: &str) -> Result<KvConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        KvConfig::parse(&text)
    }

    /// Raw string value of `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    /// Float value of `key`, or `default` when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .with_context(|| format!("config key {key}: invalid float {s}")),
        }
    }

    /// Integer value of `key`, or `default` when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .with_context(|| format!("config key {key}: invalid integer {s}")),
        }
    }

    /// Boolean value of `key` (`true`/`false`), or `default` when absent.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(s) => bail!("config key {key}: invalid bool {s}"),
        }
    }

    /// Apply any `knobs.*` overrides to a calibration-knob struct.
    pub fn apply_knobs(&self, k: &mut super::CalibrationKnobs) -> Result<()> {
        k.dram_eff = self.get_f64("knobs.dram_eff", k.dram_eff)?;
        k.nop_eff = self.get_f64("knobs.nop_eff", k.nop_eff)?;
        k.mxu_util = self.get_f64("knobs.mxu_util", k.mxu_util)?;
        k.group_concurrency = self.get_usize("knobs.group_concurrency", k.group_concurrency)?;
        k.switch_agg_factor = self.get_f64("knobs.switch_agg_factor", k.switch_agg_factor)?;
        k.chunk_overhead_us = self.get_f64("knobs.chunk_overhead_us", k.chunk_overhead_us)?;
        k.a2a_link_occupancy =
            self.get_f64("knobs.a2a_link_occupancy", k.a2a_link_occupancy)?;
        k.opt_traffic_factor =
            self.get_f64("knobs.opt_traffic_factor", k.opt_traffic_factor)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let c = KvConfig::parse(
            "top = 1\n[knobs]\ndram_eff = 0.5 # comment\nname = \"x y\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get_f64("knobs.dram_eff", 0.0).unwrap(), 0.5);
        assert_eq!(c.get("knobs.name"), Some("x y"));
        assert!(c.get_bool("knobs.flag", false).unwrap());
        assert_eq!(c.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(KvConfig::parse("not a kv line").is_err());
        assert!(KvConfig::parse("[unterminated").is_err());
        assert!(KvConfig::parse("= novalue").is_err());
    }

    #[test]
    fn knob_overrides() {
        let c = KvConfig::parse("[knobs]\nmxu_util = 0.9\ngroup_concurrency = 4\n").unwrap();
        let mut k = crate::config::CalibrationKnobs::default();
        c.apply_knobs(&mut k).unwrap();
        assert_eq!(k.mxu_util, 0.9);
        assert_eq!(k.group_concurrency, 4);
        // untouched knobs keep defaults
        assert_eq!(k.nop_eff, crate::config::CalibrationKnobs::default().nop_eff);
    }

    #[test]
    fn bad_types_error() {
        let c = KvConfig::parse("[knobs]\ndram_eff = abc\n").unwrap();
        let mut k = crate::config::CalibrationKnobs::default();
        assert!(c.apply_knobs(&mut k).is_err());
    }
}
