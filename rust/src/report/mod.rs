//! Report generators: one function per paper table/figure, each returning
//! rendered markdown (tables + ASCII bar charts) with the paper's reported
//! numbers alongside ours where the paper gives absolute anchors.

use crate::arch::area::{hw_metrics, paper_table2_anchor};
use crate::config::{DramKind, HwConfig, Method, ModelConfig, ModelId};
use crate::coordinator::sweep::{self, run_cells, CellResult};
use crate::metrics::roofline::{profile_decoder_layer, Olmo2Scale};
use crate::pipeline::epsim::{self, EpSimConfig};
use crate::sim::Tag;
use crate::util::table::{bar_chart, Table};

/// Run options shared by the reports (iteration budget, seed).
#[derive(Clone, Copy, Debug)]
pub struct ReportOpts {
    /// Simulated training iterations to average per cell.
    pub iters: usize,
    /// RNG seed for the routing-trace generators.
    pub seed: u64,
}

impl Default for ReportOpts {
    fn default() -> Self {
        ReportOpts { iters: 4, seed: 7 }
    }
}

fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Table 1: model configurations (regenerated from the presets).
pub fn table1() -> String {
    let mut t = Table::new(
        "Table 1 — MoE-LLM configurations",
        &[
            "Model",
            "Total params",
            "Activated",
            "Routed experts",
            "Shared",
            "Hidden",
            "Layers",
            "Routing",
        ],
    );
    for id in ModelId::PAPER_MODELS {
        let m = ModelConfig::preset(id);
        t.row(&[
            id.name().to_string(),
            format!("{:.2}B", m.total_params() as f64 / 1e9),
            format!("{:.2}B", m.activated_params() as f64 / 1e9),
            m.n_experts.to_string(),
            m.n_shared_experts.to_string(),
            m.hidden.to_string(),
            m.n_layers.to_string(),
            format!("top-{}", m.top_k),
        ]);
    }
    t.render()
}

/// Table 2: hardware metrics from the analytic 28nm area/power model.
pub fn table2() -> String {
    let mut t = Table::new(
        "Table 2 — hardware metrics (analytic 28nm model vs paper)",
        &[
            "Model",
            "Area (mm^2)",
            "paper",
            "Power (kW)",
            "paper",
            "DRAM&SRAM cap (MB)",
            "DRAM&SRAM BW (GB/s)",
            "2.5D link (GB/s @ um)",
        ],
    );
    for id in ModelId::PAPER_MODELS {
        let m = ModelConfig::preset(id);
        let hw = HwConfig::paper_for_model(id, DramKind::Hbm2);
        let x = hw_metrics(&m, &hw);
        let (pa, pp) = paper_table2_anchor(id).unwrap();
        t.row(&[
            id.name().to_string(),
            f(x.total_area_mm2, 0),
            f(pa, 0),
            f(x.total_power_kw, 2),
            f(pp, 2),
            format!("{:.0} & {:.3}", x.dram_cap_mib, x.sram_per_tile_mib),
            format!("{:.0} & {:.0}", x.dram_bw_gbps, x.sram_bw_gbps),
            format!("{:.3} @ {:.0}", x.nop_link_bw_gbps, x.nop_pitch_um),
        ]);
    }
    t.render()
}

/// Figure 1: parameter distribution across module types.
pub fn fig1() -> String {
    let mut t = Table::new(
        "Figure 1 — parameter distribution (routed experts >90%)",
        &["Model", "Routed experts", "Attention", "Embedding", "Other", "Routed share"],
    );
    for id in ModelId::PAPER_MODELS {
        let m = ModelConfig::preset(id);
        let total = m.total_params() as f64;
        let routed = m.routed_expert_params() as f64;
        let attn = (m.n_layers as u64 * m.attn_params_per_layer()) as f64;
        let emb = m.embedding_params() as f64;
        let other = total - routed - attn - emb;
        t.row(&[
            id.name().to_string(),
            format!("{:.1}%", routed / total * 100.0),
            format!("{:.1}%", attn / total * 100.0),
            format!("{:.1}%", emb / total * 100.0),
            format!("{:.1}%", other / total * 100.0),
            format!("{:.3}", m.routed_expert_fraction()),
        ]);
    }
    t.render()
}

/// Figure 3: activation-frequency skew + co-activation structure of the
/// (synthetic) routing prior for DeepSeek-MoE's final layer.
pub fn fig3(opts: ReportOpts) -> String {
    use crate::trace::{Priors, TraceGen};
    use crate::util::rng::Rng;
    let m = ModelConfig::preset(ModelId::DeepSeekMoE_16B);
    let gen = TraceGen::for_model(&m, opts.seed);
    let mut rng = Rng::new(opts.seed ^ 1);
    let layer = m.n_moe_layers() - 1; // final layer, as in the paper
    let tr = gen.sample_layer(layer, 16_384, &mut rng);
    let p = Priors::from_trace(&tr);

    let mut sorted = p.workload.clone();
    sorted.sort_by(|a, b| b.total_cmp(a));
    let labels: Vec<String> = (0..8).map(|i| format!("rank-{i}")).collect();
    let top: Vec<f64> = sorted.iter().take(8).map(|&w| w * 100.0).collect();
    let mut out = bar_chart(
        "Figure 3 (left) — activation frequency, top-8 experts (% of slots)",
        &labels,
        &top,
        "%",
    );
    let uniform = 100.0 / m.n_experts as f64;
    out.push_str(&format!(
        "(uniform would be {uniform:.2}% per expert; max/min = {:.1}x -> expert specialization)\n\n",
        sorted[0] / sorted[m.n_experts - 1].max(1e-12)
    ));
    // co-activation summary: hottest pairs vs median pair
    let (hi, hj) = p.hottest_pair();
    let mut pairs: Vec<f64> = Vec::new();
    for i in 0..m.n_experts {
        for j in (i + 1)..m.n_experts {
            pairs.push(p.p(i, j));
        }
    }
    let med = crate::util::stats::percentile(&pairs, 50.0);
    out.push_str(&format!(
        "Figure 3 (right) — co-activation: hottest pair ({hi},{hj}) P=1.00, median pair P={med:.3} -> expert collaboration structure\n"
    ));
    out
}

/// Table 3 / Figure 6(a): optimization effectiveness per model.
pub fn table3(opts: ReportOpts) -> (String, Vec<CellResult>) {
    let cells = sweep::table3_cells();
    let results = run_cells(&cells, opts.iters, opts.seed);
    let paper_speedup = [1.92, 2.37, 2.17];
    let mut t = Table::new(
        "Table 3 / Figure 6(a) — latency per step, seq 256, HBM2",
        &[
            "Model",
            "Method",
            "Latency (s)",
            "Normalized",
            "Speedup",
            "paper speedup",
        ],
    );
    for (mi, model) in ModelId::PAPER_MODELS.iter().enumerate() {
        let base = results
            .iter()
            .find(|r| r.cell.model == *model && r.cell.method == Method::Baseline)
            .unwrap()
            .result
            .latency;
        for method in Method::ALL {
            let r = results
                .iter()
                .find(|r| r.cell.model == *model && r.cell.method == method)
                .unwrap();
            let lat = r.result.latency;
            t.row(&[
                model.name().to_string(),
                method.name().to_string(),
                f(lat, 3),
                f(lat / base, 3),
                format!("{:.2}x", base / lat),
                if method == Method::MozartC {
                    format!("{:.2}x", paper_speedup[mi])
                } else {
                    "-".to_string()
                },
            ]);
        }
    }
    (t.render(), results)
}

/// Table 4: C_T vs normalized latency.
pub fn table4(opts: ReportOpts) -> String {
    let cells = sweep::table3_cells();
    let results = run_cells(&cells, opts.iters, opts.seed);
    // paper anchors: (normalized latency, C_T) for A/B/C per model
    let paper: [(&str, [f64; 3], [f64; 3]); 3] = [
        ("Qwen3-30B-A3B", [0.73, 0.59, 0.52], [8.0, 6.58, 5.77]),
        ("OLMoE-1B-7B-0924", [0.63, 0.48, 0.422], [8.0, 6.84, 5.63]),
        ("deepseek-moe-16b-base", [0.67, 0.56, 0.46], [6.0, 5.56, 4.32]),
    ];
    let mut t = Table::new(
        "Table 4 — all-to-all complexity C_T vs normalized latency",
        &[
            "Model", "Method", "Norm. latency", "paper", "C_T", "paper C_T",
        ],
    );
    for (mi, model) in ModelId::PAPER_MODELS.iter().enumerate() {
        let base = results
            .iter()
            .find(|r| r.cell.model == *model && r.cell.method == Method::Baseline)
            .unwrap()
            .result
            .latency;
        for (i, method) in [Method::MozartA, Method::MozartB, Method::MozartC]
            .iter()
            .enumerate()
        {
            let r = results
                .iter()
                .find(|r| r.cell.model == *model && r.cell.method == *method)
                .unwrap();
            t.row(&[
                model.name().to_string(),
                method.name().to_string(),
                f(r.result.latency / base, 3),
                f(paper[mi].1[i], 3),
                f(r.result.c_t, 2),
                f(paper[mi].2[i], 2),
            ]);
        }
    }
    t.render()
}

/// Figure 6(b): sequence-length sweep (Qwen3, HBM2).
pub fn fig6b(opts: ReportOpts) -> String {
    let results = run_cells(&sweep::fig6b_cells(), opts.iters, opts.seed);
    let mut t = Table::new(
        "Figure 6(b) — sequence-length study (Qwen3-30B-A3B, HBM2)",
        &["Seq len", "Method", "Latency (s)", "Speedup vs baseline"],
    );
    for seq in [128usize, 256, 512] {
        let base = results
            .iter()
            .find(|r| r.cell.seq_len == seq && r.cell.method == Method::Baseline)
            .unwrap()
            .result
            .latency;
        for method in Method::ALL {
            let r = results
                .iter()
                .find(|r| r.cell.seq_len == seq && r.cell.method == method)
                .unwrap();
            t.row(&[
                seq.to_string(),
                method.name().to_string(),
                f(r.result.latency, 3),
                format!("{:.2}x", base / r.result.latency),
            ]);
        }
    }
    let mut s = t.render();
    s.push_str(
        "(paper anchors: baseline 3.88 s @128 -> 7.64 s @512; Mozart-C speedup 1.47x @128, 2.34x @512)\n",
    );
    s
}

/// Figure 6(c): DRAM-bandwidth study (Qwen3, seq 256).
pub fn fig6c(opts: ReportOpts) -> String {
    let results = run_cells(&sweep::fig6c_cells(), opts.iters, opts.seed);
    let mut t = Table::new(
        "Figure 6(c) — DRAM study (Qwen3-30B-A3B, seq 256)",
        &["DRAM", "Method", "Latency (s)", "Speedup vs baseline"],
    );
    for dram in [DramKind::Hbm2, DramKind::Ssd] {
        let base = results
            .iter()
            .find(|r| r.cell.dram == dram && r.cell.method == Method::Baseline)
            .unwrap()
            .result
            .latency;
        for method in Method::ALL {
            let r = results
                .iter()
                .find(|r| r.cell.dram == dram && r.cell.method == method)
                .unwrap();
            t.row(&[
                dram.name().to_string(),
                method.name().to_string(),
                f(r.result.latency, 3),
                format!("{:.2}x", base / r.result.latency),
            ]);
        }
    }
    let mut s = t.render();
    s.push_str("(paper: max 9.17 s; optimization gains are larger under HBM2 than SSD)\n");
    s
}

/// Appendix Figures 7/8/9: the full normalized-latency grid at a sequence
/// length (128 -> Fig 7, 256 -> Fig 8, 512 -> Fig 9).
pub fn appendix_fig(seq_len: usize, opts: ReportOpts) -> String {
    let results = run_cells(&sweep::appendix_cells(seq_len), opts.iters, opts.seed);
    let fig_no = match seq_len {
        128 => 7,
        256 => 8,
        512 => 9,
        _ => 0,
    };
    let mut t = Table::new(
        &format!("Figure {fig_no} — normalized latency, seq {seq_len}"),
        &["Model", "DRAM", "Baseline", "Mozart-A", "Mozart-B", "Mozart-C", "max wall-clock (s)"],
    );
    for model in ModelId::PAPER_MODELS {
        for dram in [DramKind::Hbm2, DramKind::Ssd] {
            let get = |m: Method| {
                results
                    .iter()
                    .find(|r| {
                        r.cell.model == model && r.cell.dram == dram && r.cell.method == m
                    })
                    .unwrap()
                    .result
                    .latency
            };
            let base = get(Method::Baseline);
            t.row(&[
                model.name().to_string(),
                dram.name().to_string(),
                "1.000".to_string(),
                f(get(Method::MozartA) / base, 3),
                f(get(Method::MozartB) / base, 3),
                f(get(Method::MozartC) / base, 3),
                f(base, 2),
            ]);
        }
    }
    t.render()
}

/// Appendix Figures 10-13: attention vs FFN roofline study.
pub fn fig10_13() -> String {
    let mut t = Table::new(
        "Figures 10-13 — attention (memory-bound) vs FFN (compute-bound), OLMo-2, batch 4",
        &[
            "Model",
            "Seq",
            "FFN FLOPs share",
            "FFN latency share",
            "Attn latency (ms)",
            "FFN latency (ms)",
        ],
    );
    for scale in Olmo2Scale::ALL {
        for seq in [512usize, 1024, 2048] {
            let r = profile_decoder_layer(scale, 4, seq);
            t.row(&[
                scale.name().to_string(),
                seq.to_string(),
                format!("{:.1}%", r.flops_share_ffn() * 100.0),
                format!("{:.1}%", r.latency_share_ffn() * 100.0),
                f(r.attn_latency * 1e3, 3),
                f(r.ffn_latency * 1e3, 3),
            ]);
        }
    }
    let mut s = t.render();
    s.push_str("(paper: FFN counts more FLOPs but less wall-clock latency at every scale)\n");
    s
}

/// Appendix Figures 14-16: GPU power/memory dynamism under expert
/// parallelism.
pub fn fig14_16(opts: ReportOpts) -> String {
    let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
    let samples = epsim::simulate(&m, &EpSimConfig::default(), 40.0, opts.seed);
    let d = epsim::summarize(&samples);
    let mut t = Table::new(
        "Figures 14-16 — GPU behaviour monitor (OLMoE, 4-way EP, 0.1 s interval)",
        &[
            "GPU",
            "Power mean CV",
            "Power range (W)",
            "Mem CV",
            "Mem range (GiB)",
        ],
    );
    for g in 0..4 {
        t.row(&[
            format!("gpu{g}"),
            f(d.power_cv[g], 3),
            format!("{:.0}-{:.0}", d.power_range[g].0, d.power_range[g].1),
            f(d.mem_cv[g], 3),
            format!("{:.1}-{:.1}", d.mem_range[g].0, d.mem_range[g].1),
        ]);
    }
    let mut s = t.render();
    s.push_str(
        "(paper: both GPU power and memory show high dynamism under MoE expert parallelism)\n",
    );
    s
}

/// §5.4 Q1: is Mozart memory-bound or compute-bound?
pub fn q1(opts: ReportOpts) -> String {
    let cell = sweep::Cell {
        model: ModelId::Qwen3_30B_A3B,
        method: Method::MozartC,
        seq_len: 256,
        dram: DramKind::Hbm2,
    };
    let r = crate::coordinator::run_experiment(&sweep::cell_config(cell, opts.iters, opts.seed));
    let mut t = Table::new(
        "Q1 — critical-path decomposition (Qwen3, Mozart-C, seq 256, HBM2)",
        &["Component", "Critical-path share"],
    );
    let total: f64 = r.critical.sum();
    let mut rows: Vec<(Tag, f64)> = r.critical.to_vec();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (tag, v) in rows.iter().filter(|(_, v)| *v > 0.0) {
        t.row(&[tag.name().to_string(), format!("{:.1}%", v / total * 100.0)]);
    }
    // memory-bound = all DRAM-traffic categories vs compute categories
    let memory: f64 = r
        .critical
        .iter()
        .filter(|(t, _)| {
            matches!(
                t,
                Tag::WeightStream
                    | Tag::AttnWeightLoad
                    | Tag::ActSave
                    | Tag::ActLoad
                    | Tag::GradWriteback
                    | Tag::OptimUpdate
            )
        })
        .map(|(_, v)| v)
        .sum();
    let compute: f64 = r
        .critical
        .iter()
        .filter(|(t, _)| matches!(t, Tag::MoeCompute | Tag::AttnCompute | Tag::Router))
        .map(|(_, v)| v)
        .sum();
    let mut s = t.render();
    s.push_str(&format!(
        "=> {} (DRAM traffic {:.0}% vs compute {:.0}% of the critical path). Paper's answer: memory-bound.\n",
        if memory > 0.4 * total && memory > compute {
            "MEMORY-BOUND"
        } else {
            "not memory-bound"
        },
        memory / total * 100.0,
        compute / total * 100.0
    ));
    s
}

/// §5.4 Q2: which algorithmic design matters most?
pub fn q2(opts: ReportOpts) -> String {
    let (_, results) = table3(opts);
    let mut t = Table::new(
        "Q2 — incremental contribution of each technique",
        &["Model", "Overlap (base->A)", "Eff. all-to-all (A->B)", "Layout (B->C)", "paper overlap"],
    );
    let paper_overlap = [1.33, 1.58, 1.49];
    for (mi, model) in ModelId::PAPER_MODELS.iter().enumerate() {
        let get = |m: Method| {
            results
                .iter()
                .find(|r| r.cell.model == *model && r.cell.method == m)
                .unwrap()
                .result
                .latency
        };
        t.row(&[
            model.name().to_string(),
            format!("{:.2}x", get(Method::Baseline) / get(Method::MozartA)),
            format!("{:.2}x", get(Method::MozartA) / get(Method::MozartB)),
            format!("{:.2}x", get(Method::MozartB) / get(Method::MozartC)),
            format!("{:.2}x", paper_overlap[mi]),
        ]);
    }
    let mut s = t.render();
    s.push_str("(paper ordering: overlap > efficient all-to-all > expert layout)\n");
    s
}

/// §5.4 Q3 (extension): constrained co-design position of the paper's
/// Table 2 platform. Runs a guided random search (12 seeded samples of the
/// default tiles × NoP-bandwidth × DRAM grid — the same evaluation budget
/// as PR 3's even-stride subsample) with the Mozart ablation as a
/// searchable gene and the paper's own die area as a hard `--max-area`-style
/// cap, so the verdict answers: *within the Table 2 silicon budget, which
/// ablation on which platform — and does any feasible combination beat the
/// paper's deployment?* The constrained joint frontier, feasibility counts,
/// and convergence curve are reported.
pub fn q3(opts: ReportOpts) -> String {
    use crate::coordinator::explore::ExploreConfig;
    use crate::coordinator::search::{search, Constraints, SearchConfig, SearchStrategy};
    let mut explore = ExploreConfig::paper_default();
    explore.iters = opts.iters;
    explore.seed = opts.seed;
    explore.methods = Method::ALL.to_vec();
    // hard cap = the paper platform's own area, so the anchor is exactly
    // feasible and every admitted competitor fits the same silicon budget
    let model = ModelId::Qwen3_30B_A3B;
    let anchor_area = hw_metrics(
        &ModelConfig::preset(model),
        &HwConfig::paper_for_model(model, DramKind::Hbm2),
    )
    .total_area_mm2;
    let cfg = SearchConfig {
        constraints: Constraints {
            max_area_mm2: Some(anchor_area),
            ..Constraints::none()
        },
        method_gene: true,
        ..SearchConfig::new(
            explore,
            SearchStrategy::Random {
                samples: 12,
                seed: opts.seed,
            },
        )
    };
    let mut s = String::from(
        "### Q3 — constrained co-design position of the Table 2 platform\n",
    );
    s.push_str(&format!(
        "(hard area budget: the paper platform's own {anchor_area:.0} mm^2; \
         method is a searchable gene over all four Table 3 ablations)\n\n"
    ));
    s.push_str(&search(&cfg).render_markdown());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> ReportOpts {
        ReportOpts { iters: 1, seed: 3 }
    }

    #[test]
    fn static_reports_render() {
        assert!(table1().contains("Qwen3-30B-A3B"));
        assert!(table2().contains("14175") || table2().contains("Area"));
        assert!(fig1().contains("Routed share"));
        assert!(fig10_13().contains("OLMo-2"));
    }

    #[test]
    fn fig3_renders() {
        let s = fig3(fast());
        assert!(s.contains("specialization"));
        assert!(s.contains("collaboration"));
    }

    #[test]
    fn fig14_16_renders() {
        let s = fig14_16(fast());
        assert!(s.contains("gpu0"));
    }
}
