//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path. Python never runs at request time — `make artifacts`
//! lowers the JAX/Pallas model once, and this module does
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute(_b)`.
//!
//! HLO *text* is the interchange format (not `.serialize()`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The whole runtime is gated behind the `pjrt` cargo feature because the
//! `xla` bindings crate is not part of the offline crate set; the default
//! build ships a stub that reports unavailability (see DESIGN.md).

#[cfg(feature = "pjrt")]
pub mod exec;

#[cfg(feature = "pjrt")]
pub use exec::{Executable, Runtime};

use anyhow::Result;

/// Smoke helper: create a CPU PJRT client and report the platform name.
#[cfg(feature = "pjrt")]
pub fn platform() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}

/// Stub when built without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub fn platform() -> Result<String> {
    anyhow::bail!(
        "PJRT runtime unavailable: this build has no `xla` bindings. \
         Add the `xla` dependency and rebuild with `--features pjrt` (see rust/DESIGN.md)."
    )
}
