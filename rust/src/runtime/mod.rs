//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path. Python never runs at request time — `make artifacts`
//! lowers the JAX/Pallas model once, and this module does
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute(_b)`.
//!
//! HLO *text* is the interchange format (not `.serialize()`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.

pub mod exec;

pub use exec::{Executable, Runtime};

use anyhow::Result;

/// Smoke helper: create a CPU PJRT client and report the platform name.
pub fn platform() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
