//! Executable loading and invocation over the PJRT C API (`xla` crate).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus the executables loaded from the artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client (the only backend in this offline image;
    /// the same code path works for TPU/GPU PJRT plugins).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.display().to_string(),
        })
    }

    /// Move a host literal onto the device (for long-lived state like model
    /// parameters — avoids a host->device copy on every step).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .context("uploading literal to device")
    }
}

/// A compiled computation. All artifacts are lowered with
/// `return_tuple=True`, so outputs arrive as one tuple literal.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Source path the executable was loaded from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals in, tuple of host literals out.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        tuple.to_tuple().context("destructuring result tuple")
    }

    /// Execute with device buffers in. NOTE: the artifacts are lowered with
    /// `return_tuple=True` and this crate's PJRT wrapper does not set
    /// `untuple_result`, so the result arrives as a SINGLE tuple buffer —
    /// callers must `to_literal_sync()?.to_tuple()` it. For multi-output
    /// training steps prefer [`Executable::run`], which does that for you;
    /// `run_b` is the zero-copy path for single-output executables.
    pub fn run_b(&self, args: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self
            .exe
            .execute_b::<xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {}", self.name))?;
        Ok(out.swap_remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end runtime smoke: build a computation with the XlaBuilder
    /// (no python needed), compile and run it through the same client.
    #[test]
    fn builder_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform_name(), "cpu");
        let b = xla::XlaBuilder::new("t");
        let p = b
            .parameter_s(0, &xla::Shape::array::<f32>(vec![2]), "p")
            .unwrap();
        let comp = (p.clone() * p).unwrap().build().unwrap();
        let exe = rt.client.compile(&comp).unwrap();
        let x = xla::Literal::vec1(&[3f32, 4f32]);
        let out = exe.execute::<xla::Literal>(&[x]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![9.0, 16.0]);
    }

    #[test]
    fn device_buffer_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        let lit = xla::Literal::vec1(&[1f32, 2f32, 3f32]);
        let buf = rt.to_device(&lit).unwrap();
        let back = buf.to_literal_sync().unwrap();
        assert_eq!(back.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn missing_artifact_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}
