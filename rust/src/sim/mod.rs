//! Discrete-event simulation of the wafer-scale platform.
//!
//! The simulator executes a *plan*: a DAG of tasks, each bound to at most
//! one sequential hardware resource (a DRAM channel, a chiplet's compute
//! array, the NoP tree, ...) with a fixed duration. Event-driven list
//! scheduling resolves dependency readiness and resource contention; the
//! result carries the makespan, per-tag/per-resource busy times, and the
//! critical path — which is exactly the granularity the paper's
//! cycle-accurate simulator reports at the micro-batch x layer x stream-
//! chunk level (its per-cycle detail is only used to *validate* those
//! aggregates against Verilog, which we cannot ship).
//!
//! *Which* ready task runs next is a pluggable [`Scheduler`] policy
//! ([`sched`]): the paper's streaming order (default), FIFO list, HEFT
//! upward-rank, or work-conserving greedy — all bit-reproducible, all
//! checked by the schedule-validity oracle ([`ScheduleTrace::validate`])
//! in debug builds and tests.
//!
//! [`serve`] layers an event-driven queueing simulation on top: open-loop
//! request traffic, continuous dynamic batching with pluggable
//! batch-close policies, and its own queueing-invariant oracle
//! ([`ServeTrace::validate`]).

pub mod engine;
pub mod plan;
pub mod sched;
pub mod serve;

pub use engine::{SimResult, SimScratch, Simulator};
pub use plan::{Plan, ResourceId, Tag, TagBreakdown, TaskId, TaskSpec};
pub use sched::{SchedPolicy, ScheduleTrace, Scheduler, TaskSlot};
pub use serve::{
    simulate_serve, BatchClose, CloseReason, Job, JobClass, ServeParams, ServeTrace,
    ServiceModel,
};
