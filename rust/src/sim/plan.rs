//! Task-DAG plan representation consumed by the simulator engine.

/// Index of a task within its [`Plan`].
pub type TaskId = usize;
/// Index of a sequential resource within its [`Plan`].
pub type ResourceId = usize;

/// Semantic label of a task, used for latency breakdowns (paper §5.4 Q1/Q2)
/// and energy accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tag {
    /// Expert weight streaming DRAM -> chiplet (fwd or bwd reload).
    WeightStream,
    /// Attention weight load on the attention DRAM channels.
    AttnWeightLoad,
    /// Attention compute (QKV projection, scores, output projection).
    AttnCompute,
    /// Router/gating compute.
    Router,
    /// All-to-all dispatch (attention -> expert chiplets).
    A2aDispatch,
    /// Expert FFN compute on an MoE chiplet.
    MoeCompute,
    /// All-to-all combine (expert chiplets -> attention), switch-aggregated.
    A2aCombine,
    /// Saving activations to DRAM for the backward pass.
    ActSave,
    /// Re-reading activations during backward.
    ActLoad,
    /// Gradient writeback to DRAM.
    GradWriteback,
    /// Optimizer update (near-memory read-modify-write of weights+state).
    OptimUpdate,
    /// Synchronization / barrier placeholder (zero or small duration).
    Barrier,
}

impl Tag {
    /// Number of tag variants; sizes the dense per-tag accumulators.
    pub const COUNT: usize = 12;

    /// Stable dense index of this tag (declaration order, matching
    /// [`Tag::ALL`]). The simulator's accounting arrays index by this.
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Every tag in declaration (dense-index) order.
    pub const ALL: [Tag; 12] = [
        Tag::WeightStream,
        Tag::AttnWeightLoad,
        Tag::AttnCompute,
        Tag::Router,
        Tag::A2aDispatch,
        Tag::MoeCompute,
        Tag::A2aCombine,
        Tag::ActSave,
        Tag::ActLoad,
        Tag::GradWriteback,
        Tag::OptimUpdate,
        Tag::Barrier,
    ];

    /// Kebab-case display name used by the breakdown printers.
    pub fn name(&self) -> &'static str {
        match self {
            Tag::WeightStream => "weight-stream",
            Tag::AttnWeightLoad => "attn-weight-load",
            Tag::AttnCompute => "attn-compute",
            Tag::Router => "router",
            Tag::A2aDispatch => "a2a-dispatch",
            Tag::MoeCompute => "moe-compute",
            Tag::A2aCombine => "a2a-combine",
            Tag::ActSave => "act-save",
            Tag::ActLoad => "act-load",
            Tag::GradWriteback => "grad-writeback",
            Tag::OptimUpdate => "optim-update",
            Tag::Barrier => "barrier",
        }
    }
}

/// Dense per-[`Tag`] `f64` accumulator: a fixed-size array indexed by
/// [`Tag::index`], replacing the `Vec<(Tag, f64)>` find-scans that used to
/// cost O(|Tag|) per task/query on the simulator hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TagBreakdown {
    vals: [f64; Tag::COUNT],
}

impl TagBreakdown {
    /// The all-zero accumulator.
    pub const fn zero() -> TagBreakdown {
        TagBreakdown {
            vals: [0.0; Tag::COUNT],
        }
    }

    /// Value accumulated for `tag`.
    #[inline]
    pub fn get(&self, tag: Tag) -> f64 {
        self.vals[tag.index()]
    }

    /// Accumulate `v` into `tag`'s slot.
    #[inline]
    pub fn add(&mut self, tag: Tag, v: f64) {
        self.vals[tag.index()] += v;
    }

    /// `self[t] += other[t] / divisor` for every tag (iteration averaging).
    pub fn accumulate_div(&mut self, other: &TagBreakdown, divisor: f64) {
        for i in 0..Tag::COUNT {
            self.vals[i] += other.vals[i] / divisor;
        }
    }

    /// Sum over all tags.
    pub fn sum(&self) -> f64 {
        self.vals.iter().sum()
    }

    /// Iterate `(tag, value)` pairs in [`Tag::ALL`] order.
    pub fn iter(&self) -> impl Iterator<Item = (Tag, f64)> + '_ {
        Tag::ALL.iter().map(move |&t| (t, self.vals[t.index()]))
    }

    /// Collect the `(tag, value)` pairs (report sorting convenience).
    pub fn to_vec(&self) -> Vec<(Tag, f64)> {
        self.iter().collect()
    }
}

/// One schedulable unit of work.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    /// Sequential resource this task occupies (None = pure dependency node).
    pub resource: Option<ResourceId>,
    /// Service time on the resource, seconds.
    pub duration: f64,
    /// Tasks that must finish before this one may start.
    pub deps: Vec<TaskId>,
    /// Scheduling priority among same-resource contenders (lower = sooner);
    /// the streaming-experts scheduler uses this to load hot clusters first.
    pub priority: i64,
    /// Semantic label for breakdowns and energy accounting.
    pub tag: Tag,
    /// Bytes moved (memory/NoP tasks) — for energy accounting.
    pub bytes: f64,
    /// FLOPs executed (compute tasks) — for energy accounting.
    pub flops: f64,
}

/// A full plan: resources + task DAG.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Plan {
    /// Display names of the sequential resources, indexed by `ResourceId`.
    pub resource_names: Vec<String>,
    /// The task DAG, indexed by `TaskId`. Deps *usually* point backwards
    /// (tasks are appended in dependency order), but forward edges are
    /// legal and do occur — the pipeline builder patches barrier gates
    /// with higher ids into earlier tasks' deps in baseline mode — so
    /// consumers must never assume `dep < id` (acyclicity is what
    /// [`Plan::validate`] actually checks).
    pub tasks: Vec<TaskSpec>,
}

impl Plan {
    /// An empty plan.
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Register a sequential resource; returns its id.
    pub fn add_resource(&mut self, name: impl Into<String>) -> ResourceId {
        self.resource_names.push(name.into());
        self.resource_names.len() - 1
    }

    /// Add a task; returns its id.
    pub fn add_task(&mut self, spec: TaskSpec) -> TaskId {
        // reject definitely-negative durations eagerly; NaN/inf flow on to
        // `validate`, which reports them with task context instead of
        // panicking mid-build
        debug_assert!(!(spec.duration < 0.0), "negative task duration");
        self.tasks.push(spec);
        self.tasks.len() - 1
    }

    /// Convenience builder for common tasks.
    pub fn task(
        &mut self,
        tag: Tag,
        resource: Option<ResourceId>,
        duration: f64,
        deps: &[TaskId],
    ) -> TaskId {
        self.add_task(TaskSpec {
            resource,
            duration,
            deps: deps.to_vec(),
            priority: 0,
            tag,
            bytes: 0.0,
            flops: 0.0,
        })
    }

    /// Number of tasks in the plan.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Validate: deps reference earlier-or-existing tasks, resources exist,
    /// and the graph is acyclic (guaranteed if deps < id, checked here).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, t) in self.tasks.iter().enumerate() {
            if let Some(r) = t.resource {
                anyhow::ensure!(
                    r < self.resource_names.len(),
                    "task {i}: resource {r} undefined"
                );
            }
            anyhow::ensure!(
                t.duration.is_finite() && t.duration >= 0.0,
                "task {i}: non-finite or negative duration {}",
                t.duration
            );
            anyhow::ensure!(
                t.bytes.is_finite() && t.bytes >= 0.0,
                "task {i}: non-finite or negative bytes {}",
                t.bytes
            );
            anyhow::ensure!(
                t.flops.is_finite() && t.flops >= 0.0,
                "task {i}: non-finite or negative flops {}",
                t.flops
            );
            for &d in &t.deps {
                anyhow::ensure!(d < self.tasks.len(), "task {i}: dep {d} out of range");
                anyhow::ensure!(d != i, "task {i}: self-dependency");
            }
        }
        // cycle check via Kahn's algorithm
        let mut indeg = vec![0usize; self.tasks.len()];
        let mut out: Vec<Vec<TaskId>> = vec![Vec::new(); self.tasks.len()];
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                indeg[i] += 1;
                out[d].push(i);
            }
        }
        let mut stack: Vec<TaskId> = (0..self.tasks.len())
            .filter(|&i| indeg[i] == 0)
            .collect();
        let mut seen = 0usize;
        while let Some(i) = stack.pop() {
            seen += 1;
            for &j in &out[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    stack.push(j);
                }
            }
        }
        anyhow::ensure!(seen == self.tasks.len(), "plan contains a cycle");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_validate() {
        let mut p = Plan::new();
        let r = p.add_resource("dram");
        let a = p.task(Tag::WeightStream, Some(r), 1.0, &[]);
        let b = p.task(Tag::MoeCompute, Some(r), 2.0, &[a]);
        assert_eq!(p.n_tasks(), 2);
        assert_eq!(p.tasks[b].deps, vec![a]);
        p.validate().unwrap();
    }

    #[test]
    fn validate_catches_bad_resource() {
        let mut p = Plan::new();
        p.add_task(TaskSpec {
            resource: Some(3),
            duration: 1.0,
            deps: vec![],
            priority: 0,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_catches_cycle() {
        let mut p = Plan::new();
        let r = p.add_resource("x");
        // manual cycle 0 -> 1 -> 0
        p.add_task(TaskSpec {
            resource: Some(r),
            duration: 1.0,
            deps: vec![1],
            priority: 0,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
        p.add_task(TaskSpec {
            resource: Some(r),
            duration: 1.0,
            deps: vec![0],
            priority: 0,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_catches_nan_duration() {
        let mut p = Plan::new();
        let r = p.add_resource("x");
        p.add_task(TaskSpec {
            resource: Some(r),
            duration: f64::NAN,
            deps: vec![],
            priority: 0,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("duration"), "unhelpful error: {err}");
    }

    #[test]
    fn tag_index_matches_all_order() {
        assert_eq!(Tag::ALL.len(), Tag::COUNT);
        for (i, t) in Tag::ALL.iter().enumerate() {
            assert_eq!(t.index(), i, "Tag::ALL order diverged from index()");
        }
    }

    #[test]
    fn tag_breakdown_accumulates() {
        let mut b = TagBreakdown::zero();
        b.add(Tag::MoeCompute, 2.0);
        b.add(Tag::MoeCompute, 1.0);
        b.add(Tag::Router, 0.5);
        assert_eq!(b.get(Tag::MoeCompute), 3.0);
        assert_eq!(b.get(Tag::WeightStream), 0.0);
        assert_eq!(b.sum(), 3.5);
        let mut acc = TagBreakdown::zero();
        acc.accumulate_div(&b, 2.0);
        acc.accumulate_div(&b, 2.0);
        assert_eq!(acc.get(Tag::MoeCompute), 3.0);
        assert_eq!(acc.to_vec().len(), Tag::COUNT);
        assert_eq!(
            b.iter().filter(|(_, v)| *v > 0.0).count(),
            2,
            "iter yields only the two touched tags as nonzero"
        );
    }

    #[test]
    fn tag_names_unique() {
        let mut names: Vec<&str> = Tag::ALL.iter().map(|t| t.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Tag::ALL.len());
    }
}
