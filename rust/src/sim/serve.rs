//! Event-driven queueing layer for the serving workload: continuous
//! dynamic batching of prefill/decode jobs over a single logical server,
//! plus the [`ServeTrace::validate`] queueing-invariant oracle.
//!
//! The model is deliberately the textbook one so it can be checked
//! against closed-form queueing theory (M/D/1 Pollaczek–Khinchine in
//! the differential tests) while still exercising the real batching
//! semantics of LLM serving:
//!
//! * Each [`Request`](crate::trace::arrivals::Request) expands into a
//!   **prefill job** (ready at arrival) followed by a chain of **decode
//!   chunk jobs** — continuous batching: a request re-enters the ready
//!   queue after every chunk, so new arrivals interleave with in-flight
//!   decodes instead of waiting behind whole requests.
//! * The server executes one batch at a time. **Batch close IS service
//!   start**: when the server frees up, the [`BatchClose`] policy picks
//!   the moment the next batch closes (`size:N` waits for N ready jobs,
//!   `timeout:MS` closes a deadline after the oldest ready job,
//!   `hybrid:MS:N` at whichever trigger fires first) and the batch
//!   departs as one unit after a [`ServiceModel`] lookup on its total
//!   token count. Once the arrival stream is exhausted the closer
//!   switches to drain mode (serve whatever is ready, immediately) so
//!   every run ends with an empty queue — which is what lets the
//!   Little's-law check hold exactly.
//! * Admission is FIFO with an optional queue cap: a request arriving
//!   while `queue_cap` requests are in the system is dropped (and
//!   counted — conservation is an oracle invariant, nothing vanishes).
//!
//! Everything the engine decides is recorded in a [`ServeTrace`];
//! [`ServeTrace::validate`] re-derives every decision from first
//! principles (FIFO-within-class order, no service before ready, batch
//! tightness `start == max(prev_finish, trigger)` with exact f64
//! equality, close-policy triggers, conservation, drop legality,
//! service-duration exactness) and is run automatically under
//! `debug_assertions` — the serving analogue of
//! [`ScheduleTrace::validate`](crate::sim::sched::ScheduleTrace).

use crate::trace::arrivals::Request;
use anyhow::{bail, ensure, Context, Result};

/// Which pass a job belongs to (prefill = prompt ingestion, decode =
/// one autoregressive output chunk).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobClass {
    /// Prompt-ingestion pass: one job per request, ready at arrival.
    Prefill,
    /// One decode chunk; ready when the previous chunk's batch finishes.
    Decode,
}

impl JobClass {
    /// Lowercase label for artifacts.
    pub fn label(self) -> &'static str {
        match self {
            JobClass::Prefill => "prefill",
            JobClass::Decode => "decode",
        }
    }
}

/// One schedulable unit of work: a request's prefill pass or one of its
/// decode chunks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Job {
    /// Owning request id.
    pub request: u64,
    /// Prefill or decode.
    pub class: JobClass,
    /// Chunk index within the request: 0 = prefill, 1.. = decode chunks.
    pub seq: u32,
    /// Tokens processed by this job.
    pub tokens: u32,
    /// Earliest time the job can be served (arrival for prefill, the
    /// producing batch's finish for a decode chunk).
    pub ready_s: f64,
}

fn job_key(j: &Job) -> (f64, u64, u32) {
    (j.ready_s, j.request, j.seq)
}

fn key_lt(a: (f64, u64, u32), b: (f64, u64, u32)) -> bool {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)) == std::cmp::Ordering::Less
}

/// FIFO order: by ready time, ties by request id then chunk index.
fn sort_jobs(jobs: &mut [Job]) {
    jobs.sort_by(|a, b| {
        a.ready_s
            .total_cmp(&b.ready_s)
            .then(a.request.cmp(&b.request))
            .then(a.seq.cmp(&b.seq))
    });
}

/// When the next batch closes (and, since batch close is service start,
/// when the server begins executing it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchClose {
    /// Close as soon as `N` jobs are ready; the batch is exactly `N` jobs.
    Size(usize),
    /// Close a fixed deadline (seconds) after the oldest ready job.
    Timeout(f64),
    /// Whichever of `Size(N)` / `Timeout(s)` fires first; batches are
    /// capped at `N` jobs either way.
    Hybrid(f64, usize),
}

impl BatchClose {
    /// Parse the CLI grammar: `size:N` | `timeout:MS` | `hybrid:MS:N`
    /// (milliseconds on the wire, seconds internally).
    pub fn parse(spec: &str) -> Result<BatchClose> {
        let parts: Vec<&str> = spec.split(':').collect();
        let close = match parts.as_slice() {
            ["size", n] => BatchClose::Size(
                n.parse::<usize>()
                    .with_context(|| format!("bad batch size in `{spec}`"))?,
            ),
            ["timeout", ms] => BatchClose::Timeout(
                ms.parse::<f64>()
                    .with_context(|| format!("bad timeout in `{spec}`"))?
                    / 1e3,
            ),
            ["hybrid", ms, n] => BatchClose::Hybrid(
                ms.parse::<f64>()
                    .with_context(|| format!("bad timeout in `{spec}`"))?
                    / 1e3,
                n.parse::<usize>()
                    .with_context(|| format!("bad batch size in `{spec}`"))?,
            ),
            _ => bail!("bad batch-close spec `{spec}` (expected size:N | timeout:MS | hybrid:MS:N)"),
        };
        close.check()?;
        Ok(close)
    }

    fn check(&self) -> Result<()> {
        match *self {
            BatchClose::Size(n) => ensure!(n >= 1, "batch size must be >= 1"),
            BatchClose::Timeout(s) => {
                ensure!(s >= 0.0 && s.is_finite(), "batch timeout must be >= 0")
            }
            BatchClose::Hybrid(s, n) => {
                ensure!(s >= 0.0 && s.is_finite(), "batch timeout must be >= 0");
                ensure!(n >= 1, "batch size must be >= 1");
            }
        }
        Ok(())
    }

    /// Short label (`size:8`, `timeout:5ms`, `hybrid:5ms:8`).
    pub fn label(&self) -> String {
        match *self {
            BatchClose::Size(n) => format!("size:{n}"),
            BatchClose::Timeout(s) => format!("timeout:{}ms", s * 1e3),
            BatchClose::Hybrid(s, n) => format!("hybrid:{}ms:{n}", s * 1e3),
        }
    }
}

/// Token-bucketed batch service times: the cost of executing one closed
/// batch, looked up by its total token count (smallest bucket that
/// covers the count; the largest bucket is the ceiling). Built by the
/// serve coordinator from real step simulations; tests construct
/// degenerate models directly (e.g. [`ServiceModel::constant`] for the
/// deterministic-service M/D/1 differential).
#[derive(Clone, Debug)]
pub struct ServiceModel {
    /// `(max_tokens, latency_s)` rows, strictly increasing in tokens.
    buckets: Vec<(u64, f64)>,
}

impl ServiceModel {
    /// Build from `(max_tokens, latency_s)` rows (strictly increasing
    /// token ceilings, positive finite latencies).
    pub fn new(buckets: Vec<(u64, f64)>) -> Result<ServiceModel> {
        ensure!(!buckets.is_empty(), "service model needs at least one bucket");
        for w in buckets.windows(2) {
            ensure!(
                w[0].0 < w[1].0,
                "service-model buckets must be strictly increasing"
            );
        }
        for &(t, l) in &buckets {
            ensure!(t >= 1, "bucket token ceiling must be >= 1");
            ensure!(l > 0.0 && l.is_finite(), "bucket latency must be > 0");
        }
        Ok(ServiceModel { buckets })
    }

    /// A model that serves any batch in exactly `latency_s` seconds —
    /// deterministic service, as the M/D/1 closed form assumes.
    pub fn constant(latency_s: f64) -> ServiceModel {
        ServiceModel::new(vec![(u64::MAX, latency_s)]).expect("constant model")
    }

    /// Service time for a batch totalling `tokens` tokens.
    pub fn service_time(&self, tokens: u64) -> f64 {
        for &(cap, lat) in &self.buckets {
            if tokens <= cap {
                return lat;
            }
        }
        self.buckets.last().expect("non-empty").1
    }

    /// The `(max_tokens, latency_s)` rows (for artifacts and docs).
    pub fn buckets(&self) -> &[(u64, f64)] {
        &self.buckets
    }
}

/// Engine knobs for one serving run.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeParams {
    /// Batch-close policy.
    pub close: BatchClose,
    /// Job cap per batch for timeout-closed and drain batches (`size` /
    /// `hybrid` batches are capped by their own `N`).
    pub max_batch_jobs: usize,
    /// Admission cap on requests in the system; `0` = unbounded.
    pub queue_cap: usize,
    /// Decode tokens per chunk (>= 1); smaller chunks interleave decode
    /// with new prefills more aggressively.
    pub decode_chunk: u32,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            close: BatchClose::Hybrid(0.005, 8),
            max_batch_jobs: 32,
            queue_cap: 0,
            decode_chunk: 32,
        }
    }
}

/// Why a batch closed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloseReason {
    /// The size trigger fired: the Nth job became ready.
    Size,
    /// The timeout trigger fired: the oldest ready job hit its deadline.
    Timeout,
    /// The arrival stream was exhausted; the closer drains what is ready.
    Drain,
}

impl CloseReason {
    /// Lowercase label for artifacts.
    pub fn label(self) -> &'static str {
        match self {
            CloseReason::Size => "size",
            CloseReason::Timeout => "timeout",
            CloseReason::Drain => "drain",
        }
    }
}

/// One executed batch: close/start time, finish, members.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchRec {
    /// Batch close == service start time.
    pub start_s: f64,
    /// Service completion time (`start_s + service_time(tokens)`).
    pub finish_s: f64,
    /// Total tokens across member jobs.
    pub tokens: u64,
    /// Which trigger closed the batch.
    pub reason: CloseReason,
    /// Member jobs in selection (FIFO) order.
    pub jobs: Vec<Job>,
}

/// Final disposition of one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Outcome {
    /// All jobs served; the request left the system at `finish_s`.
    Completed {
        /// Finish time of the request's last job's batch.
        finish_s: f64,
    },
    /// Rejected at arrival because the queue cap was reached.
    Dropped,
}

/// One request plus its disposition, as recorded in the trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestRec {
    /// The original request.
    pub request: Request,
    /// Completed-or-dropped disposition (conservation: never neither).
    pub outcome: Outcome,
}

/// Complete record of one serving run: every admission decision, every
/// batch, every timestamp — enough for [`ServeTrace::validate`] to
/// re-derive the engine's behavior from first principles.
#[derive(Clone, Debug)]
pub struct ServeTrace {
    /// Engine knobs the run used.
    pub params: ServeParams,
    /// Every offered request with its disposition, in arrival (id) order.
    pub requests: Vec<RequestRec>,
    /// Executed batches in service order.
    pub batches: Vec<BatchRec>,
}

/// One tenant's server instance in a multi-tenant partition
/// (`coordinator::tenants`): its own service model (simulated on the
/// tenant's carved sub-wafer) and queueing knobs, isolated from every
/// other tenant — the partition shares silicon, never queues. Binding the
/// pair into one value keeps a tenant's engine configuration from drifting
/// between the policy sweep's repeated evaluations.
#[derive(Clone, Debug)]
pub struct TenantServer {
    /// Tenant label (diagnostics only; timing never reads it).
    pub label: String,
    /// Bucketed service model of the tenant's sub-wafer.
    pub model: ServiceModel,
    /// Queueing-engine knobs for this tenant's instance.
    pub params: ServeParams,
}

impl TenantServer {
    /// Run this tenant's queue over its own arrival stream. The trace is
    /// checked against the queueing-invariant oracle unconditionally —
    /// tenant traces feed the partition artifact, and every emitted point
    /// must be oracle-clean.
    pub fn run(&self, requests: &[Request]) -> ServeTrace {
        let trace = simulate_serve(requests, &self.model, &self.params);
        trace
            .validate(&self.model)
            .unwrap_or_else(|e| panic!("tenant {} trace failed the oracle: {e}", self.label));
        trace
    }
}

/// Run the serving simulation: expand `requests` (sorted by arrival)
/// into prefill/decode jobs, batch them per `params`, and time every
/// batch with `model`. Drains to an empty queue after the last arrival.
///
/// Under `debug_assertions` the returned trace is validated by
/// [`ServeTrace::validate`] before being returned.
pub fn simulate_serve(
    requests: &[Request],
    model: &ServiceModel,
    params: &ServeParams,
) -> ServeTrace {
    assert!(params.decode_chunk >= 1, "decode_chunk must be >= 1");
    assert!(params.max_batch_jobs >= 1, "max_batch_jobs must be >= 1");
    params.close.check().expect("valid close policy");
    for w in requests.windows(2) {
        assert!(
            w[0].arrival_s <= w[1].arrival_s && w[0].id < w[1].id,
            "requests must be sorted by arrival with increasing ids"
        );
    }

    let n = requests.len();
    let mut outcome: Vec<Option<Outcome>> = vec![None; n];
    let mut pending: Vec<Job> = Vec::new();
    let mut batches: Vec<BatchRec> = Vec::new();
    // departures not yet applied to the in-system count, sorted ascending
    let mut departures: Vec<f64> = Vec::new();
    let mut in_system: usize = 0;
    let mut arr_idx: usize = 0;
    let mut free: f64 = 0.0;

    let admit_until = |t: f64,
                           arr_idx: &mut usize,
                           pending: &mut Vec<Job>,
                           departures: &mut Vec<f64>,
                           in_system: &mut usize,
                           outcome: &mut Vec<Option<Outcome>>| {
        while *arr_idx < n && requests[*arr_idx].arrival_s <= t {
            let r = &requests[*arr_idx];
            // departures at or before this arrival free their slots first
            while let Some(&d) = departures.first() {
                if d <= r.arrival_s {
                    departures.remove(0);
                    *in_system -= 1;
                } else {
                    break;
                }
            }
            if params.queue_cap > 0 && *in_system >= params.queue_cap {
                outcome[r.id as usize] = Some(Outcome::Dropped);
            } else {
                pending.push(Job {
                    request: r.id,
                    class: JobClass::Prefill,
                    seq: 0,
                    tokens: r.prefill_tokens,
                    ready_s: r.arrival_s,
                });
                *in_system += 1;
            }
            *arr_idx += 1;
        }
    };

    loop {
        admit_until(free, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
        if pending.is_empty() {
            if arr_idx == n {
                break;
            }
            let t = requests[arr_idx].arrival_s;
            admit_until(t, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
            continue;
        }
        sort_jobs(&mut pending);

        // decide the close time, batch cap, and reason
        let (close, cap, reason) = if arr_idx == n {
            // drain mode: serve whatever is ready, immediately
            let cap = match params.close {
                BatchClose::Size(nb) | BatchClose::Hybrid(_, nb) => nb,
                BatchClose::Timeout(_) => params.max_batch_jobs,
            };
            (free.max(pending[0].ready_s), cap, CloseReason::Drain)
        } else {
            match params.close {
                BatchClose::Size(nb) => {
                    // wait for the Nth job, admitting any arrival that
                    // would beat (or tie) the current trigger
                    loop {
                        if pending.len() >= nb {
                            let t_sz = pending[nb - 1].ready_s;
                            if arr_idx < n && requests[arr_idx].arrival_s <= t_sz {
                                let t = requests[arr_idx].arrival_s;
                                admit_until(t, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
                                sort_jobs(&mut pending);
                                continue;
                            }
                            break;
                        }
                        if arr_idx == n {
                            break;
                        }
                        let t = requests[arr_idx].arrival_s;
                        admit_until(t, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
                        sort_jobs(&mut pending);
                    }
                    if pending.len() >= nb {
                        (free.max(pending[nb - 1].ready_s), nb, CloseReason::Size)
                    } else {
                        // waiting exhausted the arrivals: drain
                        (free.max(pending[0].ready_s), nb, CloseReason::Drain)
                    }
                }
                BatchClose::Timeout(tmo) => {
                    let t_to = pending[0].ready_s + tmo;
                    let close = free.max(t_to);
                    admit_until(close, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
                    (close, params.max_batch_jobs, CloseReason::Timeout)
                }
                BatchClose::Hybrid(tmo, nb) => {
                    let t_to = pending[0].ready_s + tmo;
                    let horizon = free.max(t_to);
                    admit_until(horizon, &mut arr_idx, &mut pending, &mut departures, &mut in_system, &mut outcome);
                    sort_jobs(&mut pending);
                    if pending.len() >= nb && pending[nb - 1].ready_s <= t_to {
                        (free.max(pending[nb - 1].ready_s), nb, CloseReason::Size)
                    } else {
                        (horizon, nb, CloseReason::Timeout)
                    }
                }
            }
        };

        // form the batch: the oldest ready jobs at `close`, up to `cap`
        // (re-sort: the policy branches may have admitted new arrivals)
        sort_jobs(&mut pending);
        let mut batch: Vec<Job> = Vec::new();
        let mut rest: Vec<Job> = Vec::new();
        for job in pending.drain(..) {
            if batch.len() < cap && job.ready_s <= close {
                batch.push(job);
            } else {
                rest.push(job);
            }
        }
        pending = rest;
        debug_assert!(!batch.is_empty(), "closed an empty batch");

        let tokens: u64 = batch.iter().map(|j| j.tokens as u64).sum();
        let dur = model.service_time(tokens);
        let finish = close + dur;

        // spawn decode continuations / record completions
        for job in &batch {
            let req = &requests[job.request as usize];
            let chunks = req.decode_tokens.div_ceil(params.decode_chunk);
            if job.seq < chunks {
                let done = job.seq * params.decode_chunk;
                let next = (req.decode_tokens - done).min(params.decode_chunk);
                pending.push(Job {
                    request: job.request,
                    class: JobClass::Decode,
                    seq: job.seq + 1,
                    tokens: next,
                    ready_s: finish,
                });
            } else {
                outcome[job.request as usize] = Some(Outcome::Completed { finish_s: finish });
                let at = departures.partition_point(|&d| d <= finish);
                departures.insert(at, finish);
            }
        }

        batches.push(BatchRec {
            start_s: close,
            finish_s: finish,
            tokens,
            reason,
            jobs: batch,
        });
        free = finish;
    }

    let trace = ServeTrace {
        params: params.clone(),
        requests: requests
            .iter()
            .map(|r| RequestRec {
                request: *r,
                outcome: outcome[r.id as usize].expect("conservation: drained to empty"),
            })
            .collect(),
        batches,
    };
    #[cfg(debug_assertions)]
    trace
        .validate(model)
        .expect("serve trace failed its own oracle");
    trace
}

impl ServeTrace {
    /// `(arrival_s, finish_s)` spans of completed requests (the input to
    /// the Little's-law check).
    pub fn completed_spans(&self) -> Vec<(f64, f64)> {
        self.requests
            .iter()
            .filter_map(|r| match r.outcome {
                Outcome::Completed { finish_s } => Some((r.request.arrival_s, finish_s)),
                Outcome::Dropped => None,
            })
            .collect()
    }

    /// Number of dropped requests.
    pub fn dropped(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| r.outcome == Outcome::Dropped)
            .count()
    }

    /// The queueing-invariant oracle. Checks, with exact f64 equality
    /// where the engine computes exactly:
    ///
    /// 1. **Conservation** — every request is completed XOR dropped;
    ///    a completed request's jobs form exactly `prefill` +
    ///    `ceil(decode/chunk)` decode chunks with the right token
    ///    counts, served exactly once each, and its recorded finish is
    ///    its last batch's finish; a dropped request has no jobs.
    /// 2. **Causality** — no job served before it is ready; prefill
    ///    ready == arrival; decode-chunk ready == producing batch finish.
    /// 3. **FIFO within class** — flattened service order is sorted by
    ///    `(ready_s, request, seq)` within each job class.
    /// 4. **Server exclusivity + tightness** — batches do not overlap
    ///    and `start == max(prev_finish, trigger)` where the trigger is
    ///    re-derived per close reason (`Size`: Nth member's ready;
    ///    `Timeout`: oldest member's ready + deadline; `Drain`: oldest
    ///    member's ready).
    /// 5. **Close policy honored** — `Size` batches carry exactly N
    ///    jobs; `Timeout`/`Drain`/hybrid batches respect their caps,
    ///    and an under-cap batch leaves no ready job behind
    ///    (completeness / no starvation); `Drain` batches form a
    ///    suffix of the run.
    /// 6. **Service-duration exactness** — `finish == start +
    ///    service_time(tokens)` and `tokens` equals the member sum.
    /// 7. **Drop legality** — with a queue cap, a request is dropped
    ///    iff the cap was reached at its arrival instant.
    pub fn validate(&self, model: &ServiceModel) -> Result<()> {
        let params = &self.params;
        ensure!(params.decode_chunk >= 1, "decode_chunk must be >= 1");

        // ---- per-request job accounting (conservation + causality) ----
        let mut jobs_of: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.requests.len()];
        for (bi, b) in self.batches.iter().enumerate() {
            ensure!(!b.jobs.is_empty(), "batch {bi} is empty");
            for (ji, j) in b.jobs.iter().enumerate() {
                ensure!(
                    (j.request as usize) < self.requests.len(),
                    "batch {bi}: unknown request {}",
                    j.request
                );
                jobs_of[j.request as usize].push((bi, ji));
            }
        }
        for (ri, rec) in self.requests.iter().enumerate() {
            ensure!(
                rec.request.id as usize == ri,
                "request ids must be dense and ordered"
            );
            let r = &rec.request;
            let served = &jobs_of[ri];
            match rec.outcome {
                Outcome::Dropped => {
                    ensure!(
                        served.is_empty(),
                        "dropped request {ri} was served (conservation)"
                    );
                    ensure!(
                        params.queue_cap > 0,
                        "request {ri} dropped without a queue cap"
                    );
                }
                Outcome::Completed { finish_s } => {
                    let chunks = r.decode_tokens.div_ceil(params.decode_chunk);
                    ensure!(
                        served.len() == 1 + chunks as usize,
                        "request {ri}: {} jobs served, expected {} (conservation)",
                        served.len(),
                        1 + chunks
                    );
                    let mut prev_finish = r.arrival_s;
                    for (seq, &(bi, ji)) in served.iter().enumerate() {
                        let b = &self.batches[bi];
                        let j = &b.jobs[ji];
                        ensure!(
                            j.seq as usize == seq,
                            "request {ri}: job seq {} out of order",
                            j.seq
                        );
                        let (class, want_tokens, want_ready) = if seq == 0 {
                            (JobClass::Prefill, r.prefill_tokens, r.arrival_s)
                        } else {
                            let done = (seq as u32 - 1) * params.decode_chunk;
                            (
                                JobClass::Decode,
                                (r.decode_tokens - done).min(params.decode_chunk),
                                prev_finish,
                            )
                        };
                        ensure!(j.class == class, "request {ri} job {seq}: wrong class");
                        ensure!(
                            j.tokens == want_tokens,
                            "request {ri} job {seq}: {} tokens, expected {want_tokens}",
                            j.tokens
                        );
                        ensure!(
                            j.ready_s == want_ready,
                            "request {ri} job {seq}: ready {} != {want_ready} \
                             (no request served before arrival / chunk chaining)",
                            j.ready_s
                        );
                        ensure!(
                            b.start_s >= j.ready_s,
                            "request {ri} job {seq}: served at {} before ready {}",
                            b.start_s,
                            j.ready_s
                        );
                        prev_finish = b.finish_s;
                    }
                    ensure!(
                        finish_s == prev_finish,
                        "request {ri}: recorded finish {finish_s} != last batch finish {prev_finish}"
                    );
                }
            }
        }

        // ---- FIFO within class over the flattened service order ----
        for class in [JobClass::Prefill, JobClass::Decode] {
            let mut prev: Option<(f64, u64, u32)> = None;
            for b in &self.batches {
                for j in &b.jobs {
                    if j.class != class {
                        continue;
                    }
                    let k = job_key(j);
                    if let Some(p) = prev {
                        ensure!(
                            key_lt(p, k),
                            "{} jobs served out of FIFO order: {:?} then {:?}",
                            class.label(),
                            p,
                            k
                        );
                    }
                    prev = Some(k);
                }
            }
        }

        // ---- batch-level checks ----
        let mut prev_finish = 0.0f64;
        let mut seen_drain = false;
        for (bi, b) in self.batches.iter().enumerate() {
            // service-duration exactness
            let tokens: u64 = b.jobs.iter().map(|j| j.tokens as u64).sum();
            ensure!(
                b.tokens == tokens,
                "batch {bi}: recorded {} tokens, members sum to {tokens}",
                b.tokens
            );
            let want_finish = b.start_s + model.service_time(tokens);
            ensure!(
                b.finish_s == want_finish,
                "batch {bi}: finish {} != start + service_time = {want_finish}",
                b.finish_s
            );

            // exclusivity
            ensure!(
                b.start_s >= prev_finish,
                "batch {bi} starts at {} before previous finish {prev_finish} (server exclusivity)",
                b.start_s
            );

            // drain batches form a suffix
            if b.reason == CloseReason::Drain {
                seen_drain = true;
            } else {
                ensure!(
                    !seen_drain,
                    "batch {bi}: {} batch after drain began",
                    b.reason.label()
                );
            }

            // tightness + policy trigger, re-derived from the members
            let min_ready = b.jobs.iter().map(|j| j.ready_s).fold(f64::INFINITY, f64::min);
            let max_ready = b.jobs.iter().map(|j| j.ready_s).fold(f64::NEG_INFINITY, f64::max);
            let cap = match (params.close, b.reason) {
                (BatchClose::Size(nb), CloseReason::Size) => {
                    ensure!(
                        b.jobs.len() == nb,
                        "batch {bi}: size-closed with {} jobs, policy wants {nb}",
                        b.jobs.len()
                    );
                    ensure!(
                        b.start_s == prev_finish.max(max_ready),
                        "batch {bi}: start {} != max(prev_finish, Nth ready) (tightness)",
                        b.start_s
                    );
                    nb
                }
                (BatchClose::Timeout(tmo), CloseReason::Timeout) => {
                    ensure!(
                        b.start_s == prev_finish.max(min_ready + tmo),
                        "batch {bi}: start {} != max(prev_finish, oldest + timeout) (tightness)",
                        b.start_s
                    );
                    params.max_batch_jobs
                }
                (BatchClose::Hybrid(tmo, nb), CloseReason::Size) => {
                    ensure!(
                        b.jobs.len() == nb,
                        "batch {bi}: size-closed with {} jobs, policy wants {nb}",
                        b.jobs.len()
                    );
                    ensure!(
                        max_ready <= min_ready + tmo,
                        "batch {bi}: size trigger after the hybrid deadline"
                    );
                    ensure!(
                        b.start_s == prev_finish.max(max_ready),
                        "batch {bi}: start {} != max(prev_finish, Nth ready) (tightness)",
                        b.start_s
                    );
                    nb
                }
                (BatchClose::Hybrid(tmo, nb), CloseReason::Timeout) => {
                    ensure!(
                        b.start_s == prev_finish.max(min_ready + tmo),
                        "batch {bi}: start {} != max(prev_finish, oldest + timeout) (tightness)",
                        b.start_s
                    );
                    nb
                }
                (close, CloseReason::Drain) => {
                    ensure!(
                        b.start_s == prev_finish.max(min_ready),
                        "batch {bi}: drain start {} != max(prev_finish, oldest ready) (tightness)",
                        b.start_s
                    );
                    match close {
                        BatchClose::Size(nb) | BatchClose::Hybrid(_, nb) => nb,
                        BatchClose::Timeout(_) => params.max_batch_jobs,
                    }
                }
                (close, reason) => bail!(
                    "batch {bi}: close reason `{}` impossible under policy `{}`",
                    reason.label(),
                    close.label()
                ),
            };
            ensure!(
                b.jobs.len() <= cap,
                "batch {bi}: {} jobs exceed the cap {cap}",
                b.jobs.len()
            );

            // completeness / no starvation: an under-cap batch leaves no
            // ready job behind for a later batch
            if b.jobs.len() < cap {
                for later in &self.batches[bi + 1..] {
                    for j in &later.jobs {
                        ensure!(
                            j.ready_s > b.start_s,
                            "batch {bi} closed under cap at {} but job {:?} \
                             (ready {}) was left waiting (completeness)",
                            b.start_s,
                            (j.request, j.seq),
                            j.ready_s
                        );
                    }
                }
            }

            prev_finish = b.finish_s;
        }

        // ---- drop legality under the queue cap ----
        if params.queue_cap > 0 {
            for (ri, rec) in self.requests.iter().enumerate() {
                let a = rec.request.arrival_s;
                // in-system at this arrival instant: admitted requests
                // ordered before this one whose completion is after `a`
                // (departures at exactly `a` free their slot first)
                let live = self
                    .requests
                    .iter()
                    .take(ri)
                    .filter(|q| match q.outcome {
                        Outcome::Completed { finish_s } => finish_s > a,
                        Outcome::Dropped => false,
                    })
                    .count();
                match rec.outcome {
                    Outcome::Dropped => ensure!(
                        live >= params.queue_cap,
                        "request {ri} dropped with only {live} in system (cap {})",
                        params.queue_cap
                    ),
                    Outcome::Completed { .. } => ensure!(
                        live < params.queue_cap,
                        "request {ri} admitted with {live} in system (cap {})",
                        params.queue_cap
                    ),
                }
            }
        }

        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::arrivals::{ArrivalProcess, RequestShape};

    fn poisson_requests(rate: f64, dur: f64, seed: u64) -> Vec<Request> {
        ArrivalProcess::Poisson { rate }.generate(dur, &RequestShape::fixed(128, 64), seed)
    }

    fn model() -> ServiceModel {
        ServiceModel::new(vec![(256, 0.001), (1024, 0.003), (4096, 0.008)]).unwrap()
    }

    #[test]
    fn batch_close_parse_grammar() {
        assert_eq!(BatchClose::parse("size:8").unwrap(), BatchClose::Size(8));
        assert_eq!(
            BatchClose::parse("timeout:5").unwrap(),
            BatchClose::Timeout(0.005)
        );
        assert_eq!(
            BatchClose::parse("hybrid:2:4").unwrap(),
            BatchClose::Hybrid(0.002, 4)
        );
        for bad in ["size", "size:0", "size:x", "timeout:-1", "hybrid:5", "grow:3", ""] {
            assert!(BatchClose::parse(bad).is_err(), "`{bad}` should fail");
        }
        assert_eq!(BatchClose::Hybrid(0.005, 8).label(), "hybrid:5ms:8");
    }

    #[test]
    fn service_model_bucket_lookup() {
        let m = model();
        assert_eq!(m.service_time(1), 0.001);
        assert_eq!(m.service_time(256), 0.001);
        assert_eq!(m.service_time(257), 0.003);
        assert_eq!(m.service_time(9999), 0.008); // above all buckets: ceiling
        assert!(ServiceModel::new(vec![]).is_err());
        assert!(ServiceModel::new(vec![(5, 0.1), (5, 0.2)]).is_err());
        assert!(ServiceModel::new(vec![(5, 0.0)]).is_err());
    }

    /// Every policy x arrival-process cell runs end to end and passes the
    /// oracle explicitly (it also ran implicitly in debug builds).
    #[test]
    fn oracle_passes_across_policy_and_process_grid() {
        let shape = RequestShape::fixed(96, 48);
        let processes: Vec<(&str, Vec<Request>)> = vec![
            (
                "poisson",
                ArrivalProcess::Poisson { rate: 300.0 }.generate(1.0, &shape, 5),
            ),
            (
                "mmpp",
                ArrivalProcess::Mmpp { rate: 300.0, burst: 6.0, dwell_s: 0.05 }
                    .generate(1.0, &shape, 5),
            ),
            (
                "diurnal",
                ArrivalProcess::Diurnal { rate: 300.0, period_s: 0.5, amplitude: 0.8 }
                    .generate(1.0, &shape, 5),
            ),
        ];
        let policies = [
            BatchClose::Size(4),
            BatchClose::Timeout(0.004),
            BatchClose::Hybrid(0.004, 4),
        ];
        let m = model();
        for (pname, reqs) in &processes {
            assert!(!reqs.is_empty(), "{pname}: no requests");
            for close in policies {
                let params = ServeParams { close, ..ServeParams::default() };
                let trace = simulate_serve(reqs, &m, &params);
                trace
                    .validate(&m)
                    .unwrap_or_else(|e| panic!("{pname} x {}: {e:#}", close.label()));
                assert_eq!(
                    trace.completed_spans().len() + trace.dropped(),
                    reqs.len(),
                    "{pname} x {}: conservation",
                    close.label()
                );
                // drains to empty: last batch finish >= last arrival
                let last = trace.batches.last().unwrap().finish_s;
                assert!(last >= reqs.last().unwrap().arrival_s);
            }
        }
    }

    #[test]
    fn size_policy_closes_exact_batches() {
        let reqs = poisson_requests(500.0, 1.0, 9);
        let m = model();
        let params = ServeParams {
            close: BatchClose::Size(4),
            ..ServeParams::default()
        };
        let trace = simulate_serve(&reqs, &m, &params);
        let sized = trace
            .batches
            .iter()
            .filter(|b| b.reason == CloseReason::Size)
            .count();
        assert!(sized > 0, "no size-closed batches at this load");
        for b in &trace.batches {
            match b.reason {
                CloseReason::Size => assert_eq!(b.jobs.len(), 4),
                CloseReason::Drain => assert!(b.jobs.len() <= 4),
                CloseReason::Timeout => panic!("timeout close under a size policy"),
            }
        }
    }

    #[test]
    fn queue_cap_drops_and_conserves() {
        let reqs = poisson_requests(2000.0, 0.5, 3);
        let m = ServiceModel::constant(0.01); // slow server: forced backlog
        let params = ServeParams {
            close: BatchClose::Size(1),
            queue_cap: 4,
            ..ServeParams::default()
        };
        let trace = simulate_serve(&reqs, &m, &params);
        trace.validate(&m).unwrap();
        assert!(trace.dropped() > 0, "cap 4 at 20x overload must drop");
        assert_eq!(trace.completed_spans().len() + trace.dropped(), reqs.len());
    }

    #[test]
    fn simulation_is_deterministic() {
        let reqs = poisson_requests(400.0, 1.0, 17);
        let m = model();
        let params = ServeParams::default();
        let a = simulate_serve(&reqs, &m, &params);
        let b = simulate_serve(&reqs, &m, &params);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.requests, b.requests);
    }

    // ---- oracle soundness: every mutation class is rejected ----

    fn valid_trace() -> (ServeTrace, ServiceModel) {
        let reqs = poisson_requests(300.0, 1.0, 21);
        let m = model();
        let params = ServeParams {
            close: BatchClose::Size(4),
            ..ServeParams::default()
        };
        let t = simulate_serve(&reqs, &m, &params);
        t.validate(&m).unwrap();
        (t, m)
    }

    #[test]
    fn oracle_rejects_reordered_admissions() {
        let (mut t, m) = valid_trace();
        // swap the first jobs of two different batches: FIFO breaks
        let (a, b) = (0, t.batches.len() / 2);
        assert_ne!(a, b);
        let ja = t.batches[a].jobs[0];
        let jb = t.batches[b].jobs[0];
        t.batches[a].jobs[0] = jb;
        t.batches[b].jobs[0] = ja;
        assert!(t.validate(&m).is_err(), "reordered admissions accepted");
    }

    #[test]
    fn oracle_rejects_serve_before_arrival() {
        let (mut t, m) = valid_trace();
        // claim a job was ready (and served) before its request arrived
        let bi = t.batches.len() / 2;
        let j = &mut t.batches[bi].jobs[0];
        j.ready_s -= 0.5;
        assert!(t.validate(&m).is_err(), "serve-before-arrival accepted");
    }

    #[test]
    fn oracle_rejects_dropped_completion() {
        let (mut t, m) = valid_trace();
        // lose a completion: mark a served request as dropped
        let ri = t.requests.len() / 2;
        t.requests[ri].outcome = Outcome::Dropped;
        assert!(t.validate(&m).is_err(), "lost completion accepted");
    }

    #[test]
    fn oracle_rejects_batch_close_violation() {
        let (mut t, m) = valid_trace();
        // shrink a size-closed batch below N (move its last job away)
        let bi = t
            .batches
            .iter()
            .position(|b| b.reason == CloseReason::Size)
            .expect("a size-closed batch");
        let j = t.batches[bi].jobs.pop().unwrap();
        t.batches[bi].tokens -= j.tokens as u64;
        // keep duration consistent so only the close policy is violated
        t.batches[bi].finish_s = t.batches[bi].start_s + m.service_time(t.batches[bi].tokens);
        assert!(t.validate(&m).is_err(), "undersized size batch accepted");
    }

    #[test]
    fn oracle_rejects_overlapping_batches() {
        let (mut t, m) = valid_trace();
        let bi = t.batches.len() / 2;
        // start a batch before its predecessor finished
        t.batches[bi].start_s = t.batches[bi - 1].finish_s - 1e-6;
        t.batches[bi].finish_s = t.batches[bi].start_s + m.service_time(t.batches[bi].tokens);
        assert!(t.validate(&m).is_err(), "overlapping batches accepted");
    }

    #[test]
    fn oracle_rejects_wrong_service_duration() {
        let (mut t, m) = valid_trace();
        let bi = t.batches.len() / 2;
        t.batches[bi].finish_s += 1e-9;
        assert!(t.validate(&m).is_err(), "padded service duration accepted");
    }

    #[test]
    fn tenant_server_is_a_transparent_wrapper() {
        // a tenant instance is the same engine behind a label: identical
        // requests and knobs produce a bit-identical trace
        let reqs = poisson_requests(120.0, 1.0, 9);
        let server = TenantServer {
            label: "serve:olmoe".to_string(),
            model: model(),
            params: ServeParams::default(),
        };
        let a = server.run(&reqs);
        let b = simulate_serve(&reqs, &model(), &ServeParams::default());
        assert_eq!(a.batches.len(), b.batches.len());
        for (x, y) in a.batches.iter().zip(b.batches.iter()) {
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
