//! Event-driven list-scheduling engine over a task-DAG plan.
//!
//! Tasks become *ready* when all dependencies finish; ready tasks contend
//! for their (sequential) resource and are served in (ready-time, priority,
//! id) order. The engine records start/finish per task, per-tag and
//! per-resource busy time, the makespan, and the critical path (the chain
//! of dependency/resource waits that determined the final finish time).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::plan::{Plan, Tag, TaskId};

/// Heap entry: min-heap by (ready_time, priority, id).
#[derive(PartialEq)]
struct Entry {
    ready: f64,
    priority: i64,
    id: TaskId,
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reverse for min-heap
        other
            .ready
            .partial_cmp(&self.ready)
            .unwrap()
            .then(other.priority.cmp(&self.priority))
            .then(other.id.cmp(&self.id))
    }
}

/// What determined a task's start time (for critical-path extraction).
#[derive(Clone, Copy, Debug)]
enum StartCause {
    /// No wait: started at its ready time with the resource idle.
    Dep(TaskId),
    /// Waited for the resource; the blocking task is recorded.
    Resource(TaskId),
    /// Source task (no deps, no wait).
    Source,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub makespan: f64,
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    /// Busy seconds per tag (sum of task durations).
    pub tag_busy: Vec<(Tag, f64)>,
    /// Busy seconds per resource.
    pub resource_busy: Vec<f64>,
    /// Seconds of the critical path attributed to each tag.
    pub critical_path: Vec<(Tag, f64)>,
    /// Total bytes and flops (energy accounting inputs) per tag.
    pub tag_bytes: Vec<(Tag, f64)>,
    pub tag_flops: Vec<(Tag, f64)>,
}

impl SimResult {
    pub fn tag_time(&self, tag: Tag) -> f64 {
        self.tag_busy
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    pub fn critical_time(&self, tag: Tag) -> f64 {
        self.critical_path
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    pub fn bytes(&self, tag: Tag) -> f64 {
        self.tag_bytes
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    pub fn flops(&self, tag: Tag) -> f64 {
        self.tag_flops
            .iter()
            .find(|(t, _)| *t == tag)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    /// Utilization of a resource relative to the makespan.
    pub fn utilization(&self, resource: usize) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.resource_busy[resource] / self.makespan
        }
    }
}

/// The engine. Stateless; `run` consumes a plan reference.
pub struct Simulator;

impl Simulator {
    /// Execute the plan, returning timing and accounting.
    pub fn run(plan: &Plan) -> SimResult {
        let n = plan.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (i, t) in plan.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for &d in &t.deps {
                dependents[d].push(i);
            }
        }

        let mut ready_time = vec![0.0f64; n];
        // which dep finished last (start cause candidate)
        let mut last_dep: Vec<Option<TaskId>> = vec![None; n];
        let mut heap: BinaryHeap<Entry> = BinaryHeap::new();
        for i in 0..n {
            if indeg[i] == 0 {
                heap.push(Entry {
                    ready: 0.0,
                    priority: plan.tasks[i].priority,
                    id: i,
                });
            }
        }

        let nres = plan.resource_names.len();
        let mut res_free = vec![0.0f64; nres];
        let mut res_last: Vec<Option<TaskId>> = vec![None; nres];
        let mut res_busy = vec![0.0f64; nres];

        let mut start = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut cause: Vec<StartCause> = vec![StartCause::Source; n];
        let mut done = 0usize;

        while let Some(e) = heap.pop() {
            let i = e.id;
            let t = &plan.tasks[i];
            let (s, c) = match t.resource {
                Some(r) => {
                    if res_free[r] > e.ready {
                        (res_free[r], StartCause::Resource(res_last[r].unwrap()))
                    } else {
                        match last_dep[i] {
                            Some(d) => (e.ready, StartCause::Dep(d)),
                            None => (e.ready, StartCause::Source),
                        }
                    }
                }
                None => match last_dep[i] {
                    Some(d) => (e.ready, StartCause::Dep(d)),
                    None => (e.ready, StartCause::Source),
                },
            };
            let f = s + t.duration;
            start[i] = s;
            finish[i] = f;
            cause[i] = c;
            if let Some(r) = t.resource {
                res_free[r] = f;
                res_last[r] = Some(i);
                res_busy[r] += t.duration;
            }
            done += 1;
            for &j in &dependents[i] {
                if f > ready_time[j] {
                    ready_time[j] = f;
                    last_dep[j] = Some(i);
                }
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    heap.push(Entry {
                        ready: ready_time[j],
                        priority: plan.tasks[j].priority,
                        id: j,
                    });
                }
            }
        }
        assert_eq!(done, n, "plan contains a cycle (validate() first)");

        let makespan = finish.iter().cloned().fold(0.0f64, f64::max);

        // per-tag accounting
        let mut tag_busy: Vec<(Tag, f64)> = Tag::ALL.iter().map(|&t| (t, 0.0)).collect();
        let mut tag_bytes: Vec<(Tag, f64)> = Tag::ALL.iter().map(|&t| (t, 0.0)).collect();
        let mut tag_flops: Vec<(Tag, f64)> = Tag::ALL.iter().map(|&t| (t, 0.0)).collect();
        let idx = |tag: Tag| Tag::ALL.iter().position(|&t| t == tag).unwrap();
        for t in &plan.tasks {
            tag_busy[idx(t.tag)].1 += t.duration;
            tag_bytes[idx(t.tag)].1 += t.bytes;
            tag_flops[idx(t.tag)].1 += t.flops;
        }

        // critical path: walk back from the last-finishing task
        let mut critical: Vec<(Tag, f64)> = Tag::ALL.iter().map(|&t| (t, 0.0)).collect();
        if n > 0 {
            let mut cur = (0..n)
                .max_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap())
                .unwrap();
            loop {
                critical[idx(plan.tasks[cur].tag)].1 += plan.tasks[cur].duration;
                match cause[cur] {
                    StartCause::Source => break,
                    StartCause::Dep(d) => cur = d,
                    StartCause::Resource(p) => cur = p,
                }
            }
        }

        SimResult {
            makespan,
            start,
            finish,
            tag_busy,
            resource_busy: res_busy,
            critical_path: critical,
            tag_bytes,
            tag_flops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::{Plan, Tag, TaskSpec};

    fn spec(resource: Option<usize>, duration: f64, deps: &[usize], priority: i64) -> TaskSpec {
        TaskSpec {
            resource,
            duration,
            deps: deps.to_vec(),
            priority,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        }
    }

    #[test]
    fn chain_accumulates() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let a = p.add_task(spec(Some(r), 1.0, &[], 0));
        let b = p.add_task(spec(Some(r), 2.0, &[a], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.finish[b], 3.0);
        assert_eq!(res.makespan, 3.0);
        assert_eq!(res.utilization(r), 1.0);
    }

    #[test]
    fn independent_tasks_on_distinct_resources_overlap() {
        let mut p = Plan::new();
        let r1 = p.add_resource("r1");
        let r2 = p.add_resource("r2");
        p.add_task(spec(Some(r1), 3.0, &[], 0));
        p.add_task(spec(Some(r2), 2.0, &[], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 3.0);
    }

    #[test]
    fn same_resource_serializes() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        p.add_task(spec(Some(r), 3.0, &[], 0));
        p.add_task(spec(Some(r), 2.0, &[], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 5.0);
    }

    #[test]
    fn priority_orders_contenders() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let lo = p.add_task(spec(Some(r), 1.0, &[], 10));
        let hi = p.add_task(spec(Some(r), 1.0, &[], -10));
        let res = Simulator::run(&p);
        assert!(res.start[hi] < res.start[lo]);
    }

    #[test]
    fn diamond_dependencies() {
        let mut p = Plan::new();
        let r1 = p.add_resource("r1");
        let r2 = p.add_resource("r2");
        let a = p.add_task(spec(Some(r1), 1.0, &[], 0));
        let b = p.add_task(spec(Some(r1), 2.0, &[a], 0));
        let c = p.add_task(spec(Some(r2), 5.0, &[a], 0));
        let d = p.add_task(spec(None, 0.0, &[b, c], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.finish[d], 6.0); // gated by the longer branch
        assert_eq!(res.makespan, 6.0);
    }

    #[test]
    fn critical_path_follows_bottleneck() {
        let mut p = Plan::new();
        let dram = p.add_resource("dram");
        let comp = p.add_resource("compute");
        // long load gates a short compute: critical path is mostly load
        let mut load_spec = spec(Some(dram), 10.0, &[], 0);
        load_spec.tag = Tag::WeightStream;
        let l = p.add_task(load_spec);
        let mut comp_spec = spec(Some(comp), 1.0, &[l], 0);
        comp_spec.tag = Tag::MoeCompute;
        p.add_task(comp_spec);
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 11.0);
        assert_eq!(res.critical_time(Tag::WeightStream), 10.0);
        assert_eq!(res.critical_time(Tag::MoeCompute), 1.0);
    }

    #[test]
    fn resource_wait_appears_in_critical_path() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let a = p.add_task(spec(Some(r), 4.0, &[], -1));
        let b = p.add_task(spec(Some(r), 1.0, &[], 0));
        let res = Simulator::run(&p);
        // b waits for a on the resource; critical path includes both
        assert_eq!(res.makespan, 5.0);
        assert_eq!(res.finish[b], 5.0);
        assert_eq!(res.start[b], res.finish[a]);
    }

    #[test]
    fn empty_plan() {
        let p = Plan::new();
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 0.0);
    }

    #[test]
    fn busy_times_by_tag() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let mut s1 = spec(Some(r), 2.0, &[], 0);
        s1.tag = Tag::A2aDispatch;
        s1.bytes = 100.0;
        p.add_task(s1);
        let mut s2 = spec(Some(r), 3.0, &[], 0);
        s2.tag = Tag::A2aDispatch;
        s2.bytes = 50.0;
        p.add_task(s2);
        let res = Simulator::run(&p);
        assert_eq!(res.tag_time(Tag::A2aDispatch), 5.0);
        assert_eq!(res.bytes(Tag::A2aDispatch), 150.0);
    }
}
