//! Event-driven scheduling engine over a task-DAG plan.
//!
//! Tasks become *ready* when all dependencies finish; a pluggable
//! [`Scheduler`] policy (see [`super::sched`]) picks which ready task to
//! dispatch next, and the engine resolves its start time against the
//! sequential resource model (start = max(ready, resource free)). The
//! default [`SchedPolicy::Streaming`] policy serves ready tasks in
//! (ready-time, priority, id) order — byte-for-byte the engine's
//! historical baked-in behavior. The engine records start/finish per task,
//! per-tag and per-resource busy time, the makespan, and the critical path
//! (the chain of dependency/resource waits that determined the final
//! finish time).
//!
//! In debug builds every run additionally records a [`ScheduleTrace`] and
//! feeds it through the schedule-validity oracle
//! ([`ScheduleTrace::validate`]); release builds skip both.
//!
//! Hot-path design (sweeps run this tens of thousands of times):
//! - per-tag accounting is a dense [`TagBreakdown`] indexed by
//!   [`Tag::index`] — O(1) per task instead of an O(|Tag|) find-scan;
//! - float orderings use `f64::total_cmp`, so a NaN duration can never
//!   panic mid-run (NaNs are rejected loudly by [`Plan::validate`]);
//! - all per-run working memory (in-degrees, the CSR dependent adjacency,
//!   ready times, the streaming ready heap, resource state) lives in a
//!   reusable [`SimScratch`], so repeated [`Simulator::run_with`] calls
//!   allocate only the `start`/`finish`/`resource_busy` vectors they
//!   return (the streaming policy borrows the scratch's persistent heap).

use std::collections::BinaryHeap;

use super::plan::{Plan, Tag, TagBreakdown, TaskId};
use super::sched::{
    Entry, GreedySched, HeftSched, ListSched, ReplaySched, SchedPolicy, ScheduleTrace, Scheduler,
    StreamingSched,
};

/// What determined a task's start time (for critical-path extraction).
#[derive(Clone, Copy, Debug)]
enum StartCause {
    /// No wait: started at its ready time with the resource idle.
    Dep(TaskId),
    /// Waited for the resource; the blocking task is recorded.
    Resource(TaskId),
    /// Source task (no deps, no wait).
    Source,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end schedule length (seconds).
    pub makespan: f64,
    /// Start time of each task.
    pub start: Vec<f64>,
    /// Finish time of each task.
    pub finish: Vec<f64>,
    /// Busy seconds per tag (sum of task durations).
    pub tag_busy: TagBreakdown,
    /// Busy seconds per resource.
    pub resource_busy: Vec<f64>,
    /// Seconds of the critical path attributed to each tag.
    pub critical_path: TagBreakdown,
    /// Total bytes and flops (energy accounting inputs) per tag.
    pub tag_bytes: TagBreakdown,
    /// Total FLOPs executed per tag.
    pub tag_flops: TagBreakdown,
}

impl SimResult {
    /// Busy seconds of `tag`.
    pub fn tag_time(&self, tag: Tag) -> f64 {
        self.tag_busy.get(tag)
    }

    /// Critical-path seconds attributed to `tag`.
    pub fn critical_time(&self, tag: Tag) -> f64 {
        self.critical_path.get(tag)
    }

    /// Bytes moved by tasks of `tag`.
    pub fn bytes(&self, tag: Tag) -> f64 {
        self.tag_bytes.get(tag)
    }

    /// FLOPs executed by tasks of `tag`.
    pub fn flops(&self, tag: Tag) -> f64 {
        self.tag_flops.get(tag)
    }

    /// Utilization of a resource relative to the makespan.
    pub fn utilization(&self, resource: usize) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.resource_busy[resource] / self.makespan
        }
    }
}

/// Reusable working memory for [`Simulator::run_with`]. One scratch serves
/// any number of sequential runs over plans of any size; buffers grow to
/// the high-water mark and stay allocated.
#[derive(Default)]
pub struct SimScratch {
    indeg: Vec<usize>,
    /// CSR adjacency of the reverse dependency graph: task i's dependents
    /// are `dep_edges[dep_heads[i]..dep_heads[i + 1]]`.
    dep_heads: Vec<usize>,
    dep_edges: Vec<TaskId>,
    cursor: Vec<usize>,
    ready_time: Vec<f64>,
    last_dep: Vec<Option<TaskId>>,
    heap: BinaryHeap<Entry>,
    res_free: Vec<f64>,
    res_last: Vec<Option<TaskId>>,
    cause: Vec<StartCause>,
}

impl SimScratch {
    /// Fresh (empty) scratch; buffers grow on first use.
    pub fn new() -> SimScratch {
        SimScratch::default()
    }

    /// Resize-and-reset every buffer for a plan with `n` tasks and `nres`
    /// resources, retaining capacity.
    fn reset(&mut self, n: usize, nres: usize) {
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.dep_heads.clear();
        self.dep_heads.resize(n + 1, 0);
        self.cursor.clear();
        self.cursor.resize(n, 0);
        self.ready_time.clear();
        self.ready_time.resize(n, 0.0);
        self.last_dep.clear();
        self.last_dep.resize(n, None);
        self.cause.clear();
        self.cause.resize(n, StartCause::Source);
        self.res_free.clear();
        self.res_free.resize(nres, 0.0);
        self.res_last.clear();
        self.res_last.resize(nres, None);
        self.heap.clear();
        self.dep_edges.clear();
    }
}

/// The engine. Stateless; `run` consumes a plan reference.
pub struct Simulator;

impl Simulator {
    /// Execute the plan under the default streaming policy, returning
    /// timing and accounting. Convenience wrapper over
    /// [`Simulator::run_with`] with throwaway scratch.
    pub fn run(plan: &Plan) -> SimResult {
        Simulator::run_with(plan, &mut SimScratch::new())
    }

    /// Execute the plan under the default streaming policy using
    /// caller-provided scratch buffers. Results are identical to
    /// [`Simulator::run`]; repeated calls avoid re-allocating the engine's
    /// working memory.
    pub fn run_with(plan: &Plan, scratch: &mut SimScratch) -> SimResult {
        Simulator::run_policy(plan, SchedPolicy::Streaming, 0, scratch)
    }

    /// Execute the plan under `policy` with tie-break seed `seed` (ignored
    /// by `streaming` and `list`; see [`super::sched`] for the documented
    /// tie orders). `SchedPolicy::Streaming` is bit-identical to
    /// [`Simulator::run_with`]. In debug builds the run is traced and the
    /// schedule-validity oracle panics on any violated invariant.
    pub fn run_policy(
        plan: &Plan,
        policy: SchedPolicy,
        seed: u64,
        scratch: &mut SimScratch,
    ) -> SimResult {
        #[cfg(debug_assertions)]
        {
            let (res, trace) = Simulator::run_policy_traced(plan, policy, seed, scratch);
            if let Err(e) = trace.validate(plan) {
                panic!(
                    "schedule-validity oracle rejected a {} schedule: {e}",
                    policy.name()
                );
            }
            res
        }
        #[cfg(not(debug_assertions))]
        Simulator::dispatch(plan, policy, seed, scratch, None)
    }

    /// Execute the plan under `policy` and return the explicit
    /// [`ScheduleTrace`] alongside the result (always recorded, in every
    /// build). The trace can be validated with [`ScheduleTrace::validate`]
    /// and replayed with [`Simulator::replay`].
    pub fn run_policy_traced(
        plan: &Plan,
        policy: SchedPolicy,
        seed: u64,
        scratch: &mut SimScratch,
    ) -> (SimResult, ScheduleTrace) {
        let mut trace = ScheduleTrace::default();
        let res = Simulator::dispatch(plan, policy, seed, scratch, Some(&mut trace));
        (res, trace)
    }

    /// Re-execute a recorded trace's dispatch order through the engine.
    /// For any trace produced by [`Simulator::run_policy_traced`] on the
    /// same plan, the replayed result is bit-identical to the original run
    /// (the dispatch order fully determines the schedule).
    pub fn replay(plan: &Plan, trace: &ScheduleTrace, scratch: &mut SimScratch) -> SimResult {
        let mut sched = ReplaySched::new(&trace.order);
        Simulator::run_core(plan, &mut sched, scratch, None)
    }

    /// Execute the plan under a caller-supplied [`Scheduler`]
    /// implementation (the extension point for scheduling research beyond
    /// the built-in [`SchedPolicy`] set).
    pub fn run_sched<S: Scheduler + ?Sized>(
        plan: &Plan,
        sched: &mut S,
        scratch: &mut SimScratch,
    ) -> SimResult {
        Simulator::run_core(plan, sched, scratch, None)
    }

    /// Policy dispatch: monomorphize the core per built-in policy. The
    /// streaming policy borrows the scratch's persistent heap so the hot
    /// default path stays allocation-free.
    fn dispatch(
        plan: &Plan,
        policy: SchedPolicy,
        seed: u64,
        scratch: &mut SimScratch,
        trace: Option<&mut ScheduleTrace>,
    ) -> SimResult {
        match policy {
            SchedPolicy::Streaming => {
                let mut s = StreamingSched::with_heap(std::mem::take(&mut scratch.heap));
                let res = Simulator::run_core(plan, &mut s, scratch, trace);
                scratch.heap = s.into_heap();
                res
            }
            SchedPolicy::List => {
                Simulator::run_core(plan, &mut ListSched::new(), scratch, trace)
            }
            SchedPolicy::Heft => {
                Simulator::run_core(plan, &mut HeftSched::new(seed), scratch, trace)
            }
            SchedPolicy::Greedy => {
                Simulator::run_core(plan, &mut GreedySched::new(seed), scratch, trace)
            }
        }
    }

    /// The engine core, generic over the scheduling policy. The dispatch
    /// loop is byte-for-byte the historical engine with the heap pop/push
    /// replaced by `sched.next_task` / `sched.task_ready` callbacks.
    fn run_core<S: Scheduler + ?Sized>(
        plan: &Plan,
        sched: &mut S,
        scratch: &mut SimScratch,
        mut trace: Option<&mut ScheduleTrace>,
    ) -> SimResult {
        let n = plan.tasks.len();
        let nres = plan.resource_names.len();
        scratch.reset(n, nres);
        if let Some(tr) = trace.as_deref_mut() {
            tr.reset(n);
        }

        // reverse dependency graph as CSR: count, prefix-sum, fill. The
        // `indeg` buffer doubles as the dependent counter during the first
        // pass and is rebuilt as the true in-degree in the fill pass.
        let total_deps: usize = plan.tasks.iter().map(|t| t.deps.len()).sum();
        scratch.dep_edges.resize(total_deps, 0);
        for t in plan.tasks.iter() {
            for &d in &t.deps {
                scratch.indeg[d] += 1;
            }
        }
        let mut acc = 0usize;
        for i in 0..n {
            scratch.dep_heads[i] = acc;
            scratch.cursor[i] = acc;
            acc += scratch.indeg[i];
        }
        scratch.dep_heads[n] = acc;
        for (i, t) in plan.tasks.iter().enumerate() {
            for &d in &t.deps {
                scratch.dep_edges[scratch.cursor[d]] = i;
                scratch.cursor[d] += 1;
            }
            scratch.indeg[i] = t.deps.len();
        }

        sched.prepare(plan);
        for (i, t) in plan.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                sched.task_ready(i, 0.0, plan);
            }
        }

        let mut res_busy = vec![0.0f64; nres];
        let mut start = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut done = 0usize;

        while let Some(i) = sched.next_task(plan, &scratch.res_free) {
            debug_assert_eq!(
                scratch.indeg[i], 0,
                "scheduler dispatched task {i} before its dependencies finished"
            );
            let t = &plan.tasks[i];
            let ready = scratch.ready_time[i];
            let (s, c) = match t.resource {
                Some(r) => {
                    if scratch.res_free[r] > ready {
                        (
                            scratch.res_free[r],
                            StartCause::Resource(scratch.res_last[r].unwrap()),
                        )
                    } else {
                        match scratch.last_dep[i] {
                            Some(d) => (ready, StartCause::Dep(d)),
                            None => (ready, StartCause::Source),
                        }
                    }
                }
                None => match scratch.last_dep[i] {
                    Some(d) => (ready, StartCause::Dep(d)),
                    None => (ready, StartCause::Source),
                },
            };
            let f = s + t.duration;
            start[i] = s;
            finish[i] = f;
            scratch.cause[i] = c;
            if let Some(r) = t.resource {
                scratch.res_free[r] = f;
                scratch.res_last[r] = Some(i);
                res_busy[r] += t.duration;
            }
            if let Some(tr) = trace.as_deref_mut() {
                tr.record(i, t.resource, s, f);
            }
            sched.task_complete(i, f, plan);
            done += 1;
            for k in scratch.dep_heads[i]..scratch.dep_heads[i + 1] {
                let j = scratch.dep_edges[k];
                if f > scratch.ready_time[j] {
                    scratch.ready_time[j] = f;
                    scratch.last_dep[j] = Some(i);
                }
                scratch.indeg[j] -= 1;
                if scratch.indeg[j] == 0 {
                    sched.task_ready(j, scratch.ready_time[j], plan);
                }
            }
        }
        assert_eq!(done, n, "plan contains a cycle (validate() first)");

        let makespan = finish.iter().cloned().fold(0.0f64, f64::max);
        if let Some(tr) = trace.as_deref_mut() {
            tr.makespan = makespan;
        }

        // per-tag accounting: O(1) dense-array adds
        let mut tag_busy = TagBreakdown::zero();
        let mut tag_bytes = TagBreakdown::zero();
        let mut tag_flops = TagBreakdown::zero();
        for t in &plan.tasks {
            tag_busy.add(t.tag, t.duration);
            tag_bytes.add(t.tag, t.bytes);
            tag_flops.add(t.tag, t.flops);
        }

        // critical path: walk back from the last-finishing task
        let mut critical = TagBreakdown::zero();
        if n > 0 {
            let mut cur = (0..n)
                .max_by(|&a, &b| finish[a].total_cmp(&finish[b]))
                .unwrap();
            loop {
                critical.add(plan.tasks[cur].tag, plan.tasks[cur].duration);
                match scratch.cause[cur] {
                    StartCause::Source => break,
                    StartCause::Dep(d) => cur = d,
                    StartCause::Resource(p) => cur = p,
                }
            }
        }

        SimResult {
            makespan,
            start,
            finish,
            tag_busy,
            resource_busy: res_busy,
            critical_path: critical,
            tag_bytes,
            tag_flops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::{Plan, Tag, TaskSpec};

    fn spec(resource: Option<usize>, duration: f64, deps: &[usize], priority: i64) -> TaskSpec {
        TaskSpec {
            resource,
            duration,
            deps: deps.to_vec(),
            priority,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        }
    }

    #[test]
    fn chain_accumulates() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let a = p.add_task(spec(Some(r), 1.0, &[], 0));
        let b = p.add_task(spec(Some(r), 2.0, &[a], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.finish[b], 3.0);
        assert_eq!(res.makespan, 3.0);
        assert_eq!(res.utilization(r), 1.0);
    }

    #[test]
    fn independent_tasks_on_distinct_resources_overlap() {
        let mut p = Plan::new();
        let r1 = p.add_resource("r1");
        let r2 = p.add_resource("r2");
        p.add_task(spec(Some(r1), 3.0, &[], 0));
        p.add_task(spec(Some(r2), 2.0, &[], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 3.0);
    }

    #[test]
    fn same_resource_serializes() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        p.add_task(spec(Some(r), 3.0, &[], 0));
        p.add_task(spec(Some(r), 2.0, &[], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 5.0);
    }

    #[test]
    fn priority_orders_contenders() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let lo = p.add_task(spec(Some(r), 1.0, &[], 10));
        let hi = p.add_task(spec(Some(r), 1.0, &[], -10));
        let res = Simulator::run(&p);
        assert!(res.start[hi] < res.start[lo]);
    }

    #[test]
    fn diamond_dependencies() {
        let mut p = Plan::new();
        let r1 = p.add_resource("r1");
        let r2 = p.add_resource("r2");
        let a = p.add_task(spec(Some(r1), 1.0, &[], 0));
        let b = p.add_task(spec(Some(r1), 2.0, &[a], 0));
        let c = p.add_task(spec(Some(r2), 5.0, &[a], 0));
        let d = p.add_task(spec(None, 0.0, &[b, c], 0));
        let res = Simulator::run(&p);
        assert_eq!(res.finish[d], 6.0); // gated by the longer branch
        assert_eq!(res.makespan, 6.0);
    }

    #[test]
    fn critical_path_follows_bottleneck() {
        let mut p = Plan::new();
        let dram = p.add_resource("dram");
        let comp = p.add_resource("compute");
        // long load gates a short compute: critical path is mostly load
        let mut load_spec = spec(Some(dram), 10.0, &[], 0);
        load_spec.tag = Tag::WeightStream;
        let l = p.add_task(load_spec);
        let mut comp_spec = spec(Some(comp), 1.0, &[l], 0);
        comp_spec.tag = Tag::MoeCompute;
        p.add_task(comp_spec);
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 11.0);
        assert_eq!(res.critical_time(Tag::WeightStream), 10.0);
        assert_eq!(res.critical_time(Tag::MoeCompute), 1.0);
    }

    #[test]
    fn resource_wait_appears_in_critical_path() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let a = p.add_task(spec(Some(r), 4.0, &[], -1));
        let b = p.add_task(spec(Some(r), 1.0, &[], 0));
        let res = Simulator::run(&p);
        // b waits for a on the resource; critical path includes both
        assert_eq!(res.makespan, 5.0);
        assert_eq!(res.finish[b], 5.0);
        assert_eq!(res.start[b], res.finish[a]);
    }

    #[test]
    fn empty_plan() {
        let p = Plan::new();
        let res = Simulator::run(&p);
        assert_eq!(res.makespan, 0.0);
    }

    #[test]
    fn busy_times_by_tag() {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let mut s1 = spec(Some(r), 2.0, &[], 0);
        s1.tag = Tag::A2aDispatch;
        s1.bytes = 100.0;
        p.add_task(s1);
        let mut s2 = spec(Some(r), 3.0, &[], 0);
        s2.tag = Tag::A2aDispatch;
        s2.bytes = 50.0;
        p.add_task(s2);
        let res = Simulator::run(&p);
        assert_eq!(res.tag_time(Tag::A2aDispatch), 5.0);
        assert_eq!(res.bytes(Tag::A2aDispatch), 150.0);
    }

    /// Scratch reuse across plans of different shapes must not leak state.
    #[test]
    fn scratch_reuse_is_equivalent_to_fresh() {
        let mut scratch = SimScratch::new();

        let mut big = Plan::new();
        let r1 = big.add_resource("r1");
        let r2 = big.add_resource("r2");
        let a = big.add_task(spec(Some(r1), 1.5, &[], 0));
        let b = big.add_task(spec(Some(r2), 2.5, &[a], 1));
        let c = big.add_task(spec(Some(r1), 0.5, &[a], -1));
        big.add_task(spec(None, 0.0, &[b, c], 0));

        let mut small = Plan::new();
        let r = small.add_resource("only");
        small.add_task(spec(Some(r), 3.0, &[], 0));
        small.add_task(spec(Some(r), 2.0, &[0], 0));

        for plan in [&big, &small, &big, &small, &big] {
            let fresh = Simulator::run(plan);
            let reused = Simulator::run_with(plan, &mut scratch);
            assert_eq!(fresh.makespan, reused.makespan);
            assert_eq!(fresh.start, reused.start);
            assert_eq!(fresh.finish, reused.finish);
            assert_eq!(fresh.tag_busy, reused.tag_busy);
            assert_eq!(fresh.critical_path, reused.critical_path);
            assert_eq!(fresh.resource_busy, reused.resource_busy);
        }
    }
}
