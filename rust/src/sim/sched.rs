//! Pluggable DAG scheduling policies and the schedule-validity oracle.
//!
//! The engine in [`super::engine`] resolves *when* a dispatched task runs
//! (start = max(ready time, resource free time)); a [`Scheduler`] decides
//! *which* ready task is dispatched next. Extracting that decision into a
//! trait (in the spirit of dslab-dag's callback-driven design: the engine
//! calls back on task-ready / task-complete and asks for the next task over
//! the shared resource model) turns scheduling into an ablatable policy
//! dimension — "good allocation" and "good scheduling" can finally be
//! separated, which is the axis the paper's fine-grained streaming schedule
//! argues matters.
//!
//! Four interchangeable, bit-reproducible policies ship:
//!
//! - [`SchedPolicy::Streaming`] — the paper's schedule and the default:
//!   ready tasks are served in (ready-time, priority, id) order, where the
//!   plan builder's priorities stream hot expert clusters first. This is
//!   byte-for-byte the engine's historical baked-in behavior.
//! - [`SchedPolicy::List`] — plain FIFO list scheduling: tasks dispatch in
//!   the order they became ready (sources in id order, then dependents in
//!   completion-propagation order). No priorities, no look-ahead.
//! - [`SchedPolicy::Heft`] — HEFT-style upward-rank priority: tasks with
//!   the longest remaining dependent chain (rank = duration + max dependent
//!   rank) dispatch first.
//! - [`SchedPolicy::Greedy`] — work-conserving earliest-estimated-finish:
//!   among ready tasks, dispatch the one that would finish soonest given
//!   the current resource free times (lazily re-sorted as resources drain).
//!
//! **Tie-breaking is seeded and documented** so every policy is
//! bit-reproducible: `streaming` breaks ties by (priority, id) and ignores
//! the seed; `list` has no ties (FIFO); `heft` and `greedy` break equal
//! priorities by `mix64(seed ^ id * GOLDEN)` then id. The same seed always
//! produces the same schedule, on any thread count, because scheduling runs
//! entirely inside one engine call.
//!
//! Every engine run in a debug build records a [`ScheduleTrace`] and feeds
//! it to the **schedule-validity oracle** [`ScheduleTrace::validate`]: no
//! task starts before its dependencies finish, no two tasks overlap on a
//! sequential resource, every task is placed exactly once, starts are tight
//! (work-conserving given the dispatch order), and the recorded makespan
//! equals the critical path through the trace-induced graph. Release
//! builds skip the oracle; tests run it against every policy on every
//! Table 2/3 cell (`tests/integration_sched.rs`).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::plan::{Plan, ResourceId, TaskId};

/// Which scheduling policy the engine dispatches ready tasks with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchedPolicy {
    /// The paper's streaming schedule (default): (ready, priority, id)
    /// min-order — bit-identical to the historical engine.
    Streaming,
    /// FIFO list scheduling in ready-event order.
    List,
    /// HEFT-style upward-rank priority (longest remaining chain first).
    Heft,
    /// Work-conserving earliest-estimated-finish.
    Greedy,
}

impl SchedPolicy {
    /// Every policy, in declaration order (CLI/report ordering).
    pub const ALL: [SchedPolicy; 4] = [
        SchedPolicy::Streaming,
        SchedPolicy::List,
        SchedPolicy::Heft,
        SchedPolicy::Greedy,
    ];

    /// Stable dense index (declaration order, matching [`SchedPolicy::ALL`]).
    pub const fn index(self) -> usize {
        self as usize
    }

    /// CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Streaming => "streaming",
            SchedPolicy::List => "list",
            SchedPolicy::Heft => "heft",
            SchedPolicy::Greedy => "greedy",
        }
    }

    /// Parse a single policy name (as passed to `--sched`).
    pub fn from_name(s: &str) -> Option<SchedPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "streaming" => Some(SchedPolicy::Streaming),
            "list" => Some(SchedPolicy::List),
            "heft" => Some(SchedPolicy::Heft),
            "greedy" => Some(SchedPolicy::Greedy),
            _ => None,
        }
    }

    /// Parse a `--scheds` list: comma-separated names or `all`, deduplicated
    /// preserving first-occurrence order (mirrors `Method::parse_list`).
    pub fn parse_list(s: &str) -> Result<Vec<SchedPolicy>, String> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("all") {
            return Ok(SchedPolicy::ALL.to_vec());
        }
        let mut out = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let p = SchedPolicy::from_name(part)
                .ok_or_else(|| format!("unknown scheduler `{part}` (streaming|list|heft|greedy|all)"))?;
            if !out.contains(&p) {
                out.push(p);
            }
        }
        if out.is_empty() {
            return Err("no schedulers given".to_string());
        }
        Ok(out)
    }
}

/// SplitMix64 finalizer — the documented seeded tie-break hash. `heft` and
/// `greedy` order equal-priority ready tasks by `tie_key(seed, id)` then
/// `id`, so a schedule is a pure function of (plan, policy, seed).
pub(crate) fn tie_key(seed: u64, id: TaskId) -> u64 {
    let mut z = seed
        ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Callback interface between the engine and a scheduling policy.
///
/// The engine owns the resource model and the clock: it computes start =
/// max(ready, resource-free) for whatever task the policy picks, so any
/// policy produces a *valid* schedule by construction (the oracle proves
/// it). The policy only chooses the dispatch order:
///
/// 1. [`Scheduler::prepare`] — once per run, before any dispatch (build
///    ranks, size buffers).
/// 2. [`Scheduler::task_ready`] — `id` has all dependencies finished and
///    may be dispatched from now on; `ready` is its final ready time.
/// 3. [`Scheduler::next_task`] — pick the next ready task to dispatch,
///    given the current per-resource free times. `None` ends the run.
/// 4. [`Scheduler::task_complete`] — `id` was dispatched and assigned its
///    finish time (bookkeeping hook; none of the built-ins need it).
pub trait Scheduler {
    /// Called once per run before any `task_ready`, with the full plan.
    fn prepare(&mut self, _plan: &Plan) {}

    /// Task `id` became ready at time `ready` (all dependencies finished).
    fn task_ready(&mut self, id: TaskId, ready: f64, plan: &Plan);

    /// Pick the next ready task to dispatch. `res_free[r]` is the time
    /// resource `r` becomes free. Returning `None` means no ready tasks
    /// remain (the run is complete, or the plan has a cycle — the engine
    /// checks which).
    fn next_task(&mut self, plan: &Plan, res_free: &[f64]) -> Option<TaskId>;

    /// Task `id` was dispatched and will finish at `finish`.
    fn task_complete(&mut self, _id: TaskId, _finish: f64, _plan: &Plan) {}
}

/// Heap entry of the streaming policy: min-heap by (ready, priority, id).
/// Lives here (not in `engine`) so the policy and the scratch buffer share
/// one definition.
#[derive(PartialEq)]
pub(crate) struct Entry {
    pub(crate) ready: f64,
    pub(crate) priority: i64,
    pub(crate) id: TaskId,
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reverse for min-heap; total_cmp matches partial_cmp on the
        // non-NaN, non-negative times the engine produces
        other
            .ready
            .total_cmp(&self.ready)
            .then(other.priority.cmp(&self.priority))
            .then(other.id.cmp(&self.id))
    }
}

/// The paper's streaming schedule: ready tasks served in (ready-time,
/// priority, id) min-order. Ties break by plan priority then task id —
/// no seed involved — so this is byte-for-byte the engine's historical
/// behavior and the default policy.
#[derive(Default)]
pub struct StreamingSched {
    heap: BinaryHeap<Entry>,
}

impl StreamingSched {
    /// Fresh policy with an empty ready heap.
    pub fn new() -> StreamingSched {
        StreamingSched::default()
    }

    /// Wrap a caller-owned heap (the engine lends `SimScratch`'s persistent
    /// heap so the hot streaming path stays allocation-free).
    pub(crate) fn with_heap(heap: BinaryHeap<Entry>) -> StreamingSched {
        StreamingSched { heap }
    }

    /// Hand the (now empty) heap back for reuse.
    pub(crate) fn into_heap(self) -> BinaryHeap<Entry> {
        self.heap
    }
}

impl Scheduler for StreamingSched {
    fn prepare(&mut self, _plan: &Plan) {
        self.heap.clear();
    }

    fn task_ready(&mut self, id: TaskId, ready: f64, plan: &Plan) {
        self.heap.push(Entry {
            ready,
            priority: plan.tasks[id].priority,
            id,
        });
    }

    fn next_task(&mut self, _plan: &Plan, _res_free: &[f64]) -> Option<TaskId> {
        self.heap.pop().map(|e| e.id)
    }
}

/// FIFO list scheduling: dispatch in ready-event order. Sources enqueue in
/// id order; dependents enqueue in the engine's (deterministic) completion-
/// propagation order. There are no ties to break.
#[derive(Default)]
pub struct ListSched {
    queue: VecDeque<TaskId>,
}

impl ListSched {
    /// Fresh policy with an empty ready queue.
    pub fn new() -> ListSched {
        ListSched::default()
    }
}

impl Scheduler for ListSched {
    fn prepare(&mut self, _plan: &Plan) {
        self.queue.clear();
    }

    fn task_ready(&mut self, id: TaskId, _ready: f64, _plan: &Plan) {
        self.queue.push_back(id);
    }

    fn next_task(&mut self, _plan: &Plan, _res_free: &[f64]) -> Option<TaskId> {
        self.queue.pop_front()
    }
}

/// Max-heap entry for [`HeftSched`]: highest rank first, then the seeded
/// tie key ascending, then id ascending.
#[derive(PartialEq)]
struct HeftEntry {
    rank: f64,
    tie: u64,
    id: TaskId,
}

impl Eq for HeftEntry {}

impl PartialOrd for HeftEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeftEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.rank
            .total_cmp(&other.rank)
            .then(other.tie.cmp(&self.tie))
            .then(other.id.cmp(&self.id))
    }
}

/// HEFT-style upward-rank list scheduling: `rank(i) = duration(i) + max`
/// rank over dependents (0 for sinks), computed once per run over a Kahn
/// topological order (the plan builder patches *forward* dependency edges
/// into baseline plans, so reverse-id iteration would be wrong). Ready
/// tasks dispatch by descending rank; ties break by `tie_key(seed, id)`
/// then id.
pub struct HeftSched {
    seed: u64,
    rank: Vec<f64>,
    heap: BinaryHeap<HeftEntry>,
}

impl HeftSched {
    /// Policy with the given tie-break seed.
    pub fn new(seed: u64) -> HeftSched {
        HeftSched {
            seed,
            rank: Vec::new(),
            heap: BinaryHeap::new(),
        }
    }
}

/// Upward ranks over any topological order (Kahn). Public to the crate so
/// tests can cross-check the policy's priorities.
pub(crate) fn upward_ranks(plan: &Plan) -> Vec<f64> {
    let n = plan.tasks.len();
    let mut out: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    let mut indeg = vec![0usize; n];
    for (i, t) in plan.tasks.iter().enumerate() {
        indeg[i] = t.deps.len();
        for &d in &t.deps {
            out[d].push(i);
        }
    }
    let mut queue: VecDeque<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut topo: Vec<TaskId> = Vec::with_capacity(n);
    while let Some(i) = queue.pop_front() {
        topo.push(i);
        for &j in &out[i] {
            indeg[j] -= 1;
            if indeg[j] == 0 {
                queue.push_back(j);
            }
        }
    }
    debug_assert_eq!(topo.len(), n, "plan contains a cycle (validate() first)");
    let mut rank = vec![0.0f64; n];
    for &i in topo.iter().rev() {
        let mut best = 0.0f64;
        for &j in &out[i] {
            if rank[j] > best {
                best = rank[j];
            }
        }
        rank[i] = best + plan.tasks[i].duration;
    }
    rank
}

impl Scheduler for HeftSched {
    fn prepare(&mut self, plan: &Plan) {
        self.rank = upward_ranks(plan);
        self.heap.clear();
    }

    fn task_ready(&mut self, id: TaskId, _ready: f64, _plan: &Plan) {
        self.heap.push(HeftEntry {
            rank: self.rank[id],
            tie: tie_key(self.seed, id),
            id,
        });
    }

    fn next_task(&mut self, _plan: &Plan, _res_free: &[f64]) -> Option<TaskId> {
        self.heap.pop().map(|e| e.id)
    }
}

/// Min-heap entry for [`GreedySched`]: earliest estimated finish first,
/// then the seeded tie key, then id (Ord reversed for `BinaryHeap`).
#[derive(PartialEq)]
struct GreedyEntry {
    est: f64,
    tie: u64,
    id: TaskId,
}

impl Eq for GreedyEntry {}

impl PartialOrd for GreedyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for GreedyEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .est
            .total_cmp(&self.est)
            .then(other.tie.cmp(&self.tie))
            .then(other.id.cmp(&self.id))
    }
}

/// Work-conserving earliest-estimated-finish: among ready tasks, dispatch
/// the one with the smallest `max(ready, res_free[r]) + duration`. The
/// heap is lazily repaired: entries are pushed with the estimate at
/// ready-time (a lower bound, since resource free times only grow) and
/// re-pushed with the refreshed estimate when popped stale; a popped entry
/// whose estimate is current dispatches. Within one `next_task` call the
/// free times are fixed, so every entry is re-pushed at most once and the
/// loop terminates. Ties break by `tie_key(seed, id)` then id.
pub struct GreedySched {
    seed: u64,
    ready: Vec<f64>,
    heap: BinaryHeap<GreedyEntry>,
}

impl GreedySched {
    /// Policy with the given tie-break seed.
    pub fn new(seed: u64) -> GreedySched {
        GreedySched {
            seed,
            ready: Vec::new(),
            heap: BinaryHeap::new(),
        }
    }

    fn estimate(&self, id: TaskId, plan: &Plan, res_free: &[f64]) -> f64 {
        let t = &plan.tasks[id];
        let ready = self.ready[id];
        let start = match t.resource {
            Some(r) if res_free[r] > ready => res_free[r],
            _ => ready,
        };
        start + t.duration
    }
}

impl Scheduler for GreedySched {
    fn prepare(&mut self, plan: &Plan) {
        self.ready.clear();
        self.ready.resize(plan.tasks.len(), 0.0);
        self.heap.clear();
    }

    fn task_ready(&mut self, id: TaskId, ready: f64, plan: &Plan) {
        self.ready[id] = ready;
        self.heap.push(GreedyEntry {
            est: ready + plan.tasks[id].duration,
            tie: tie_key(self.seed, id),
            id,
        });
    }

    fn next_task(&mut self, plan: &Plan, res_free: &[f64]) -> Option<TaskId> {
        loop {
            let e = self.heap.pop()?;
            let cur = self.estimate(e.id, plan, res_free);
            if cur > e.est {
                self.heap.push(GreedyEntry {
                    est: cur,
                    tie: e.tie,
                    id: e.id,
                });
            } else {
                return Some(e.id);
            }
        }
    }
}

/// Replays a fixed dispatch order (the `order` of a recorded
/// [`ScheduleTrace`]) through the engine. Used by `Simulator::replay` to
/// prove a trace round-trips to the exact same timings.
pub(crate) struct ReplaySched<'a> {
    order: &'a [TaskId],
    cursor: usize,
}

impl<'a> ReplaySched<'a> {
    pub(crate) fn new(order: &'a [TaskId]) -> ReplaySched<'a> {
        ReplaySched { order, cursor: 0 }
    }
}

impl Scheduler for ReplaySched<'_> {
    fn task_ready(&mut self, _id: TaskId, _ready: f64, _plan: &Plan) {}

    fn next_task(&mut self, _plan: &Plan, _res_free: &[f64]) -> Option<TaskId> {
        let i = *self.order.get(self.cursor)?;
        self.cursor += 1;
        Some(i)
    }
}

/// Where one task sat in a schedule: its resource binding (copied from the
/// plan and cross-checked by the oracle) and its start/finish times.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TaskSlot {
    /// Sequential resource the task occupied (None = pure dependency node).
    pub resource: Option<ResourceId>,
    /// Assigned start time (seconds).
    pub start: f64,
    /// Assigned finish time (start + duration).
    pub finish: f64,
}

/// Explicit record of one engine run: per-task placement slots, the
/// dispatch order the policy chose, and the resulting makespan.
/// [`ScheduleTrace::validate`] is the schedule-validity oracle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScheduleTrace {
    /// Per-task `{resource, start, finish}`, indexed by `TaskId`.
    pub slots: Vec<TaskSlot>,
    /// Task ids in dispatch order (the policy's decisions, verbatim).
    pub order: Vec<TaskId>,
    /// Recorded end-to-end schedule length.
    pub makespan: f64,
}

impl ScheduleTrace {
    /// Size for a plan with `n` tasks and clear any previous recording.
    pub(crate) fn reset(&mut self, n: usize) {
        self.slots.clear();
        self.slots.resize(n, TaskSlot::default());
        self.order.clear();
        self.makespan = 0.0;
    }

    /// Record one dispatch.
    pub(crate) fn record(&mut self, id: TaskId, resource: Option<ResourceId>, start: f64, finish: f64) {
        self.slots[id] = TaskSlot {
            resource,
            start,
            finish,
        };
        self.order.push(id);
    }

    /// The schedule-validity oracle. Checks, in order:
    ///
    /// 1. **placement** — every task of `plan` is dispatched exactly once,
    ///    on the resource the plan binds it to;
    /// 2. **dependency precedence** — no task starts before every
    ///    dependency has finished;
    /// 3. **resource exclusivity** — tasks sharing a sequential resource
    ///    never overlap (each starts at or after the previous occupant's
    ///    finish, in dispatch order);
    /// 4. **tightness** — every start equals max(ready time, resource free
    ///    time): the engine is work-conserving given the dispatch order, so
    ///    a slack start means the trace was not produced by this engine;
    /// 5. **makespan = critical path** — the recorded makespan equals both
    ///    the max finish time and an independently recomputed longest path
    ///    through the trace-induced graph (dependency edges plus
    ///    resource-succession edges).
    ///
    /// All comparisons are exact (`f64` equality): the engine assigns times
    /// by copying and single additions, so a valid trace reproduces them
    /// bit-for-bit.
    pub fn validate(&self, plan: &Plan) -> anyhow::Result<()> {
        let n = plan.tasks.len();
        let nres = plan.resource_names.len();
        anyhow::ensure!(
            self.slots.len() == n,
            "trace has {} slots for a {}-task plan",
            self.slots.len(),
            n
        );
        anyhow::ensure!(
            self.order.len() == n,
            "trace dispatched {} of {} tasks",
            self.order.len(),
            n
        );

        // (1) placement: dispatch order is a permutation of the task ids
        let mut dispatched = vec![false; n];
        for &i in &self.order {
            anyhow::ensure!(i < n, "trace dispatches unknown task {i}");
            anyhow::ensure!(!dispatched[i], "task {i} dispatched twice");
            dispatched[i] = true;
        }

        // (2)-(4): one pass in dispatch order over the resource model
        let mut res_free = vec![0.0f64; nres];
        let mut finished = vec![false; n];
        for &i in &self.order {
            let t = &plan.tasks[i];
            let slot = &self.slots[i];
            anyhow::ensure!(
                slot.resource == t.resource,
                "task {i} placed on {:?}, plan binds {:?}",
                slot.resource,
                t.resource
            );
            anyhow::ensure!(
                slot.finish == slot.start + t.duration,
                "task {i} duration distorted: {} -> {} vs duration {}",
                slot.start,
                slot.finish,
                t.duration
            );
            let mut ready = 0.0f64;
            for &d in &t.deps {
                anyhow::ensure!(
                    finished[d] && self.slots[d].finish <= slot.start,
                    "dependency violation: task {i} starts at {} before dep {d} finishes at {}",
                    slot.start,
                    self.slots[d].finish
                );
                if self.slots[d].finish > ready {
                    ready = self.slots[d].finish;
                }
            }
            let expected = match t.resource {
                Some(r) => {
                    anyhow::ensure!(
                        slot.start >= res_free[r],
                        "resource overlap: task {i} starts at {} while resource {r} is busy until {}",
                        slot.start,
                        res_free[r]
                    );
                    let s = if res_free[r] > ready { res_free[r] } else { ready };
                    res_free[r] = slot.finish;
                    s
                }
                None => ready,
            };
            anyhow::ensure!(
                slot.start == expected,
                "slack start: task {i} starts at {} but was dispatchable at {}",
                slot.start,
                expected
            );
            finished[i] = true;
        }

        // (5) makespan == critical path through the trace-induced graph
        // (dependency edges + resource-succession edges), recomputed
        // independently of the recorded start/finish values
        let mut cp = vec![0.0f64; n];
        let mut res_pred: Vec<Option<TaskId>> = vec![None; nres];
        let mut critical = 0.0f64;
        let mut max_finish = 0.0f64;
        for &i in &self.order {
            let t = &plan.tasks[i];
            let mut longest = 0.0f64;
            for &d in &t.deps {
                if cp[d] > longest {
                    longest = cp[d];
                }
            }
            if let Some(r) = t.resource {
                if let Some(p) = res_pred[r] {
                    if cp[p] > longest {
                        longest = cp[p];
                    }
                }
                res_pred[r] = Some(i);
            }
            cp[i] = longest + t.duration;
            if cp[i] > critical {
                critical = cp[i];
            }
            if self.slots[i].finish > max_finish {
                max_finish = self.slots[i].finish;
            }
        }
        anyhow::ensure!(
            self.makespan == max_finish,
            "recorded makespan {} != max finish {}",
            self.makespan,
            max_finish
        );
        anyhow::ensure!(
            self.makespan == critical,
            "recorded makespan {} != critical path {} through the trace",
            self.makespan,
            critical
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::{Plan, Tag, TaskSpec};
    use crate::sim::{SimScratch, Simulator};

    fn spec(resource: Option<usize>, duration: f64, deps: &[usize], priority: i64) -> TaskSpec {
        TaskSpec {
            resource,
            duration,
            deps: deps.to_vec(),
            priority,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        }
    }

    /// The wide-DAG fixture where rank-based scheduling provably beats
    /// FIFO: four short sources ahead (in id order) of a chain head whose
    /// dependent chain dominates the makespan.
    fn wide_dag() -> Plan {
        let mut p = Plan::new();
        let r0 = p.add_resource("sources");
        let r1 = p.add_resource("chain");
        for _ in 0..4 {
            p.add_task(spec(Some(r0), 1.0, &[], 0));
        }
        let head = p.add_task(spec(Some(r0), 1.0, &[], 0));
        let mut prev = head;
        for _ in 0..10 {
            prev = p.add_task(spec(Some(r1), 1.0, &[prev], 0));
        }
        p
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(SchedPolicy::from_name("nope"), None);
        for (i, p) in SchedPolicy::ALL.iter().enumerate() {
            assert_eq!(p.index(), i, "ALL order diverged from index()");
        }
    }

    #[test]
    fn parse_list_mirrors_method_semantics() {
        assert_eq!(SchedPolicy::parse_list("all").unwrap(), SchedPolicy::ALL.to_vec());
        assert_eq!(SchedPolicy::parse_list("ALL").unwrap(), SchedPolicy::ALL.to_vec());
        assert_eq!(
            SchedPolicy::parse_list("heft,streaming,heft").unwrap(),
            vec![SchedPolicy::Heft, SchedPolicy::Streaming],
            "dedup preserves first-occurrence order"
        );
        assert_eq!(
            SchedPolicy::parse_list(" list , greedy ").unwrap(),
            vec![SchedPolicy::List, SchedPolicy::Greedy]
        );
        assert!(SchedPolicy::parse_list("quantum").unwrap_err().contains("quantum"));
        assert!(SchedPolicy::parse_list(",,").is_err());
    }

    #[test]
    fn tie_keys_are_seeded_and_spread() {
        assert_eq!(tie_key(7, 3), tie_key(7, 3));
        assert_ne!(tie_key(7, 3), tie_key(8, 3));
        assert_ne!(tie_key(7, 3), tie_key(7, 4));
    }

    #[test]
    fn upward_ranks_follow_longest_chain() {
        let p = wide_dag();
        let rank = upward_ranks(&p);
        // chain head carries the whole chain; sinks carry their own duration
        assert_eq!(rank[4], 11.0);
        assert_eq!(rank[0], 1.0);
        assert_eq!(rank[p.n_tasks() - 1], 1.0);
        // forward deps (higher-id task depended on by a lower-id one) must
        // not break the rank computation — mirror of the plan builder's
        // baseline barrier gates
        let mut fwd = Plan::new();
        let r = fwd.add_resource("r");
        fwd.add_task(TaskSpec {
            resource: Some(r),
            duration: 1.0,
            deps: vec![1], // forward edge
            priority: 0,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
        fwd.add_task(spec(Some(r), 2.0, &[], 0));
        let fr = upward_ranks(&fwd);
        assert_eq!(fr[1], 3.0, "rank must flow across the forward edge");
        assert_eq!(fr[0], 1.0);
    }

    #[test]
    fn streaming_policy_is_bit_identical_to_run_with() {
        let p = wide_dag();
        let a = Simulator::run(&p);
        let b = Simulator::run_policy(&p, SchedPolicy::Streaming, 0xDEAD_BEEF, &mut SimScratch::new());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.start, b.start);
        assert_eq!(a.finish, b.finish);
        assert_eq!(a.critical_path, b.critical_path);
    }

    #[test]
    fn every_policy_validates_on_the_fixture() {
        let p = wide_dag();
        for policy in SchedPolicy::ALL {
            let (res, trace) =
                Simulator::run_policy_traced(&p, policy, 42, &mut SimScratch::new());
            trace
                .validate(&p)
                .unwrap_or_else(|e| panic!("{} schedule rejected: {e}", policy.name()));
            assert_eq!(res.makespan.to_bits(), trace.makespan.to_bits());
        }
    }

    #[test]
    fn heft_beats_list_on_the_wide_dag() {
        let p = wide_dag();
        let mut scratch = SimScratch::new();
        let list = Simulator::run_policy(&p, SchedPolicy::List, 0, &mut scratch);
        let heft = Simulator::run_policy(&p, SchedPolicy::Heft, 0, &mut scratch);
        // FIFO burns 5s before the chain head; HEFT dispatches it first
        assert_eq!(list.makespan, 15.0);
        assert_eq!(heft.makespan, 11.0);
    }

    #[test]
    fn greedy_dispatches_earliest_finish() {
        // one resource, two sources: short (id 1) finishes earlier than
        // long (id 0); greedy must pick it first despite the id order
        let mut p = Plan::new();
        let r = p.add_resource("r");
        let long = p.add_task(spec(Some(r), 5.0, &[], 0));
        let short = p.add_task(spec(Some(r), 1.0, &[], 0));
        let res = Simulator::run_policy(&p, SchedPolicy::Greedy, 0, &mut SimScratch::new());
        assert_eq!(res.start[short], 0.0);
        assert_eq!(res.start[long], 1.0);
    }

    #[test]
    fn seeded_ties_are_reproducible_and_seed_sensitive() {
        // many identical contenders: order is pure tie-breaking
        let mut p = Plan::new();
        let r = p.add_resource("r");
        for _ in 0..16 {
            p.add_task(spec(Some(r), 1.0, &[], 0));
        }
        for policy in [SchedPolicy::Heft, SchedPolicy::Greedy] {
            let a = Simulator::run_policy(&p, policy, 1, &mut SimScratch::new());
            let b = Simulator::run_policy(&p, policy, 1, &mut SimScratch::new());
            assert_eq!(a.start, b.start, "{} not reproducible", policy.name());
            let c = Simulator::run_policy(&p, policy, 2, &mut SimScratch::new());
            assert_ne!(
                a.start,
                c.start,
                "{} ignored its tie-break seed on an all-tie plan",
                policy.name()
            );
        }
        // streaming documents that it ignores the seed entirely
        let s1 = Simulator::run_policy(&p, SchedPolicy::Streaming, 1, &mut SimScratch::new());
        let s2 = Simulator::run_policy(&p, SchedPolicy::Streaming, 99, &mut SimScratch::new());
        assert_eq!(s1.start, s2.start);
    }

    #[test]
    fn replay_reproduces_the_trace_bitwise() {
        let p = wide_dag();
        for policy in SchedPolicy::ALL {
            let (res, trace) =
                Simulator::run_policy_traced(&p, policy, 9, &mut SimScratch::new());
            let replayed = Simulator::replay(&p, &trace, &mut SimScratch::new());
            assert_eq!(res.makespan.to_bits(), replayed.makespan.to_bits());
            assert_eq!(res.start, replayed.start);
            assert_eq!(res.finish, replayed.finish);
            assert_eq!(res.critical_path, replayed.critical_path);
        }
    }

    #[test]
    fn oracle_rejects_mutated_traces() {
        let p = wide_dag();
        let (_, trace) =
            Simulator::run_policy_traced(&p, SchedPolicy::Streaming, 0, &mut SimScratch::new());
        trace.validate(&p).unwrap();

        // dependency violation: chain task yanked before its parent
        let mut t = trace.clone();
        let last = p.n_tasks() - 1;
        t.slots[last].start = 0.0;
        t.slots[last].finish = p.tasks[last].duration;
        assert!(t.validate(&p).is_err(), "dependency violation accepted");

        // resource overlap: two source tasks at the same instant
        let mut t = trace.clone();
        t.slots[1].start = t.slots[0].start;
        t.slots[1].finish = t.slots[0].start + p.tasks[1].duration;
        assert!(t.validate(&p).is_err(), "resource overlap accepted");

        // double placement
        let mut t = trace.clone();
        t.order[1] = t.order[0];
        assert!(t.validate(&p).is_err(), "double dispatch accepted");

        // makespan lie
        let mut t = trace.clone();
        t.makespan += 1.0;
        assert!(t.validate(&p).is_err(), "inflated makespan accepted");

        // slack start: delay a task beyond its tight start
        let mut t = trace.clone();
        t.slots[0].start += 0.5;
        t.slots[0].finish += 0.5;
        assert!(t.validate(&p).is_err(), "non-work-conserving start accepted");
    }

    #[test]
    fn empty_plan_trace_is_valid() {
        let p = Plan::new();
        let (res, trace) =
            Simulator::run_policy_traced(&p, SchedPolicy::List, 0, &mut SimScratch::new());
        trace.validate(&p).unwrap();
        assert_eq!(res.makespan, 0.0);
    }
}
