//! Metrics: per-step energy accounting, the attention-vs-FFN roofline
//! profiler (paper Appendix C.1, Figures 10-13), the Pareto-dominance
//! analysis (batch + streaming archive) behind the design-space explorer
//! and the guided search strategies, and the serving SLO metrics
//! (streaming P² percentiles, Little's-law consistency, per-tenant SLO
//! attainment and the fleet objectives of the multi-tenant partitioner).

pub mod energy;
pub mod pareto;
pub mod roofline;
pub mod slo;

pub use energy::{step_energy, EnergyBreakdown};
// `pareto::Frontier` (the streaming archive) is deliberately NOT re-exported
// here: `coordinator::explore::Frontier` is an unrelated public type of the
// same name, and two bare `Frontier`s in one domain invite wrong imports.
pub use pareto::{
    constrained_selection_order, crowding_distance, dominates, dominators,
    non_dominated_sort, pareto_frontier,
};
pub use roofline::{profile_decoder_layer, Olmo2Scale, RooflineRow};
pub use slo::{fleet_objectives, littles_law, slo_violation, LittlesLaw, P2Quantile};
