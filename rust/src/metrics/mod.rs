//! Metrics: per-step energy accounting, the attention-vs-FFN roofline
//! profiler (paper Appendix C.1, Figures 10-13), and the Pareto-dominance
//! analysis behind the design-space explorer.

pub mod energy;
pub mod pareto;
pub mod roofline;

pub use energy::{step_energy, EnergyBreakdown};
pub use pareto::{dominates, dominators, pareto_frontier};
pub use roofline::{profile_decoder_layer, Olmo2Scale, RooflineRow};
