//! Metrics: per-step energy accounting and the attention-vs-FFN roofline
//! profiler (paper Appendix C.1, Figures 10-13).

pub mod energy;
pub mod roofline;

pub use energy::{step_energy, EnergyBreakdown};
pub use roofline::{profile_decoder_layer, Olmo2Scale, RooflineRow};
