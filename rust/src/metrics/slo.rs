//! SLO metrics for the serving workload: streaming quantiles and the
//! Little's-law consistency check.
//!
//! * [`P2Quantile`] — the P² (Jain & Chlamtac 1985) streaming quantile
//!   estimator: five markers tracking a target percentile in O(1) space,
//!   so `mozart serve` can report p50/p99/p999 without holding every
//!   latency sample. The estimator is *checked against* the exact
//!   sort-based [`crate::util::stats::percentile`] in the property
//!   tests — both numbers appear in the `SERVE_*.json` artifact, and a
//!   divergence is a bug.
//! * [`littles_law`] — L = λW evaluated from two *independently
//!   computed* sides: L as the time-average number of requests in the
//!   system (an event-sweep integral of N(t)) and λW from the
//!   completion count and mean sojourn time. A simulator that loses,
//!   duplicates, or time-warps a request breaks the identity; every
//!   emitted serve artifact must keep the relative error under 1%.

use crate::util::stats;

/// Streaming estimate of one quantile via the P² algorithm: five
/// markers whose heights approximate the q-quantile without storing
/// samples. Exact (sort-based) below five observations.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    count: u64,
    /// Marker heights (sorted ascending once initialized).
    heights: [f64; 5],
    /// Actual marker positions, 1-based.
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Per-observation increments of the desired positions.
    incr: [f64; 5],
    /// Holds the first few samples until five have arrived.
    init: Vec<f64>,
}

impl P2Quantile {
    /// Estimator for the `q`-quantile, `q` in (0, 1) — e.g. `0.99` for p99.
    pub fn new(q: f64) -> Self {
        assert!(q > 0.0 && q < 1.0, "quantile {q} outside (0, 1)");
        P2Quantile {
            q,
            count: 0,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            incr: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            init: Vec::with_capacity(5),
        }
    }

    /// Samples observed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Feed one observation.
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        if self.count <= 5 {
            self.init.push(x);
            if self.count == 5 {
                self.init.sort_by(f64::total_cmp);
                for (h, &v) in self.heights.iter_mut().zip(self.init.iter()) {
                    *h = v;
                }
            }
            return;
        }

        // locate the cell k with heights[k] <= x < heights[k+1],
        // extending the extreme markers when x falls outside them
        let h = &mut self.heights;
        let k = if x < h[0] {
            h[0] = x;
            0
        } else if x >= h[4] {
            if x > h[4] {
                h[4] = x;
            }
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= h[k + 1] {
                k += 1;
            }
            k
        };

        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.incr[i];
        }

        // nudge the three interior markers toward their desired positions
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let room_up = self.pos[i + 1] - self.pos[i] > 1.0;
            let room_dn = self.pos[i - 1] - self.pos[i] < -1.0;
            if (d >= 1.0 && room_up) || (d <= -1.0 && room_dn) {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.heights[i] = if self.heights[i - 1] < qp && qp < self.heights[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let q = &self.heights;
        let n = &self.pos;
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + d * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate of the quantile. NaN before the first sample;
    /// exact (sort-based) while fewer than five samples have arrived.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count < 5 {
            let mut s = self.init.clone();
            s.sort_by(f64::total_cmp);
            return stats::percentile(&s, self.q * 100.0);
        }
        self.heights[2]
    }
}

/// Both sides of Little's law plus their relative disagreement
/// (see [`littles_law`]).
#[derive(Clone, Copy, Debug)]
pub struct LittlesLaw {
    /// Time-average number of requests in the system (event-sweep
    /// integral of N(t) over the horizon).
    pub l: f64,
    /// Completion throughput over the horizon, requests/s.
    pub lambda_per_s: f64,
    /// Mean sojourn (arrival → completion) time, seconds.
    pub mean_sojourn_s: f64,
    /// `|L − λW| / max(L, ε)` — must stay under 0.01 on every emitted
    /// serve artifact.
    pub rel_err: f64,
}

/// Check Little's law L = λW over completed-request `(arrival_s,
/// finish_s)` spans observed on `[0, horizon_s]`.
///
/// The two sides are computed independently: L by sweeping +1/−1
/// events and integrating the in-system count N(t) (finishes clamped
/// to the horizon), λW from the completion count and the mean
/// *unclamped* sojourn. Requests still in flight at the horizon — or
/// any accounting bug that loses, duplicates, or reorders a request —
/// drive the two sides apart.
pub fn littles_law(spans: &[(f64, f64)], horizon_s: f64) -> LittlesLaw {
    assert!(horizon_s > 0.0, "horizon must be > 0");
    if spans.is_empty() {
        return LittlesLaw {
            l: 0.0,
            lambda_per_s: 0.0,
            mean_sojourn_s: 0.0,
            rel_err: 0.0,
        };
    }
    let mut events: Vec<(f64, f64)> = Vec::with_capacity(2 * spans.len());
    let mut sojourn_sum = 0.0;
    for &(a, f) in spans {
        assert!(f >= a, "finish {f} before arrival {a}");
        sojourn_sum += f - a;
        events.push((a.min(horizon_s), 1.0));
        events.push((f.min(horizon_s), -1.0));
    }
    // departures before arrivals at equal timestamps: N(t) stays minimal
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let (mut area, mut n, mut prev) = (0.0, 0.0, 0.0);
    for (t, delta) in events {
        area += n * (t - prev);
        n += delta;
        prev = t;
    }
    let l = area / horizon_s;
    let lambda = spans.len() as f64 / horizon_s;
    let w = sojourn_sum / spans.len() as f64;
    let rhs = lambda * w;
    let rel_err = (l - rhs).abs() / l.max(1e-12);
    LittlesLaw {
        l,
        lambda_per_s: lambda,
        mean_sojourn_s: w,
        rel_err,
    }
}

/// Relative SLO violation of a measured tail latency: `max(0, (p99 −
/// slo) / slo)`. Zero means the tenant met its objective; `1.0` means the
/// tail ran at twice the agreed latency. Training tenants (no latency
/// SLO) report `0.0` by convention, so fleet aggregation can treat every
/// tenant uniformly.
pub fn slo_violation(p99_ms: f64, slo_ms: f64) -> f64 {
    assert!(slo_ms > 0.0, "SLO must be > 0, got {slo_ms}");
    ((p99_ms - slo_ms) / slo_ms).max(0.0)
}

/// Fleet objectives of one multi-tenant partition, in the minimized
/// orientation the Pareto machinery expects: worst per-tenant SLO
/// violation, negated total token throughput (so more is better), and
/// aggregate mean package power. This triple is the frontier space of the
/// `TENANTS_*.json` artifact.
pub fn fleet_objectives(
    violations: &[f64],
    total_tokens_per_s: f64,
    power_w: f64,
) -> [f64; 3] {
    let worst = violations.iter().copied().fold(0.0f64, f64::max);
    [worst, -total_tokens_per_s, power_w]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exact(samples: &[f64], q: f64) -> f64 {
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        stats::percentile(&s, q * 100.0)
    }

    /// Satellite 3: the P² streaming estimate converges to the exact
    /// sort-based percentile on seeded workloads, across distribution
    /// shapes and target quantiles.
    #[test]
    fn p2_converges_to_exact_percentiles() {
        let mut rng = Rng::new(42);
        let n = 20_000;
        let uniform: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let normal: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let expo: Vec<f64> = (0..n).map(|_| -(1.0 - rng.f64()).ln()).collect();
        for (name, samples) in [("uniform", &uniform), ("normal", &normal), ("exp", &expo)] {
            for q in [0.5, 0.9, 0.99] {
                let mut p2 = P2Quantile::new(q);
                for &x in samples.iter() {
                    p2.observe(x);
                }
                let est = p2.value();
                let truth = exact(samples, q);
                let spread = exact(samples, 0.999) - exact(samples, 0.001);
                let err = (est - truth).abs() / spread;
                assert!(
                    err < 0.02,
                    "{name} q={q}: p2={est} exact={truth} relerr={err}"
                );
            }
        }
    }

    #[test]
    fn p2_is_exact_below_five_samples() {
        let mut p2 = P2Quantile::new(0.5);
        assert!(p2.value().is_nan());
        for (i, x) in [5.0, 1.0, 3.0].iter().enumerate() {
            p2.observe(*x);
            assert_eq!(p2.count(), i as u64 + 1);
        }
        assert_eq!(p2.value(), 3.0); // exact median of {1, 3, 5}
    }

    #[test]
    fn p2_heights_stay_ordered_and_bounded() {
        let mut rng = Rng::new(7);
        let mut p2 = P2Quantile::new(0.99);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for _ in 0..5_000 {
            let x = rng.normal() * 10.0;
            lo = lo.min(x);
            hi = hi.max(x);
            p2.observe(x);
        }
        let v = p2.value();
        assert!(v >= lo && v <= hi, "estimate {v} outside [{lo}, {hi}]");
        for w in p2.heights.windows(2) {
            assert!(w[0] <= w[1], "marker heights out of order: {:?}", p2.heights);
        }
    }

    #[test]
    fn littles_law_holds_on_consistent_accounting() {
        // random but complete spans: L and λW must agree to rounding
        let mut rng = Rng::new(11);
        let mut spans = Vec::new();
        let mut t = 0.0;
        for _ in 0..2_000 {
            t += rng.f64() * 0.01;
            spans.push((t, t + 0.001 + rng.f64() * 0.05));
        }
        let horizon = spans.iter().map(|s| s.1).fold(0.0, f64::max) + 0.01;
        let ll = littles_law(&spans, horizon);
        assert!(ll.rel_err < 1e-9, "rel_err={}", ll.rel_err);
        assert!(ll.l > 0.0 && ll.lambda_per_s > 0.0 && ll.mean_sojourn_s > 0.0);
    }

    #[test]
    fn littles_law_flags_truncated_sojourns() {
        // a request still in flight at the horizon breaks the identity:
        // the integral clamps at the horizon, the sojourn side does not
        let spans = vec![(0.0, 1.0), (0.1, 50.0)];
        let ll = littles_law(&spans, 2.0);
        assert!(ll.rel_err > 0.5, "rel_err={} should be large", ll.rel_err);
    }

    #[test]
    fn littles_law_empty_is_clean() {
        let ll = littles_law(&[], 1.0);
        assert_eq!(ll.rel_err, 0.0);
        assert_eq!(ll.l, 0.0);
    }

    #[test]
    fn slo_violation_is_zero_within_slo_and_relative_beyond() {
        assert_eq!(slo_violation(30.0, 50.0), 0.0);
        assert_eq!(slo_violation(50.0, 50.0), 0.0);
        assert!((slo_violation(100.0, 50.0) - 1.0).abs() < 1e-12);
        assert!((slo_violation(75.0, 50.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fleet_objectives_orientation() {
        let o = fleet_objectives(&[0.0, 0.4, 0.1], 1000.0, 250.0);
        assert_eq!(o[0], 0.4, "worst violation");
        assert_eq!(o[1], -1000.0, "throughput is negated for minimization");
        assert_eq!(o[2], 250.0);
        // no tenants (degenerate): worst violation is zero, not NaN
        assert_eq!(fleet_objectives(&[], 0.0, 0.0)[0], 0.0);
    }
}
