//! Pareto-dominance analysis for the hardware design-space explorer.
//!
//! The explorer scores every hardware variant on several objectives that are
//! all *minimized* (iteration latency, energy per iteration, die area); a
//! variant is worth reporting only if no other variant is at least as good on
//! every objective and strictly better on one. This module provides the
//! dominance predicate and an `O(n^2)` frontier extraction over objective
//! vectors — exact and deterministic, which is what the paper-scale grids
//! (tens to hundreds of points) need. The invariants (no frontier member is
//! dominated; every excluded point is dominated by a frontier member) are
//! property-tested in `tests/prop_invariants.rs`.

/// Returns true iff `a` dominates `b`: `a` is no worse than `b` on every
/// objective and strictly better on at least one. All objectives are
/// minimized and must be finite (NaN never dominates and is never dominated,
/// which would silently corrupt a frontier — feed only finite scores).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "objective arity mismatch");
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated points among `points` (each a vector of
/// minimized objectives of equal arity), in input order.
///
/// Duplicate points do not dominate each other, so all copies of a
/// frontier-worthy point are kept — callers that want one representative can
/// dedup by objective vector afterwards.
pub fn pareto_frontier(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            points
                .iter()
                .enumerate()
                .all(|(j, other)| j == i || !dominates(other, &points[i]))
        })
        .collect()
}

/// For one point, the indices of every point in `points` that dominates it
/// (empty iff the point is on the frontier of `points ∪ {point}`). Used by
/// the explorer to report *how* the paper's Table 2 configuration loses to
/// discovered variants.
pub fn dominators(point: &[f64], points: &[Vec<f64>]) -> Vec<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, other)| dominates(other, point))
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict win
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn frontier_of_a_chain_is_the_minimum() {
        // strictly ordered points: only the best survives
        let pts = vec![vec![3.0, 3.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        assert_eq!(pareto_frontier(&pts), vec![1]);
    }

    #[test]
    fn frontier_keeps_all_tradeoffs() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicates_survive_together() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0], vec![2.0, 0.5]];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(pareto_frontier(&[]).is_empty());
        assert_eq!(pareto_frontier(&[vec![5.0]]), vec![0]);
    }

    #[test]
    fn dominators_of_an_interior_point() {
        let pts = vec![vec![1.0, 1.0], vec![4.0, 4.0], vec![2.0, 5.0]];
        assert_eq!(dominators(&[3.0, 3.0], &pts), vec![0]);
        assert!(dominators(&[0.5, 0.5], &pts).is_empty());
    }

    #[test]
    fn three_objectives() {
        let pts = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 1.0, 3.0],
            vec![1.0, 2.0, 4.0], // dominated by the first
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1]);
    }
}
