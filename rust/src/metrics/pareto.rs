//! Pareto-dominance analysis for the hardware design-space explorer and the
//! guided search strategies.
//!
//! The explorer scores every hardware variant on several objectives that are
//! all *minimized* (iteration latency, energy per iteration, die area); a
//! variant is worth reporting only if no other variant is at least as good on
//! every objective and strictly better on one. This module provides the
//! dominance predicate, an `O(n^2)` batch frontier extraction over objective
//! vectors, a streaming [`Frontier`] archive ([`Frontier::insert`] is
//! `O(n)` per point) for search loops that discover candidates
//! incrementally, and the NSGA-II selection machinery the evolutionary
//! search strategy is built on ([`non_dominated_sort`],
//! [`crowding_distance`], and the constraint-aware
//! [`constrained_selection_order`]) — exact and deterministic, which is what
//! the paper-scale grids (tens to hundreds of points) need. The invariants
//! (no frontier member is dominated; every excluded point is dominated by a
//! frontier member; the streaming archive equals the batch reduction; front
//! 0 of the sort equals the batch frontier; crowding distance is a function
//! of objective values alone; feasible points always precede infeasible
//! ones) are property-tested in `tests/prop_invariants.rs`.

/// Returns true iff `a` dominates `b`: `a` is no worse than `b` on every
/// objective and strictly better on at least one. All objectives are
/// minimized and must be finite (NaN never dominates and is never dominated,
/// which would silently corrupt a frontier — feed only finite scores).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "objective arity mismatch");
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated points among `points` (each a vector of
/// minimized objectives of equal arity), in input order.
///
/// Duplicate points do not dominate each other, so all copies of a
/// frontier-worthy point are kept — callers that want one representative can
/// dedup by objective vector afterwards.
pub fn pareto_frontier(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            points
                .iter()
                .enumerate()
                .all(|(j, other)| j == i || !dominates(other, &points[i]))
        })
        .collect()
}

/// For one point, the indices of every point in `points` that dominates it
/// (empty iff the point is on the frontier of `points ∪ {point}`). Used by
/// the explorer to report *how* the paper's Table 2 configuration loses to
/// discovered variants. A point exactly equal to `point` is never listed
/// (equality carries no strict win), so querying a point against a set that
/// contains copies of it does not report the copies as dominators —
/// regression-tested with tied points.
pub fn dominators(point: &[f64], points: &[Vec<f64>]) -> Vec<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, other)| dominates(other, point))
        .map(|(i, _)| i)
        .collect()
}

/// Index of the smallest value under `f64::total_cmp` (first index on exact
/// ties, so the result is deterministic even with duplicated minima), or
/// `None` for an empty slice. Used by the explorer's schedule frontier to
/// pick the winning policy per variant; `total_cmp` keeps NaNs from
/// poisoning the scan (they order above every real value).
pub fn argmin(values: &[f64]) -> Option<usize> {
    values
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.total_cmp(b))
        .map(|(i, _)| i)
}

/// Total order over objective vectors (lexicographic `total_cmp`), used for
/// value-based tie-breaking so every selection routine here is a function of
/// the objective values alone — never of input order.
fn lex_cmp(a: &[f64], b: &[f64]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b.iter()) {
        let c = x.total_cmp(y);
        if c != std::cmp::Ordering::Equal {
            return c;
        }
    }
    a.len().cmp(&b.len())
}

/// NSGA-II fast non-dominated sort: partition `points` into fronts by
/// dominance rank. Front 0 is exactly [`pareto_frontier`]; every point in
/// front `k > 0` is dominated by at least one point in front `k - 1`.
/// Exactly-equal vectors never dominate each other, so duplicates always
/// share a front. Each front lists indices sorted ascending; the fronts
/// partition `0..points.len()`. `O(n^2)` like the batch frontier —
/// property-tested in `tests/prop_invariants.rs`.
pub fn non_dominated_sort(points: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let mut dominated_by = vec![0usize; n];
    let mut beats: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&points[i], &points[j]) {
                beats[i].push(j);
                dominated_by[j] += 1;
            } else if dominates(&points[j], &points[i]) {
                beats[j].push(i);
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    while !current.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &current {
            for &j in &beats[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        next.sort_unstable();
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// NSGA-II crowding distance of every point (usually the members of one
/// front): boundary points get `f64::INFINITY`, interior points the sum over
/// objectives of the normalized gap between their neighbours in that
/// objective's sorted order. Distances are computed over the *unique*
/// objective vectors and shared by exact duplicates, with value-based
/// tie-breaking, so the result is **permutation-invariant**: it depends only
/// on each point's objective values, never on input order (property-tested
/// in `tests/prop_invariants.rs`). Objectives with zero spread contribute
/// nothing. Returns one distance per input point.
pub fn crowding_distance(points: &[Vec<f64>]) -> Vec<f64> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let dims = points[0].len();
    // representative index of each unique objective vector, sorted lex
    let mut uniq: Vec<usize> = (0..n).collect();
    uniq.sort_by(|&a, &b| lex_cmp(&points[a], &points[b]));
    // dedup with the same comparator the binary search below uses, so every
    // point (including -0.0/NaN oddities) finds its representative
    uniq.dedup_by(|a, b| lex_cmp(&points[*a], &points[*b]) == std::cmp::Ordering::Equal);
    let m = uniq.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        dist.iter_mut().for_each(|d| *d = f64::INFINITY);
    } else {
        for d in 0..dims {
            let mut order: Vec<usize> = (0..m).collect();
            order.sort_by(|&a, &b| {
                points[uniq[a]][d]
                    .total_cmp(&points[uniq[b]][d])
                    .then_with(|| lex_cmp(&points[uniq[a]], &points[uniq[b]]))
            });
            let lo = points[uniq[order[0]]][d];
            let hi = points[uniq[order[m - 1]]][d];
            dist[order[0]] = f64::INFINITY;
            dist[order[m - 1]] = f64::INFINITY;
            if hi > lo {
                for k in 1..(m - 1) {
                    dist[order[k]] += (points[uniq[order[k + 1]]][d]
                        - points[uniq[order[k - 1]]][d])
                        / (hi - lo);
                }
            }
        }
    }
    (0..n)
        .map(|i| {
            let pos = uniq
                .binary_search_by(|&u| lex_cmp(&points[u], &points[i]))
                .expect("every point has a unique representative");
            dist[pos]
        })
        .collect()
}

/// NSGA-II constrained selection: indices of `points` ordered best-first
/// under the constrained-crowded-comparison operator. `violation[i]` is the
/// point's total constraint violation (`0.0` = feasible).
///
/// The order is: every feasible point before every infeasible one; feasible
/// points by non-dominated-sort rank ascending, then crowding distance
/// (computed within their front) descending, then index ascending;
/// infeasible points by violation ascending, then index ascending. Taking a
/// prefix of this order is NSGA-II environmental selection; comparing two
/// positions in it is the binary-tournament comparator. Deterministic, and
/// infeasible points can never displace feasible ones — property-tested in
/// `tests/prop_invariants.rs`.
pub fn constrained_selection_order(points: &[Vec<f64>], violation: &[f64]) -> Vec<usize> {
    assert_eq!(points.len(), violation.len(), "violation arity mismatch");
    let feasible: Vec<usize> = (0..points.len()).filter(|&i| violation[i] == 0.0).collect();
    let mut infeasible: Vec<usize> =
        (0..points.len()).filter(|&i| violation[i] != 0.0).collect();
    infeasible.sort_by(|&a, &b| violation[a].total_cmp(&violation[b]).then(a.cmp(&b)));

    let fobjs: Vec<Vec<f64>> = feasible.iter().map(|&i| points[i].clone()).collect();
    let mut out: Vec<usize> = Vec::with_capacity(points.len());
    for front in non_dominated_sort(&fobjs) {
        let members: Vec<Vec<f64>> = front.iter().map(|&k| fobjs[k].clone()).collect();
        let crowd = crowding_distance(&members);
        let mut order: Vec<usize> = (0..front.len()).collect();
        order.sort_by(|&a, &b| {
            crowd[b]
                .total_cmp(&crowd[a])
                .then(feasible[front[a]].cmp(&feasible[front[b]]))
        });
        out.extend(order.into_iter().map(|k| feasible[front[k]]));
    }
    out.extend(infeasible);
    out
}

/// Incremental Pareto archive over minimized objective vectors.
///
/// The guided search strategies (`coordinator::search`) discover candidates
/// one generation at a time; re-reducing the full point set after every
/// evaluation would be `O(n^2)` per generation. [`Frontier::insert`] keeps a
/// streaming archive instead: a new point is rejected in one `O(n)` scan if
/// any member dominates it, and otherwise evicts every member it dominates.
/// The final archive equals the batch [`pareto_frontier`] of all inserted
/// points (duplicates of a frontier-worthy point survive together, matching
/// the batch semantics) — property-tested in `tests/prop_invariants.rs`.
///
/// Each entry carries a caller-chosen `usize` key (e.g. a candidate index)
/// so archive membership can be mapped back to the evaluated design points.
///
/// # Examples
///
/// ```
/// use mozart::metrics::pareto::Frontier;
///
/// let mut f = Frontier::new();
/// assert!(f.insert(0, &[1.0, 4.0]));  // first point: always kept
/// assert!(f.insert(1, &[4.0, 1.0]));  // incomparable trade-off: both stay
/// assert!(!f.insert(2, &[5.0, 5.0])); // dominated: rejected
/// assert!(f.insert(3, &[0.5, 0.5]));  // dominates both members
/// assert_eq!(f.keys(), vec![3]);      // the archive collapsed onto it
/// ```
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    entries: Vec<(usize, Vec<f64>)>,
}

impl Frontier {
    /// An empty archive.
    pub fn new() -> Frontier {
        Frontier {
            entries: Vec::new(),
        }
    }

    /// Offer a point to the archive. Returns `true` iff the point was
    /// admitted (no current member dominates it); admission evicts every
    /// member the new point dominates. Exactly-equal objective vectors do
    /// not dominate each other, so tied members survive together (matching
    /// the batch [`pareto_frontier`] semantics); re-offering an
    /// already-archived `key` replaces that entry instead of duplicating it,
    /// so [`Frontier::len`] and [`Frontier::keys`] count each key at most
    /// once. All objectives are minimized and must be finite (same contract
    /// as [`dominates`]).
    pub fn insert(&mut self, key: usize, objectives: &[f64]) -> bool {
        if let Some((_, first)) = self.entries.first() {
            debug_assert_eq!(first.len(), objectives.len(), "objective arity mismatch");
        }
        if self
            .entries
            .iter()
            .any(|(_, member)| dominates(member, objectives))
        {
            return false;
        }
        self.entries
            .retain(|(k, member)| *k != key && !dominates(objectives, member));
        self.entries.push((key, objectives.to_vec()));
        true
    }

    /// Number of archive members.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the archive has no members.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Keys of the current members, sorted ascending (insertion order is an
    /// implementation detail; sorted keys make archive comparisons stable).
    pub fn keys(&self) -> Vec<usize> {
        let mut k: Vec<usize> = self.entries.iter().map(|(key, _)| *key).collect();
        k.sort_unstable();
        k
    }

    /// Iterate over `(key, objectives)` of the current members.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &[f64])> {
        self.entries.iter().map(|(k, o)| (*k, o.as_slice()))
    }

    /// Cheap hypervolume *proxy* against a fixed reference point (worse than
    /// every interesting point, all coordinates > 0): the sum over *unique*
    /// member objective vectors of the normalized box volume
    /// `prod_d max(0, (ref_d - obj_d) / ref_d)`. Tied members (several keys
    /// mapping to one objective vector) contribute exactly once — they are
    /// one point of the frontier, however many candidates reached it.
    /// Overlapping boxes of distinct points are still counted once per
    /// point, so this is not the exact dominated hypervolume — but it is
    /// deterministic, `O(n·d + n log n)`, and grows as the archive
    /// approaches the reference-relative ideal point, which is all the
    /// per-generation convergence curve needs.
    pub fn hypervolume_proxy(&self, reference: &[f64]) -> f64 {
        let mut objs: Vec<&[f64]> =
            self.entries.iter().map(|(_, o)| o.as_slice()).collect();
        objs.sort_by(|a, b| lex_cmp(a, b));
        objs.dedup_by(|a, b| lex_cmp(a, b) == std::cmp::Ordering::Equal);
        objs.iter()
            .map(|obj| {
                obj.iter()
                    .zip(reference.iter())
                    .map(|(&v, &r)| ((r - v) / r).max(0.0))
                    .product::<f64>()
            })
            .sum()
    }

    /// **Exact** dominated hypervolume against a fixed reference point, in
    /// the same normalized units as [`Frontier::hypervolume_proxy`]: each
    /// objective is scaled by its reference coordinate and clipped to
    /// `[0, 1]`, and the result is the volume of the *union* of the boxes
    /// `[obj_norm, 1]^d` — overlap between members is counted once, so the
    /// value is always `<=` the proxy and a flat convergence curve really
    /// means the frontier stopped improving (the proxy can keep growing on
    /// mutually overlapping points). Exact for up to three objectives — a
    /// dimension sweep over the sorted last coordinate with a 2-D union
    /// area per slab, `O(n² log n)` — and falls back to the proxy for
    /// higher arities, where the sweep would not be worth its cost for the
    /// archive sizes the search produces.
    pub fn hypervolume(&self, reference: &[f64]) -> f64 {
        if reference.len() > 3 {
            return self.hypervolume_proxy(reference);
        }
        let mut pts: Vec<Vec<f64>> = self
            .entries
            .iter()
            .map(|(_, o)| {
                o.iter()
                    .zip(reference.iter())
                    .map(|(&v, &r)| (v / r).clamp(0.0, 1.0))
                    .collect()
            })
            .collect();
        pts.sort_by(|a, b| lex_cmp(a, b));
        pts.dedup_by(|a, b| lex_cmp(a, b) == std::cmp::Ordering::Equal);
        if pts.is_empty() {
            return 0.0;
        }
        match reference.len() {
            1 => pts.iter().map(|p| 1.0 - p[0]).fold(0.0, f64::max),
            2 => union_area_2d(pts.iter().map(|p| (p[0], p[1])).collect()),
            _ => {
                // z-sweep: within the slab [z_k, z_next) exactly the points
                // with z <= z_k contribute, covering their 2-D union area
                let n = pts.len();
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| pts[a][2].total_cmp(&pts[b][2]));
                let mut hv = 0.0;
                for k in 0..n {
                    let z = pts[order[k]][2];
                    let z_next = if k + 1 < n { pts[order[k + 1]][2] } else { 1.0 };
                    if z_next > z {
                        let xy: Vec<(f64, f64)> = order[..=k]
                            .iter()
                            .map(|&j| (pts[j][0], pts[j][1]))
                            .collect();
                        hv += (z_next - z) * union_area_2d(xy);
                    }
                }
                hv
            }
        }
    }
}

/// Area of the union of the boxes `[x, 1] × [y, 1]` over normalized points
/// in `[0, 1]²`: an x-sweep where the covered height over the slab
/// `[x_i, x_next)` is set by the lowest `y` seen so far.
fn union_area_2d(mut pts: Vec<(f64, f64)>) -> f64 {
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut area = 0.0;
    let mut best_y = 1.0f64;
    for (i, &(x, y)) in pts.iter().enumerate() {
        let x_next = if i + 1 < pts.len() { pts[i + 1].0 } else { 1.0 };
        best_y = best_y.min(y);
        area += (x_next - x) * (1.0 - best_y);
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_is_first_minimum_and_nan_safe() {
        assert_eq!(argmin(&[]), None);
        assert_eq!(argmin(&[2.0]), Some(0));
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        // exact ties break to the first index — deterministic winners
        assert_eq!(argmin(&[1.0, 1.0, 1.0]), Some(0));
        assert_eq!(argmin(&[2.0, 1.0, 1.0]), Some(1));
        // total_cmp orders NaN above every real value, so a NaN entry can
        // never win against a finite latency
        assert_eq!(argmin(&[f64::NAN, 5.0]), Some(1));
    }

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict win
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn frontier_of_a_chain_is_the_minimum() {
        // strictly ordered points: only the best survives
        let pts = vec![vec![3.0, 3.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        assert_eq!(pareto_frontier(&pts), vec![1]);
    }

    #[test]
    fn frontier_keeps_all_tradeoffs() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicates_survive_together() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0], vec![2.0, 0.5]];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(pareto_frontier(&[]).is_empty());
        assert_eq!(pareto_frontier(&[vec![5.0]]), vec![0]);
    }

    #[test]
    fn dominators_of_an_interior_point() {
        let pts = vec![vec![1.0, 1.0], vec![4.0, 4.0], vec![2.0, 5.0]];
        assert_eq!(dominators(&[3.0, 3.0], &pts), vec![0]);
        assert!(dominators(&[0.5, 0.5], &pts).is_empty());
    }

    #[test]
    fn streaming_frontier_matches_batch_on_a_fixed_set() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
            vec![2.0, 2.0], // duplicate of a member: survives alongside it
        ];
        let mut f = Frontier::new();
        for (i, p) in pts.iter().enumerate() {
            f.insert(i, p);
        }
        assert_eq!(f.keys(), pareto_frontier(&pts));
        assert_eq!(f.len(), 4);
        assert!(!f.is_empty());
    }

    #[test]
    fn streaming_insert_evicts_dominated_members() {
        let mut f = Frontier::new();
        assert!(f.insert(7, &[3.0, 3.0]));
        assert!(f.insert(8, &[2.0, 4.0]));
        // dominates key 7 but not key 8
        assert!(f.insert(9, &[2.5, 2.5]));
        assert_eq!(f.keys(), vec![8, 9]);
        // rejected points leave the archive untouched
        assert!(!f.insert(10, &[9.0, 9.0]));
        assert_eq!(f.keys(), vec![8, 9]);
        let got: Vec<(usize, Vec<f64>)> =
            f.iter().map(|(k, o)| (k, o.to_vec())).collect();
        assert!(got.contains(&(9, vec![2.5, 2.5])));
    }

    #[test]
    fn hypervolume_proxy_orders_archives() {
        let reference = [10.0, 10.0];
        let mut near = Frontier::new();
        near.insert(0, &[1.0, 1.0]);
        let mut far = Frontier::new();
        far.insert(0, &[8.0, 8.0]);
        assert!(near.hypervolume_proxy(&reference) > far.hypervolume_proxy(&reference));
        // points at/behind the reference contribute nothing
        let mut behind = Frontier::new();
        behind.insert(0, &[12.0, 3.0]);
        assert_eq!(behind.hypervolume_proxy(&reference), 0.0);
        assert_eq!(Frontier::new().hypervolume_proxy(&reference), 0.0);
    }

    #[test]
    fn exact_hypervolume_hand_cases() {
        let reference = [10.0, 10.0];
        // single point: exact equals the proxy (one box, no overlap)
        let mut f = Frontier::new();
        f.insert(0, &[5.0, 5.0]);
        assert!((f.hypervolume(&reference) - 0.25).abs() < 1e-12);
        assert!((f.hypervolume(&reference) - f.hypervolume_proxy(&reference)).abs() < 1e-12);

        // two overlapping boxes: union 0.16 + 0.16 - 0.04; the proxy
        // double-counts the overlap (0.32)
        let mut f = Frontier::new();
        f.insert(0, &[2.0, 8.0]);
        f.insert(1, &[8.0, 2.0]);
        assert!((f.hypervolume(&reference) - 0.28).abs() < 1e-12);
        assert!((f.hypervolume_proxy(&reference) - 0.32).abs() < 1e-12);

        // 1-D: the best point sets the whole volume
        let mut f = Frontier::new();
        f.insert(0, &[4.0]);
        assert!((f.hypervolume(&[10.0]) - 0.6).abs() < 1e-12);

        // 3-D nested boxes: the union is the outer (better) box alone
        let mut f = Frontier::new();
        f.insert(0, &[5.0, 5.0, 5.0]);
        f.insert(1, &[2.0, 2.0, 2.0]);
        assert!((f.hypervolume(&[10.0, 10.0, 10.0]) - 0.512).abs() < 1e-12);

        // points at/behind the reference contribute nothing; empty is zero
        let mut f = Frontier::new();
        f.insert(0, &[12.0, 3.0]);
        assert!((f.hypervolume(&reference) - 0.0).abs() < 1e-12);
        assert_eq!(Frontier::new().hypervolume(&reference), 0.0);

        // tied members count once (same contract as the proxy)
        let mut f = Frontier::new();
        f.insert(0, &[5.0, 5.0]);
        f.insert(1, &[5.0, 5.0]);
        assert!((f.hypervolume(&reference) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn exact_hypervolume_matches_monte_carlo() {
        let mut rng = crate::util::rng::Rng::new(0x48_56);
        for dims in [2usize, 3] {
            for trial in 0..3 {
                let reference = vec![10.0f64; dims];
                let mut f = Frontier::new();
                for i in 0..(4 + trial * 3) {
                    let p: Vec<f64> = (0..dims).map(|_| rng.f64() * 10.0).collect();
                    f.insert(i, &p);
                }
                let exact = f.hypervolume(&reference);
                // a dominated normalized sample u has SOME member with
                // obj_norm <= u on every coordinate
                let members: Vec<Vec<f64>> = f
                    .iter()
                    .map(|(_, o)| o.iter().map(|&v| v / 10.0).collect())
                    .collect();
                let n = 200_000;
                let mut hits = 0usize;
                for _ in 0..n {
                    let u: Vec<f64> = (0..dims).map(|_| rng.f64()).collect();
                    if members
                        .iter()
                        .any(|m| m.iter().zip(u.iter()).all(|(&mv, &uv)| mv <= uv))
                    {
                        hits += 1;
                    }
                }
                let mc = hits as f64 / n as f64;
                assert!(
                    (exact - mc).abs() < 0.006,
                    "d={dims} trial={trial}: exact {exact} vs MC {mc}"
                );
                // union can never exceed the sum-of-boxes proxy
                assert!(exact <= f.hypervolume_proxy(&reference) + 1e-12);
            }
        }
    }

    #[test]
    fn exact_hypervolume_falls_back_to_the_proxy_above_three_dims() {
        let reference = [10.0, 10.0, 10.0, 10.0];
        let mut f = Frontier::new();
        f.insert(0, &[5.0, 5.0, 5.0, 5.0]);
        f.insert(1, &[2.0, 8.0, 8.0, 8.0]);
        assert_eq!(f.hypervolume(&reference), f.hypervolume_proxy(&reference));
    }

    #[test]
    fn three_objectives() {
        let pts = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 1.0, 3.0],
            vec![1.0, 2.0, 4.0], // dominated by the first
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1]);
    }

    #[test]
    fn tied_points_do_not_evict_or_double_count() {
        // regression: exactly-equal objective vectors must coexist in the
        // archive, never list each other as dominators, and count once in
        // the hypervolume proxy
        let tied = [2.0, 2.0];
        let pts = vec![tied.to_vec(), tied.to_vec(), vec![1.0, 4.0]];
        assert!(dominators(&tied, &pts).is_empty(), "a tie is not a dominator");

        let mut f = Frontier::new();
        assert!(f.insert(0, &tied));
        assert!(f.insert(1, &tied), "a tied point must not be rejected");
        assert!(f.insert(2, &[1.0, 4.0]));
        assert_eq!(f.keys(), vec![0, 1, 2], "tied members evicted each other");

        // both copies of (2,2) contribute ONE box: total equals the archive
        // with a single copy
        let reference = [10.0, 10.0];
        let mut single = Frontier::new();
        single.insert(0, &tied);
        single.insert(2, &[1.0, 4.0]);
        assert_eq!(
            f.hypervolume_proxy(&reference),
            single.hypervolume_proxy(&reference),
            "tied members double-counted in the hypervolume proxy"
        );
    }

    #[test]
    fn reinserting_a_key_does_not_duplicate_it() {
        let mut f = Frontier::new();
        assert!(f.insert(5, &[3.0, 3.0]));
        assert!(f.insert(5, &[3.0, 3.0]));
        assert_eq!(f.len(), 1, "re-offered key duplicated its entry");
        // a re-offer with better objectives refreshes the entry in place
        assert!(f.insert(5, &[1.0, 1.0]));
        assert_eq!(f.keys(), vec![5]);
        let objs: Vec<Vec<f64>> = f.iter().map(|(_, o)| o.to_vec()).collect();
        assert_eq!(objs, vec![vec![1.0, 1.0]]);
    }

    #[test]
    fn non_dominated_sort_ranks_a_layered_cloud() {
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![2.0, 2.0], // front 0
            vec![4.0, 1.0], // front 0
            vec![3.0, 3.0], // front 1 (dominated by (2,2))
            vec![5.0, 5.0], // front 2 (dominated by (3,3))
            vec![2.0, 2.0], // duplicate of a front-0 point: shares front 0
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 3);
        assert_eq!(fronts[0], vec![0, 1, 2, 5]);
        assert_eq!(fronts[1], vec![3]);
        assert_eq!(fronts[2], vec![4]);
        assert_eq!(fronts[0], pareto_frontier(&pts));
        assert!(non_dominated_sort(&[]).is_empty());
    }

    #[test]
    fn crowding_distance_boundaries_and_duplicates() {
        let pts = vec![
            vec![1.0, 4.0], // boundary
            vec![2.0, 2.0],
            vec![4.0, 1.0], // boundary
            vec![2.0, 2.0], // duplicate: must share the interior distance
        ];
        let d = crowding_distance(&pts);
        assert!(d[0].is_infinite() && d[2].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
        assert_eq!(d[1], d[3], "duplicates must share one distance");
        // a 2-point set is all boundary
        assert!(crowding_distance(&pts[..2]).iter().all(|v| v.is_infinite()));
        assert!(crowding_distance(&[]).is_empty());
    }

    #[test]
    fn constrained_order_puts_feasible_first() {
        let pts = vec![
            vec![9.0, 9.0], // feasible but awful
            vec![1.0, 1.0], // infeasible, tiny violation
            vec![2.0, 2.0], // infeasible, large violation
            vec![5.0, 5.0], // feasible, dominates (9,9)
        ];
        let violation = vec![0.0, 0.1, 0.7, 0.0];
        let order = constrained_selection_order(&pts, &violation);
        // feasible first (3 dominates 0, so rank puts 3 ahead), then the
        // infeasible points by ascending violation — even though the
        // infeasible objectives are the best of the whole set
        assert_eq!(order, vec![3, 0, 1, 2]);
    }

    #[test]
    fn constrained_order_is_a_feasible_prefix_on_random_clouds() {
        let mut rng = crate::util::rng::Rng::new(77);
        for _ in 0..20 {
            let (pts, viol) =
                crate::testkit::constrained_objective_cloud(&mut rng, 20, 3);
            let order = constrained_selection_order(&pts, &viol);
            assert_eq!(order.len(), 20);
            let n_feasible = viol.iter().filter(|&&v| v == 0.0).count();
            for (pos, &i) in order.iter().enumerate() {
                assert_eq!(
                    viol[i] == 0.0,
                    pos < n_feasible,
                    "feasible points must form the order's prefix"
                );
            }
        }
    }
}
