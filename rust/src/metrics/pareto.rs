//! Pareto-dominance analysis for the hardware design-space explorer and the
//! guided search strategies.
//!
//! The explorer scores every hardware variant on several objectives that are
//! all *minimized* (iteration latency, energy per iteration, die area); a
//! variant is worth reporting only if no other variant is at least as good on
//! every objective and strictly better on one. This module provides the
//! dominance predicate, an `O(n^2)` batch frontier extraction over objective
//! vectors, and a streaming [`Frontier`] archive ([`Frontier::insert`] is
//! `O(n)` per point) for search loops that discover candidates
//! incrementally — exact and deterministic, which is what the paper-scale
//! grids (tens to hundreds of points) need. The invariants (no frontier
//! member is dominated; every excluded point is dominated by a frontier
//! member; the streaming archive equals the batch reduction) are
//! property-tested in `tests/prop_invariants.rs`.

/// Returns true iff `a` dominates `b`: `a` is no worse than `b` on every
/// objective and strictly better on at least one. All objectives are
/// minimized and must be finite (NaN never dominates and is never dominated,
/// which would silently corrupt a frontier — feed only finite scores).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len(), "objective arity mismatch");
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Indices of the non-dominated points among `points` (each a vector of
/// minimized objectives of equal arity), in input order.
///
/// Duplicate points do not dominate each other, so all copies of a
/// frontier-worthy point are kept — callers that want one representative can
/// dedup by objective vector afterwards.
pub fn pareto_frontier(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            points
                .iter()
                .enumerate()
                .all(|(j, other)| j == i || !dominates(other, &points[i]))
        })
        .collect()
}

/// For one point, the indices of every point in `points` that dominates it
/// (empty iff the point is on the frontier of `points ∪ {point}`). Used by
/// the explorer to report *how* the paper's Table 2 configuration loses to
/// discovered variants.
pub fn dominators(point: &[f64], points: &[Vec<f64>]) -> Vec<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, other)| dominates(other, point))
        .map(|(i, _)| i)
        .collect()
}

/// Incremental Pareto archive over minimized objective vectors.
///
/// The guided search strategies (`coordinator::search`) discover candidates
/// one generation at a time; re-reducing the full point set after every
/// evaluation would be `O(n^2)` per generation. [`Frontier::insert`] keeps a
/// streaming archive instead: a new point is rejected in one `O(n)` scan if
/// any member dominates it, and otherwise evicts every member it dominates.
/// The final archive equals the batch [`pareto_frontier`] of all inserted
/// points (duplicates of a frontier-worthy point survive together, matching
/// the batch semantics) — property-tested in `tests/prop_invariants.rs`.
///
/// Each entry carries a caller-chosen `usize` key (e.g. a candidate index)
/// so archive membership can be mapped back to the evaluated design points.
///
/// # Examples
///
/// ```
/// use mozart::metrics::pareto::Frontier;
///
/// let mut f = Frontier::new();
/// assert!(f.insert(0, &[1.0, 4.0]));  // first point: always kept
/// assert!(f.insert(1, &[4.0, 1.0]));  // incomparable trade-off: both stay
/// assert!(!f.insert(2, &[5.0, 5.0])); // dominated: rejected
/// assert!(f.insert(3, &[0.5, 0.5]));  // dominates both members
/// assert_eq!(f.keys(), vec![3]);      // the archive collapsed onto it
/// ```
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    entries: Vec<(usize, Vec<f64>)>,
}

impl Frontier {
    /// An empty archive.
    pub fn new() -> Frontier {
        Frontier {
            entries: Vec::new(),
        }
    }

    /// Offer a point to the archive. Returns `true` iff the point was
    /// admitted (no current member dominates it); admission evicts every
    /// member the new point dominates. All objectives are minimized and must
    /// be finite (same contract as [`dominates`]).
    pub fn insert(&mut self, key: usize, objectives: &[f64]) -> bool {
        if let Some((_, first)) = self.entries.first() {
            debug_assert_eq!(first.len(), objectives.len(), "objective arity mismatch");
        }
        if self
            .entries
            .iter()
            .any(|(_, member)| dominates(member, objectives))
        {
            return false;
        }
        self.entries.retain(|(_, member)| !dominates(objectives, member));
        self.entries.push((key, objectives.to_vec()));
        true
    }

    /// Number of archive members.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the archive has no members.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Keys of the current members, sorted ascending (insertion order is an
    /// implementation detail; sorted keys make archive comparisons stable).
    pub fn keys(&self) -> Vec<usize> {
        let mut k: Vec<usize> = self.entries.iter().map(|(key, _)| *key).collect();
        k.sort_unstable();
        k
    }

    /// Iterate over `(key, objectives)` of the current members.
    pub fn iter(&self) -> impl Iterator<Item = (usize, &[f64])> {
        self.entries.iter().map(|(k, o)| (*k, o.as_slice()))
    }

    /// Cheap hypervolume *proxy* against a fixed reference point (worse than
    /// every interesting point, all coordinates > 0): the sum over members
    /// of the normalized box volume `prod_d max(0, (ref_d - obj_d) / ref_d)`.
    /// Overlapping boxes are counted once per member, so this is not the
    /// exact dominated hypervolume — but it is deterministic, `O(n·d)`, and
    /// grows as the archive approaches the reference-relative ideal point,
    /// which is all the per-generation convergence curve needs.
    pub fn hypervolume_proxy(&self, reference: &[f64]) -> f64 {
        self.entries
            .iter()
            .map(|(_, obj)| {
                obj.iter()
                    .zip(reference.iter())
                    .map(|(&v, &r)| ((r - v) / r).max(0.0))
                    .product::<f64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict win
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn frontier_of_a_chain_is_the_minimum() {
        // strictly ordered points: only the best survives
        let pts = vec![vec![3.0, 3.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        assert_eq!(pareto_frontier(&pts), vec![1]);
    }

    #[test]
    fn frontier_keeps_all_tradeoffs() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicates_survive_together() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0], vec![2.0, 0.5]];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(pareto_frontier(&[]).is_empty());
        assert_eq!(pareto_frontier(&[vec![5.0]]), vec![0]);
    }

    #[test]
    fn dominators_of_an_interior_point() {
        let pts = vec![vec![1.0, 1.0], vec![4.0, 4.0], vec![2.0, 5.0]];
        assert_eq!(dominators(&[3.0, 3.0], &pts), vec![0]);
        assert!(dominators(&[0.5, 0.5], &pts).is_empty());
    }

    #[test]
    fn streaming_frontier_matches_batch_on_a_fixed_set() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
            vec![2.0, 2.0], // duplicate of a member: survives alongside it
        ];
        let mut f = Frontier::new();
        for (i, p) in pts.iter().enumerate() {
            f.insert(i, p);
        }
        assert_eq!(f.keys(), pareto_frontier(&pts));
        assert_eq!(f.len(), 4);
        assert!(!f.is_empty());
    }

    #[test]
    fn streaming_insert_evicts_dominated_members() {
        let mut f = Frontier::new();
        assert!(f.insert(7, &[3.0, 3.0]));
        assert!(f.insert(8, &[2.0, 4.0]));
        // dominates key 7 but not key 8
        assert!(f.insert(9, &[2.5, 2.5]));
        assert_eq!(f.keys(), vec![8, 9]);
        // rejected points leave the archive untouched
        assert!(!f.insert(10, &[9.0, 9.0]));
        assert_eq!(f.keys(), vec![8, 9]);
        let got: Vec<(usize, Vec<f64>)> =
            f.iter().map(|(k, o)| (k, o.to_vec())).collect();
        assert!(got.contains(&(9, vec![2.5, 2.5])));
    }

    #[test]
    fn hypervolume_proxy_orders_archives() {
        let reference = [10.0, 10.0];
        let mut near = Frontier::new();
        near.insert(0, &[1.0, 1.0]);
        let mut far = Frontier::new();
        far.insert(0, &[8.0, 8.0]);
        assert!(near.hypervolume_proxy(&reference) > far.hypervolume_proxy(&reference));
        // points at/behind the reference contribute nothing
        let mut behind = Frontier::new();
        behind.insert(0, &[12.0, 3.0]);
        assert_eq!(behind.hypervolume_proxy(&reference), 0.0);
        assert_eq!(Frontier::new().hypervolume_proxy(&reference), 0.0);
    }

    #[test]
    fn three_objectives() {
        let pts = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 1.0, 3.0],
            vec![1.0, 2.0, 4.0], // dominated by the first
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1]);
    }
}
