//! Attention-vs-FFN roofline profiler (paper Appendix C.1, Figures 10-13).
//!
//! The paper profiles one decoder layer of OLMo-2 at four scales (1B / 7B /
//! 13B / 32B), batch 4, sequence lengths {512, 1024, 2048}, and observes
//! that the FFN does *more FLOPs* in *less wall-clock time* than attention:
//! attention is memory-bound (frequent KV/score traffic, as documented by
//! the FlashAttention line of work), the FFN is compute-bound (large
//! parallel matmuls). We reproduce the observation with a roofline model of
//! the A100-80G used in the paper's profiling.

/// OLMo-2 dense decoder shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Olmo2Scale {
    /// OLMo-2 1B.
    B1,
    /// OLMo-2 7B.
    B7,
    /// OLMo-2 13B.
    B13,
    /// OLMo-2 32B.
    B32,
}

impl Olmo2Scale {
    /// The four scales profiled in Appendix C.1.
    pub const ALL: [Olmo2Scale; 4] =
        [Olmo2Scale::B1, Olmo2Scale::B7, Olmo2Scale::B13, Olmo2Scale::B32];

    /// Published model name.
    pub fn name(&self) -> &'static str {
        match self {
            Olmo2Scale::B1 => "OLMo-2-0425-1B",
            Olmo2Scale::B7 => "OLMo-2-1124-7B",
            Olmo2Scale::B13 => "OLMo-2-1124-13B",
            Olmo2Scale::B32 => "OLMo-2-0325-32B",
        }
    }

    /// (hidden, n_heads, ffn_intermediate) of one decoder layer.
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            Olmo2Scale::B1 => (2048, 16, 8192),
            Olmo2Scale::B7 => (4096, 32, 11008),
            Olmo2Scale::B13 => (5120, 40, 13824),
            Olmo2Scale::B32 => (5120, 40, 27648),
        }
    }
}

/// A100-80G roofline parameters (dense BF16).
pub mod a100 {
    /// Peak BF16 tensor-core throughput (FLOP/s).
    pub const PEAK_FLOPS: f64 = 312e12;
    /// HBM2e bandwidth (B/s).
    pub const HBM_BW: f64 = 2.0e12;
    /// Large FFN GEMMs sustain ~75% of tensor-core peak.
    pub const GEMM_EFF: f64 = 0.75;
    /// Eager-mode attention sustains far less: per-head batched matmuls
    /// with head_dim-sized reductions, plus softmax/mask/transpose
    /// elementwise passes, run at a fraction of peak — this is precisely
    /// the memory-bound behaviour the FlashAttention line documents and
    /// the reason the paper calls attention memory-bound (Appendix C.1).
    pub const ATTN_EFF: f64 = 0.18;
    /// Achievable fraction of peak HBM bandwidth under streaming.
    pub const MEM_EFF: f64 = 0.85;
    /// Eager attention round-trips the T x T score tensor several times
    /// (scores write, mask, softmax read+write, dropout, PV read).
    pub const SCORE_PASSES: f64 = 8.0;
}

/// Profile of one module (attention or FFN) of one decoder layer.
#[derive(Clone, Debug)]
pub struct RooflineRow {
    /// OLMo-2 scale profiled.
    pub scale: Olmo2Scale,
    /// Sequence length of the prefill pass.
    pub seq_len: usize,
    /// Attention FLOPs of the layer's forward pass.
    pub attn_flops: f64,
    /// FFN FLOPs of the layer's forward pass.
    pub ffn_flops: f64,
    /// Modeled attention wall-clock (seconds).
    pub attn_latency: f64,
    /// Modeled FFN wall-clock (seconds).
    pub ffn_latency: f64,
}

impl RooflineRow {
    /// The appendix's normalized presentation: shares of FLOPs and latency.
    pub fn flops_share_ffn(&self) -> f64 {
        self.ffn_flops / (self.ffn_flops + self.attn_flops)
    }

    /// FFN share of the layer's wall-clock latency.
    pub fn latency_share_ffn(&self) -> f64 {
        self.ffn_latency / (self.ffn_latency + self.attn_latency)
    }
}

/// Roofline model of one decoder layer's forward (prefill) pass.
pub fn profile_decoder_layer(scale: Olmo2Scale, batch: usize, seq_len: usize) -> RooflineRow {
    let (h, heads, inter) = scale.shape();
    let head_dim = h / heads;
    let tokens = (batch * seq_len) as f64;
    let s = seq_len as f64;
    let bytes = 2.0; // bf16

    // ---- attention ----
    // projections: q,k,v,o = 4 * h*h matmuls
    let proj_flops = tokens * 2.0 * 4.0 * (h * h) as f64;
    // scores + apply: 2 * (T^2 * d) per head per sequence
    let score_flops =
        batch as f64 * heads as f64 * 2.0 * 2.0 * s * s * head_dim as f64;
    let attn_flops = proj_flops + score_flops;
    // memory: weights (4h^2) + activations + the score-matrix traffic that
    // makes attention memory-bound (naive attention materializes S and P
    // and round-trips them several times, cf. FlashAttention's analysis)
    let attn_bytes = (4.0 * (h * h) as f64
        + 6.0 * tokens * h as f64
        + a100::SCORE_PASSES * batch as f64 * heads as f64 * s * s)
        * bytes;

    // ---- FFN ----
    // gated FFN: 3 matmuls h x inter
    let ffn_flops = tokens * 2.0 * 3.0 * (h * inter) as f64;
    let ffn_bytes = (3.0 * (h * inter) as f64 + tokens * (2.0 * h as f64 + inter as f64)) * bytes;

    let lat = |flops: f64, byt: f64, eff: f64| -> f64 {
        (flops / (a100::PEAK_FLOPS * eff)).max(byt / (a100::HBM_BW * a100::MEM_EFF))
    };

    RooflineRow {
        scale,
        seq_len,
        attn_flops,
        ffn_flops,
        attn_latency: lat(attn_flops, attn_bytes, a100::ATTN_EFF),
        ffn_latency: lat(ffn_flops, ffn_bytes, a100::GEMM_EFF),
    }
}

/// Cheap closed-form roofline estimate of one training step's latency for
/// an experiment cell — the surrogate used to rank NSGA-II offspring before
/// full simulation (`--surrogate-frac`).
///
/// Models one MoE layer as the roofline max (with overlap) or sum (without)
/// of its five phases — expert weight streaming, MoE compute, all-to-all,
/// attention compute, attention weight traffic — and scales by the MoE
/// layer count. The all-to-all replication factor C_T uses the expected
/// distinct destinations under uniform top-k routing when token coalescing
/// is on. Absolute values are NOT calibrated against the simulator; only
/// the induced *ranking* of candidates matters, which the search logs as a
/// per-generation Spearman correlation against the true latencies.
pub fn surrogate_step_latency(cfg: &crate::config::ExperimentConfig) -> f64 {
    let model = &cfg.model;
    let hw = &cfg.hw;
    let tokens = (cfg.seq_len * cfg.batch_size) as f64;

    // expected distinct destination groups per token under uniform top-k
    // routing: coalescing sends one copy per distinct destination
    let n = model.n_experts as f64;
    let k = model.top_k as f64;
    let c_t = if cfg.method.efficient_a2a {
        n * (1.0 - (1.0 - 1.0 / n).powf(k))
    } else {
        k
    };

    // per-MoE-layer phase estimates (seconds; bandwidths are GB/s)
    let stream =
        model.expert_layer_bytes() / (hw.n_groups as f64 * hw.group_stream_bw() * 1e9);
    let moe_compute = tokens
        * (model.top_k + model.n_shared_experts) as f64
        * model.flops_per_token_per_expert()
        / (hw.n_moe_chiplets as f64 * hw.moe_chiplet_flops());
    let a2a =
        2.0 * tokens * model.token_activation_bytes() * c_t / (hw.a2a_root_bw() * 1e9);
    let attn_compute =
        tokens * model.attn_flops_per_token(cfg.seq_len) / hw.attn_chiplet_flops();
    let attn_stream = model.attn_layer_bytes() / (hw.attn_dram_bw() * 1e9);

    let phases = [stream, moe_compute, a2a, attn_compute, attn_stream];
    let layer: f64 = if cfg.method.overlap {
        phases.iter().cloned().fold(0.0, f64::max)
    } else {
        phases.iter().sum()
    };
    layer * model.n_moe_layers() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffn_more_flops_less_latency() {
        // the appendix's headline observation, across all scales and seqs
        for scale in Olmo2Scale::ALL {
            for seq in [512, 1024, 2048] {
                let r = profile_decoder_layer(scale, 4, seq);
                assert!(
                    r.ffn_flops > r.attn_flops,
                    "{} seq{}: ffn flops {} !> attn {}",
                    scale.name(),
                    seq,
                    r.ffn_flops,
                    r.attn_flops
                );
                assert!(
                    r.ffn_latency < r.attn_latency,
                    "{} seq{}: ffn lat {} !< attn {}",
                    scale.name(),
                    seq,
                    r.ffn_latency,
                    r.attn_latency
                );
            }
        }
    }

    #[test]
    fn attention_is_memory_bound() {
        // memory-bound behaviour = low achieved arithmetic throughput:
        // attention sustains well under 30% of peak, the FFN well over 60%
        let r = profile_decoder_layer(Olmo2Scale::B7, 4, 1024);
        let attn_achieved = r.attn_flops / r.attn_latency / a100::PEAK_FLOPS;
        let ffn_achieved = r.ffn_flops / r.ffn_latency / a100::PEAK_FLOPS;
        assert!(attn_achieved < 0.30, "attn {attn_achieved}");
        assert!(ffn_achieved > 0.60, "ffn {ffn_achieved}");
    }

    #[test]
    fn ffn_is_compute_bound() {
        let r = profile_decoder_layer(Olmo2Scale::B7, 4, 1024);
        let compute_time = r.ffn_flops / (a100::PEAK_FLOPS * a100::GEMM_EFF);
        assert!((r.ffn_latency - compute_time).abs() / compute_time < 1e-9);
    }

    #[test]
    fn shares_are_consistent() {
        let r = profile_decoder_layer(Olmo2Scale::B1, 4, 512);
        assert!(r.flops_share_ffn() > 0.5);
        assert!(r.latency_share_ffn() < 0.5);
    }

    #[test]
    fn latency_grows_with_seq() {
        let a = profile_decoder_layer(Olmo2Scale::B13, 4, 512);
        let b = profile_decoder_layer(Olmo2Scale::B13, 4, 2048);
        assert!(b.attn_latency > a.attn_latency);
        assert!(b.ffn_latency > a.ffn_latency);
    }

    fn surrogate_cfg() -> crate::config::ExperimentConfig {
        use crate::config::{ExperimentConfig, Method, ModelConfig, ModelId};
        let mut c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::OlmoE_1B_7B),
            Method::MozartC.config(),
        );
        c.seq_len = 64;
        c.iters = 2;
        c
    }

    #[test]
    fn surrogate_is_finite_and_knob_monotone() {
        let base = surrogate_step_latency(&surrogate_cfg());
        assert!(base.is_finite() && base > 0.0);

        // weaker DRAM -> slower estimate; faster clock -> no slower
        let mut slow_dram = surrogate_cfg();
        slow_dram.hw.knobs.dram_eff *= 0.5;
        assert!(surrogate_step_latency(&slow_dram) > base);

        let mut fast_clock = surrogate_cfg();
        fast_clock.hw.freq_ghz *= 2.0;
        assert!(surrogate_step_latency(&fast_clock) <= base);

        // coalescing cannot increase the a2a estimate (C_T <= k)
        let mut no_coalesce = surrogate_cfg();
        no_coalesce.method.efficient_a2a = false;
        no_coalesce.method.overlap = false;
        let mut coalesce = no_coalesce.clone();
        coalesce.method.efficient_a2a = true;
        assert!(surrogate_step_latency(&coalesce) <= surrogate_step_latency(&no_coalesce));
    }

    #[test]
    fn surrogate_ranks_track_the_simulator() {
        // the surrogate only has to *order* candidates like the simulator;
        // sweep the dominant knob (DRAM efficiency — the workload is
        // memory-bound) and check rank agreement
        let mut surrogate = Vec::new();
        let mut simulated = Vec::new();
        for eff in [0.35, 0.55, 0.75, 0.95] {
            let mut c = surrogate_cfg();
            c.hw.knobs.dram_eff = eff;
            surrogate.push(surrogate_step_latency(&c));
            simulated.push(crate::coordinator::run_experiment(&c).latency);
        }
        let rho = crate::util::stats::spearman(&surrogate, &simulated).unwrap();
        assert!(rho > 0.9, "surrogate/simulator Spearman {rho}");
    }
}
