//! Per-step energy accounting from the simulator's byte/FLOP tallies plus
//! static power over the makespan (the paper evaluates latency *and*
//! energy, §5.1).

use crate::arch::area::constants;
use crate::config::ExperimentConfig;
use crate::sim::{SimResult, Tag};

/// Energy decomposition for one training step (Joules).
#[derive(Clone, Debug)]
pub struct EnergyBreakdown {
    /// MAC energy of all compute tasks.
    pub compute_j: f64,
    /// DRAM access energy (weight streaming, activations, optimizer).
    pub dram_j: f64,
    /// NoP link energy (all-to-all phases).
    pub nop_j: f64,
    /// SRAM access energy (modeled as a fraction of compute traffic).
    pub sram_j: f64,
    /// Leakage + idle power over the step's makespan.
    pub static_j: f64,
}

impl EnergyBreakdown {
    /// Total energy of the step (J).
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.dram_j + self.nop_j + self.sram_j + self.static_j
    }

    /// Multiply every component by `s` (iteration averaging).
    pub fn scale(&self, s: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            compute_j: self.compute_j * s,
            dram_j: self.dram_j * s,
            nop_j: self.nop_j * s,
            sram_j: self.sram_j * s,
            static_j: self.static_j * s,
        }
    }

    /// Mean power draw over one step of `makespan_s` seconds (W): total
    /// step energy divided by the step's wall-clock. This is the simulated
    /// *per-configuration* power — it reflects the method (overlap changes
    /// the makespan, the layout changes the traffic) as well as the
    /// platform — and is what the co-design search's `--max-power` budget
    /// caps. Returns 0 for a degenerate zero-length step.
    pub fn mean_power_w(&self, makespan_s: f64) -> f64 {
        if makespan_s > 0.0 {
            self.total_j() / makespan_s
        } else {
            0.0
        }
    }

    /// Component-wise sum.
    pub fn add(&self, other: &EnergyBreakdown) -> EnergyBreakdown {
        EnergyBreakdown {
            compute_j: self.compute_j + other.compute_j,
            dram_j: self.dram_j + other.dram_j,
            nop_j: self.nop_j + other.nop_j,
            sram_j: self.sram_j + other.sram_j,
            static_j: self.static_j + other.static_j,
        }
    }
}

/// Which tags move bytes over DRAM channels vs the NoP tree.
fn is_dram_tag(tag: Tag) -> bool {
    matches!(
        tag,
        Tag::WeightStream
            | Tag::AttnWeightLoad
            | Tag::ActSave
            | Tag::ActLoad
            | Tag::GradWriteback
            | Tag::OptimUpdate
    )
}

fn is_nop_tag(tag: Tag) -> bool {
    matches!(tag, Tag::A2aDispatch | Tag::A2aCombine)
}

/// Compute the energy of one simulated step.
pub fn step_energy(cfg: &ExperimentConfig, res: &SimResult) -> EnergyBreakdown {
    let hw = &cfg.hw;
    let mut dram_bytes = 0.0;
    let mut nop_bytes = 0.0;
    for (tag, b) in res.tag_bytes.iter() {
        if is_dram_tag(tag) {
            dram_bytes += b;
        } else if is_nop_tag(tag) {
            nop_bytes += b;
        }
    }
    let flops = res.tag_flops.sum();

    // MACs = flops / 2; MAC energy from the 28nm constants
    let compute_j = flops / 2.0 * constants::MAC_ENERGY_PJ * 1e-12;
    let dram_j = dram_bytes * hw.mem.dram.energy_pj_per_byte() * 1e-12;
    // every DRAM byte and every a2a byte also traverses NoP links once
    let nop_j = (nop_bytes + dram_bytes) * hw.nop.energy_pj_per_byte * 1e-12;
    // SRAM: activations are read/written locally around each MAC tile;
    // model as operand traffic = 3 words/MAC amortized by tile reuse (~1/8)
    let sram_bytes = flops / 2.0 * 3.0 * 2.0 / 8.0;
    let sram_j = sram_bytes * hw.mem.sram_energy_pj_per_byte * 1e-12;
    // static: leakage of all PEs + switch/NoP idle over the makespan
    let n_pes = hw.n_moe_chiplets as f64
        * hw.moe_chiplet.tiles as f64
        * hw.moe_chiplet.sas_per_tile as f64
        * hw.moe_chiplet.pes_per_sa as f64
        + hw.attn_chiplet.tiles as f64
            * hw.attn_chiplet.sas_per_tile as f64
            * hw.attn_chiplet.pes_per_sa as f64;
    let static_w = n_pes * constants::PE_LEAKAGE_W
        + hw.n_groups as f64 * constants::SWITCH_W
        + constants::NOP_W;
    let static_j = static_w * res.makespan;

    EnergyBreakdown {
        compute_j,
        dram_j,
        nop_j,
        sram_j,
        static_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, MethodConfig, ModelConfig, ModelId};
    use crate::sim::{Plan, Simulator, Tag, TaskSpec};

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::Qwen3_30B_A3B),
            MethodConfig::mozart_c(),
        )
    }

    fn result_with(tag: Tag, bytes: f64, flops: f64, duration: f64) -> SimResult {
        let mut p = Plan::new();
        let r = p.add_resource("r");
        p.add_task(TaskSpec {
            resource: Some(r),
            duration,
            deps: vec![],
            priority: 0,
            tag,
            bytes,
            flops,
        });
        Simulator::run(&p)
    }

    #[test]
    fn dram_bytes_account() {
        let res = result_with(Tag::WeightStream, 1e9, 0.0, 0.01);
        let e = step_energy(&cfg(), &res);
        // 1 GB at 31.2 pJ/B = 31.2 mJ
        assert!((e.dram_j - 1e9 * 31.2e-12).abs() / e.dram_j < 1e-9);
        assert!(e.compute_j == 0.0);
        assert!(e.static_j > 0.0);
    }

    #[test]
    fn compute_flops_account() {
        let res = result_with(Tag::MoeCompute, 0.0, 2e12, 0.01);
        let e = step_energy(&cfg(), &res);
        // 1e12 MACs at 0.56 pJ = 0.56 J
        assert!((e.compute_j - 0.56).abs() < 1e-9, "{}", e.compute_j);
        assert!(e.sram_j > 0.0);
        assert!(e.dram_j == 0.0);
    }

    #[test]
    fn a2a_goes_to_nop() {
        let res = result_with(Tag::A2aDispatch, 1e9, 0.0, 0.001);
        let e = step_energy(&cfg(), &res);
        assert!(e.nop_j > 0.0);
        assert_eq!(e.dram_j, 0.0);
    }

    #[test]
    fn ssd_costs_more_energy_per_byte() {
        let res = result_with(Tag::WeightStream, 1e9, 0.0, 0.01);
        let mut ssd_cfg = cfg();
        ssd_cfg.hw = crate::config::HwConfig::mozart_wafer(crate::config::DramKind::Ssd);
        let hbm = step_energy(&cfg(), &res);
        let ssd = step_energy(&ssd_cfg, &res);
        assert!(ssd.dram_j > hbm.dram_j);
    }

    #[test]
    fn breakdown_arithmetic() {
        let e = EnergyBreakdown {
            compute_j: 1.0,
            dram_j: 2.0,
            nop_j: 3.0,
            sram_j: 4.0,
            static_j: 5.0,
        };
        assert_eq!(e.total_j(), 15.0);
        assert_eq!(e.scale(2.0).total_j(), 30.0);
        assert_eq!(e.add(&e).total_j(), 30.0);
        // 15 J over a 3 s step = 5 W; zero-length steps draw nothing
        assert_eq!(e.mean_power_w(3.0), 5.0);
        assert_eq!(e.mean_power_w(0.0), 0.0);
    }
}
