//! Expert-parallel GPU-cluster simulator (paper Appendix C.2, Figures
//! 14-16): a MegaBlocks-style 4-way expert-parallel fine-tuning run of
//! OLMoE, with data-parallel attention, monitored at a 0.1 s interval. The
//! paper uses this to motivate Mozart's challenges — GPU power and memory
//! consumption are highly dynamic because per-expert workloads fluctuate.
//!
//! We reproduce the monitor traces: per-GPU power (W) and memory (GiB)
//! time-series whose dynamism (coefficient of variation, range) exhibits
//! the same qualitative behaviour the paper's nvidia-smi traces show.

use crate::config::ModelConfig;
use crate::trace::TraceGen;
use crate::util::rng::Rng;
use crate::util::stats;

/// One monitored sample per GPU.
#[derive(Clone, Debug)]
pub struct GpuSample {
    /// Sample timestamp (seconds since run start).
    pub t: f64,
    /// Instantaneous power draw per GPU (W).
    pub power_w: Vec<f64>,
    /// Allocated memory per GPU (GiB).
    pub mem_gib: Vec<f64>,
}

/// Config for the expert-parallel run (paper: OLMoE, 4-way EP, batch 8 per
/// GPU, seq 512, dropless MoE, 2-3 iter/s, 0.1 s monitor interval).
#[derive(Clone, Debug)]
pub struct EpSimConfig {
    /// GPUs in the expert-parallel group.
    pub n_gpus: usize,
    /// Samples per GPU per training step.
    pub batch_per_gpu: usize,
    /// Sequence length per sample.
    pub seq_len: usize,
    /// Monitor sampling interval (seconds).
    pub monitor_interval: f64,
    /// Training throughput (iterations per second).
    pub iters_per_sec: f64,
    /// GPU TDP (A100 80G: 400 W) and idle floor.
    pub tdp_w: f64,
    /// Idle power floor (W).
    pub idle_w: f64,
    /// Baseline memory per GPU: weights shard + optimizer + framework (GiB).
    pub static_mem_gib: f64,
}

impl Default for EpSimConfig {
    fn default() -> Self {
        EpSimConfig {
            n_gpus: 4,
            batch_per_gpu: 8,
            seq_len: 512,
            monitor_interval: 0.1,
            iters_per_sec: 2.5,
            tdp_w: 400.0,
            idle_w: 60.0,
            static_mem_gib: 28.0,
        }
    }
}

/// Simulate `duration_s` seconds of training and return the monitor trace.
///
/// Per iteration, the routing trace determines each GPU's expert workload
/// share; within the iteration the GPU cycles through phases (attention /
/// all-to-all / expert FFN / backward) whose power draw differs, and
/// activation memory is allocated and freed per expert batch (dropless MoE
/// over-allocates for the hottest expert).
pub fn simulate(
    model: &ModelConfig,
    cfg: &EpSimConfig,
    duration_s: f64,
    seed: u64,
) -> Vec<GpuSample> {
    let gen = TraceGen::for_model(model, seed);
    let mut rng = Rng::new(seed ^ 0xE9A5);
    let tokens = cfg.n_gpus * cfg.batch_per_gpu * cfg.seq_len;
    let experts_per_gpu = model.n_experts / cfg.n_gpus;
    let iter_time = 1.0 / cfg.iters_per_sec;

    let n_samples = (duration_s / cfg.monitor_interval).round() as usize;
    let mut out = Vec::with_capacity(n_samples);
    let mut iter_idx = 0u64;
    // per-iteration per-GPU workload shares + phase schedule
    let mut shares = vec![1.0 / cfg.n_gpus as f64; cfg.n_gpus];
    let mut peak_expert = vec![0.0f64; cfg.n_gpus];
    for sample_idx in 0..n_samples {
        let t = sample_idx as f64 * cfg.monitor_interval;
        // resample routing at iteration boundaries
        if (t / iter_time) as u64 >= iter_idx {
            iter_idx = (t / iter_time) as u64 + 1;
            let layer = (iter_idx as usize * 7) % model.n_moe_layers();
            let mut r = rng.fork(iter_idx);
            let tr = gen.sample_layer(layer, tokens, &mut r);
            let counts = tr.expert_token_counts();
            let total: u64 = counts.iter().sum();
            for g in 0..cfg.n_gpus {
                let gpu_slots: u64 = counts
                    [g * experts_per_gpu..(g + 1) * experts_per_gpu]
                    .iter()
                    .sum();
                shares[g] = gpu_slots as f64 / total as f64;
                peak_expert[g] = counts[g * experts_per_gpu..(g + 1) * experts_per_gpu]
                    .iter()
                    .cloned()
                    .max()
                    .unwrap_or(0) as f64
                    / total as f64;
            }
        }
        // phase within the iteration: attention (dense, high power on all),
        // all-to-all (low power), expert FFN (power follows workload share),
        // backward (mix).
        let phase = (t % iter_time) / iter_time;
        let mut power = Vec::with_capacity(cfg.n_gpus);
        let mut mem = Vec::with_capacity(cfg.n_gpus);
        for g in 0..cfg.n_gpus {
            let rel = shares[g] * cfg.n_gpus as f64; // 1.0 = balanced
            let p = if phase < 0.18 {
                // attention fwd: data parallel, near-uniform high draw
                0.78 * cfg.tdp_w
            } else if phase < 0.26 {
                // all-to-all: communication-bound, low draw
                0.25 * cfg.tdp_w
            } else if phase < 0.48 {
                // expert FFN fwd: draw tracks this GPU's workload share
                (0.35 + 0.5 * rel.min(1.6)) * cfg.tdp_w * 0.7
            } else if phase < 0.56 {
                0.25 * cfg.tdp_w // grad all-to-all
            } else {
                // backward: 2x expert work + attention
                (0.40 + 0.45 * rel.min(1.6)) * cfg.tdp_w * 0.8
            };
            let jitter = 1.0 + 0.05 * rng.normal();
            power.push((p * jitter).clamp(cfg.idle_w, cfg.tdp_w));

            // memory: static + activations; dropless MoE sizes buffers for
            // the peak expert, so memory tracks the hottest expert's share
            let act_gib = 14.0 * rel + 30.0 * peak_expert[g] * experts_per_gpu as f64;
            let m = cfg.static_mem_gib
                + act_gib * (0.4 + 0.6 * (phase * std::f64::consts::PI).sin().abs());
            mem.push(m.min(80.0));
        }
        out.push(GpuSample {
            t,
            power_w: power,
            mem_gib: mem,
        });

    }
    out
}

/// Dynamism summary used by the report: per-GPU coefficient of variation
/// for power and memory, plus ranges.
#[derive(Clone, Debug)]
pub struct DynamismSummary {
    /// Coefficient of variation of each GPU's power trace.
    pub power_cv: Vec<f64>,
    /// Coefficient of variation of each GPU's memory trace.
    pub mem_cv: Vec<f64>,
    /// (min, max) power per GPU (W).
    pub power_range: Vec<(f64, f64)>,
    /// (min, max) memory per GPU (GiB).
    pub mem_range: Vec<(f64, f64)>,
}

/// Reduce a monitor trace to the per-GPU dynamism statistics the Figures
/// 14-16 report prints.
pub fn summarize(samples: &[GpuSample]) -> DynamismSummary {
    assert!(!samples.is_empty());
    let n_gpus = samples[0].power_w.len();
    let mut power_cv = Vec::new();
    let mut mem_cv = Vec::new();
    let mut power_range = Vec::new();
    let mut mem_range = Vec::new();
    for g in 0..n_gpus {
        let p: Vec<f64> = samples.iter().map(|s| s.power_w[g]).collect();
        let m: Vec<f64> = samples.iter().map(|s| s.mem_gib[g]).collect();
        power_cv.push(stats::cv(&p));
        mem_cv.push(stats::cv(&m));
        power_range.push((stats::min(&p), stats::max(&p)));
        mem_range.push((stats::min(&m), stats::max(&m)));
    }
    DynamismSummary {
        power_cv,
        mem_cv,
        power_range,
        mem_range,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelId};

    fn run(dur: f64) -> Vec<GpuSample> {
        let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
        simulate(&m, &EpSimConfig::default(), dur, 17)
    }

    #[test]
    fn trace_shape() {
        let s = run(5.0);
        assert_eq!(s.len(), 50);
        assert_eq!(s[0].power_w.len(), 4);
        assert_eq!(s[0].mem_gib.len(), 4);
    }

    #[test]
    fn power_within_physical_bounds() {
        for s in run(10.0) {
            for &p in &s.power_w {
                assert!((60.0..=400.0).contains(&p), "p={p}");
            }
            for &m in &s.mem_gib {
                assert!(m > 0.0 && m <= 80.0, "m={m}");
            }
        }
    }

    #[test]
    fn exhibits_dynamism() {
        // the paper's point: both power and memory fluctuate strongly
        let s = run(20.0);
        let d = summarize(&s);
        for g in 0..4 {
            assert!(d.power_cv[g] > 0.15, "gpu{g} power cv {}", d.power_cv[g]);
            assert!(d.mem_cv[g] > 0.05, "gpu{g} mem cv {}", d.mem_cv[g]);
            assert!(d.power_range[g].1 - d.power_range[g].0 > 100.0);
        }
    }

    #[test]
    fn deterministic() {
        let a = run(3.0);
        let b = run(3.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.power_w, y.power_w);
        }
    }
}
