//! Build the task-DAG plan of one training step (forward + backward +
//! optimizer) for the discrete-event simulator.
//!
//! Resources (paper §4.4):
//! - `attn-compute` — the attention chiplet's systolic arrays (also hosts
//!   the router, shared experts, and DeepSeek's dense layer-0 FFN).
//! - `attn-dram` — the two HBM stacks private to the attention chiplet.
//! - `group-stream[g]` — the weight-streaming path of MoE group `g`:
//!   shared DRAM I/O -> switch -> chiplet ingress links.
//! - `moe-compute[c]` — each MoE chiplet's arrays (experts on one chiplet
//!   execute sequentially, paper §4.3).
//! - `nop-root` — the serialized all-to-all path at the tree root (the
//!   phase is synchronous across all chiplets, paper §4.2).
//!
//! Method semantics (paper Table 3):
//! - **overlap off** (Baseline): intra-layer phase barriers — attention,
//!   dispatch, weight load, expert compute, combine, activation save run as
//!   strictly serial phases; no cross-layer prefetch.
//! - **overlap on** (A/B/C): streaming experts (per-expert load chunks,
//!   hot clusters first, cross-layer prefetch bounded by the SRAM
//!   double-buffer) + streaming tokens (per-micro-batch pipelining) +
//!   fire-and-forget activation saves.
//! - **efficient_a2a** (B/C): replica elision is already in the workload's
//!   `replicas`; here it additionally enables in-network switch aggregation
//!   of the combine stage.
//! - **expert_layout** (C): enters via the workload statistics (balanced
//!   `chiplet_slots`/`expert_slots`) and the cluster-priority order.
//!
//! # Plan-topology caching
//!
//! `run_experiment` simulates `iters` steps of the *same* configuration;
//! across iterations only the sampled routing workload changes. The
//! expensive workload-independent derivations — resource setup, the
//! byte/FLOP model, per-layer expert placement, calibration constants —
//! are therefore hoisted into a [`PlanCache`] built once per experiment.
//! Each iteration then calls [`PlanCache::rebuild`], a cheap re-emission
//! pass over a reusable arena: the `Plan`'s task vector and every task's
//! dependency vector are recycled from the previous iteration instead of
//! reallocated. The emission order, priorities, dependencies and durations
//! are identical to a fresh [`build_step_plan`] call, so cached rebuilds
//! are bit-identical to the uncached path (covered by a test below).

use crate::allocation::ExpertLayout;
use crate::config::ExperimentConfig;
use crate::sim::{Plan, ResourceId, Tag, TaskId, TaskSpec};

use super::workload::{LayerBytes, StepWorkload};

/// Everything the builder needs for one step. `layouts[l]` is the expert
/// placement of MoE layer `l` (the paper maps each decoder layer's experts
/// to chiplets independently, Figure 2).
pub struct StepInputs<'a> {
    /// The experiment configuration being simulated.
    pub cfg: &'a ExperimentConfig,
    /// Per-MoE-layer expert placements.
    pub layouts: &'a [ExpertLayout],
    /// The step's sampled routing workload.
    pub workload: &'a StepWorkload,
}

struct Resources {
    attn_compute: ResourceId,
    attn_dram: ResourceId,
    group_stream: Vec<ResourceId>,
    moe_compute: Vec<ResourceId>,
    nop_root: ResourceId,
}

/// Duration helpers with all calibration knobs *and* fault effects applied.
///
/// Fault health factors enter multiplicatively and default to 1.0, so the
/// healthy path computes `bw * 1.0` everywhere — bitwise identical to the
/// pre-fault-model formulas (for finite positive `x`, `x * 1.0 == x`
/// exactly, and `min`/`max`/division commute with the no-op scaling).
struct Durations {
    /// seconds per byte on group `g`'s stream path (DRAM throttling and
    /// degraded ingress links slow individual groups independently).
    group_stream_spb: Vec<f64>,
    /// seconds per byte on the attention DRAM channels.
    attn_dram_spb: f64,
    /// seconds per byte on the serialized a2a root path, inflated by the
    /// flow-level contention slowdown of the degraded NoP tree (exactly
    /// 1.0 on a healthy tree — see [`NopTree::a2a_slowdown`]).
    ///
    /// [`NopTree::a2a_slowdown`]: crate::comm::NopTree::a2a_slowdown
    a2a_spb: f64,
    /// seconds per FLOP on MoE chiplet `c` (HB-link degradation starves
    /// the arrays of operands, scaling sustained throughput).
    moe_spf: Vec<f64>,
    /// seconds per FLOP on the attention chiplet.
    attn_spf: f64,
    chunk_overhead: f64,
    a2a_occupancy: f64,
    switch_agg: f64,
    opt_factor: f64,
}

impl Durations {
    fn new(cfg: &ExperimentConfig, fx: &crate::comm::FaultEffects) -> Durations {
        let hw = &cfg.hw;
        let per = hw.chiplets_per_group();
        let group_stream_spb = (0..hw.n_groups)
            .map(|g| {
                let dram = hw.group_dram_bw() * fx.dram_health[g];
                let nop = hw.chiplet_nop_bw()
                    * fx.group_leaf_health(g, per)
                    * hw.knobs.group_concurrency as f64;
                1.0 / (dram.min(nop) * 1e9)
            })
            .collect();
        let moe_spf = (0..hw.n_moe_chiplets)
            .map(|c| 1.0 / (hw.moe_chiplet_flops() * fx.compute_health[c]))
            .collect();
        let a2a_slowdown = crate::comm::NopTree::with_faults(hw, fx).a2a_slowdown();
        Durations {
            group_stream_spb,
            attn_dram_spb: 1.0 / (hw.attn_dram_bw() * 1e9),
            a2a_spb: a2a_slowdown / (hw.a2a_root_bw() * 1e9),
            moe_spf,
            attn_spf: 1.0 / hw.attn_chiplet_flops(),
            chunk_overhead: hw.knobs.chunk_overhead_us * 1e-6,
            a2a_occupancy: hw.knobs.a2a_link_occupancy,
            switch_agg: if cfg.method.efficient_a2a {
                hw.knobs.switch_agg_factor
            } else {
                1.0
            },
            opt_factor: hw.knobs.opt_traffic_factor,
        }
    }
}

/// Pop a recycled dependency vector (always empty) or allocate a new one.
fn take_deps(spare: &mut Vec<Vec<TaskId>>) -> Vec<TaskId> {
    spare.pop().unwrap_or_default()
}

/// Copy `deps` into a recycled vector.
fn deps_from(spare: &mut Vec<Vec<TaskId>>, deps: &[TaskId]) -> Vec<TaskId> {
    let mut d = take_deps(spare);
    d.extend_from_slice(deps);
    d
}

/// Barrier/convenience task mirroring `Plan::task`, over the arena.
fn emit_simple(
    plan: &mut Plan,
    spare: &mut Vec<Vec<TaskId>>,
    tag: Tag,
    resource: Option<ResourceId>,
    duration: f64,
    deps: &[TaskId],
) -> TaskId {
    let deps = deps_from(spare, deps);
    plan.add_task(TaskSpec {
        resource,
        duration,
        deps,
        priority: 0,
        tag,
        bytes: 0.0,
        flops: 0.0,
    })
}

/// Emit an all-to-all phase: one serialized task on the NoP root plus link-
/// occupancy tasks on every group's stream path (the a2a shares the chiplet
/// ingress edges with weight streaming). Returns the root task id (the
/// barrier other tasks depend on).
#[allow(clippy::too_many_arguments)]
fn a2a_phase(
    plan: &mut Plan,
    spare: &mut Vec<Vec<TaskId>>,
    res: &Resources,
    dur: &Durations,
    tag: Tag,
    bytes: f64,
    deps: &[TaskId],
    occupancy_deps: &mut Vec<TaskId>,
    priority: i64,
) -> TaskId {
    let window = bytes * dur.a2a_spb;
    let root_deps = deps_from(spare, deps);
    let root = plan.add_task(TaskSpec {
        resource: Some(res.nop_root),
        duration: window,
        deps: root_deps,
        priority,
        tag,
        bytes,
        flops: 0.0,
    });
    if dur.a2a_occupancy > 0.0 {
        for &g in &res.group_stream {
            let occ_deps = deps_from(spare, deps);
            let t = plan.add_task(TaskSpec {
                resource: Some(g),
                duration: window * dur.a2a_occupancy,
                deps: occ_deps,
                priority,
                tag,
                bytes: 0.0, // energy is accounted on the root task
                flops: 0.0,
            });
            occupancy_deps.push(t);
        }
    }
    root
}

/// One-time topology build + reusable arena for per-iteration re-emission.
/// See the module docs for the caching contract.
pub struct PlanCache {
    cfg: ExperimentConfig,
    plan: Plan,
    /// Recycled dependency vectors harvested from the previous rebuild.
    spare: Vec<Vec<TaskId>>,
    res: Resources,
    dur: Durations,
    lb: LayerBytes,
    n_mb: usize,
    n_layers: usize,
    tokens_mb: f64,
    token_bytes: f64,
    expert_flops: f64,
    attn_flops_tok: f64,
    shared_flops_tok: f64,
    dense_flops_tok: f64,
    /// `experts_on[l][c]`: experts placed on chiplet `c` in layer `l`
    /// (cluster members) — derived from the layout, workload-independent.
    experts_on: Vec<Vec<Vec<usize>>>,
    /// `group_of[l][c]`: group of chiplet `c` in layer `l`.
    group_of: Vec<Vec<usize>>,
}

impl PlanCache {
    /// Derive every workload-independent quantity once: resources, the
    /// byte/FLOP model, calibration constants, and per-layer placements.
    pub fn new(cfg: &ExperimentConfig, layouts: &[ExpertLayout]) -> PlanCache {
        let model = &cfg.model;
        let hw = &cfg.hw;
        let n_layers = model.n_moe_layers();
        assert_eq!(layouts.len(), n_layers, "one layout per MoE layer");

        let mut plan = Plan::new();
        let res = Resources {
            attn_compute: plan.add_resource("attn-compute"),
            attn_dram: plan.add_resource("attn-dram"),
            group_stream: (0..hw.n_groups)
                .map(|g| plan.add_resource(format!("group-stream-{g}")))
                .collect(),
            moe_compute: (0..hw.n_moe_chiplets)
                .map(|c| plan.add_resource(format!("moe-compute-{c}")))
                .collect(),
            nop_root: plan.add_resource("nop-root"),
        };

        let fx = cfg.fault.effects(hw.n_moe_chiplets, hw.n_groups);

        let mut experts_on: Vec<Vec<Vec<usize>>> = Vec::with_capacity(n_layers);
        let mut group_of: Vec<Vec<usize>> = Vec::with_capacity(n_layers);
        for layout in layouts {
            let nc = layout.n_chiplets;
            let mut on: Vec<Vec<usize>> = vec![Vec::new(); nc];
            for (e, &c) in layout.expert_to_chiplet.iter().enumerate() {
                on[c].push(e);
            }
            for &c in &fx.dead() {
                assert!(
                    on[c].is_empty(),
                    "chiplet {c} is dead but still hosts experts — \
                     apply ExpertLayout::spill_dead before building the plan"
                );
            }
            experts_on.push(on);
            group_of.push((0..nc).map(|c| layout.group_of_chiplet(c)).collect());
        }

        let expert_flops = model.flops_per_token_per_expert() as f64;
        let attn_flops_tok = model.attn_flops_per_token(cfg.seq_len) as f64;
        let shared_flops_tok = model.n_shared_experts as f64 * expert_flops;
        let dense_flops_tok = 2.0 * 3.0 * (model.hidden * model.dense_intermediate) as f64;

        PlanCache {
            plan,
            spare: Vec::new(),
            res,
            dur: Durations::new(cfg, &fx),
            lb: LayerBytes::of(cfg),
            n_mb: cfg.n_micro_batches(),
            n_layers,
            tokens_mb: cfg.tokens_per_micro_batch() as f64,
            token_bytes: model.token_activation_bytes() as f64,
            expert_flops,
            attn_flops_tok,
            shared_flops_tok,
            dense_flops_tok,
            experts_on,
            group_of,
            cfg: cfg.clone(),
        }
    }

    /// Re-time the cached topology for a config that differs from the
    /// cached one only in calibration knobs, core clock, and/or fault
    /// severities that leave the dead-chiplet set unchanged (the
    /// `coordinator::cache` delta re-timing path). The duration constants
    /// are the *only* knob/frequency/fault-severity-dependent state in the
    /// cache — placements, the plan arena, and the byte/FLOP model are all
    /// derived from topology fields — so recomputing [`Durations`] and
    /// swapping in the new config makes a subsequent [`PlanCache::rebuild`]
    /// emit exactly what a fresh [`PlanCache::new`] for `cfg` would emit
    /// (asserted bit-for-bit in the tests below).
    ///
    /// The caller is responsible for only re-timing across configs with
    /// equal topology fingerprints (`HwConfig::fingerprint().topo` plus
    /// model/method/workload/seed and the fault dead-set); the debug
    /// assertion catches dead-set drift, which would leave experts homed on
    /// chiplets the new scenario kills.
    pub fn retime(&mut self, cfg: &ExperimentConfig) {
        let fx = cfg.fault.effects(cfg.hw.n_moe_chiplets, cfg.hw.n_groups);
        debug_assert_eq!(
            fx.dead(),
            self.cfg
                .fault
                .effects(self.cfg.hw.n_moe_chiplets, self.cfg.hw.n_groups)
                .dead(),
            "retime across different dead-chiplet sets (topology change)"
        );
        self.dur = Durations::new(cfg, &fx);
        self.cfg = cfg.clone();
    }

    /// The most recently rebuilt plan.
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Consume the cache, returning the current plan.
    pub fn into_plan(self) -> Plan {
        self.plan
    }

    /// Re-emit the step plan for a freshly sampled workload, recycling all
    /// task/dependency storage from the previous rebuild. Emission order
    /// and every task field match `build_step_plan` exactly.
    pub fn rebuild(&mut self, workload: &StepWorkload) -> &Plan {
        assert_eq!(
            workload.cells.len(),
            self.n_layers,
            "workload layers must match the cached topology"
        );

        let n_mb = self.n_mb;
        let n_layers = self.n_layers;
        let tokens_mb = self.tokens_mb;
        let token_bytes = self.token_bytes;
        let expert_flops = self.expert_flops;
        let attn_flops_tok = self.attn_flops_tok;
        let shared_flops_tok = self.shared_flops_tok;
        let dense_flops_tok = self.dense_flops_tok;

        let PlanCache {
            cfg,
            plan,
            spare,
            res,
            dur,
            lb,
            experts_on,
            group_of,
            ..
        } = self;
        let cfg: &ExperimentConfig = cfg;
        let hw = &cfg.hw;
        let model = &cfg.model;
        let overlap = cfg.method.overlap;

        // recycle the arena: harvest every task's dependency vector
        for t in plan.tasks.drain(..) {
            let mut d = t.deps;
            d.clear();
            spare.push(d);
        }

        // per-layer load priority: rank chiplets by this step's workload,
        // hot clusters first (streaming-experts ranking, paper §4.3)
        let load_prio: Vec<Vec<i64>> = (0..n_layers)
            .map(|l| {
                let nc = experts_on[l].len();
                let mut chiplet_work = vec![0u64; nc];
                for cell in &workload.cells[l] {
                    for (c, &s) in cell.chiplet_slots.iter().enumerate() {
                        chiplet_work[c] += s;
                    }
                }
                let mut order: Vec<usize> = (0..nc).collect();
                order.sort_by_key(|&c| std::cmp::Reverse(chiplet_work[c]));
                let mut lp = vec![0i64; nc];
                for (rank, &c) in order.iter().enumerate() {
                    lp[c] = rank as i64;
                }
                lp
            })
            .collect();

        // ---------- forward ----------
        // prev_out[m]: task producing micro-batch m's input to the current layer
        let mut prev_out: Vec<Option<TaskId>> = vec![None; n_mb];
        // free[c][e-slot]: last fwd compute using chiplet c's expert weights for
        // the current layer (gates the cross-layer prefetch of the next layer)
        let mut weight_free: Vec<Vec<TaskId>> = vec![Vec::new(); hw.n_moe_chiplets];
        // combine ids per (layer, mb) — backward consumes them in reverse
        let mut fwd_combine: Vec<Vec<TaskId>> = Vec::with_capacity(n_layers);
        // fwd act-save tasks per layer (backward's act loads depend on them)
        let mut fwd_actsaves: Vec<Vec<TaskId>> = Vec::with_capacity(n_layers);

        // DeepSeek-style dense layers run entirely on the attention chiplet
        // before the MoE stack; fold them into a prologue task per micro-batch.
        for (m, prev) in prev_out.iter_mut().enumerate() {
            if model.n_dense_layers > 0 {
                let flops = model.n_dense_layers as f64
                    * tokens_mb
                    * (attn_flops_tok + dense_flops_tok);
                let t = plan.add_task(TaskSpec {
                    resource: Some(res.attn_compute),
                    duration: flops * dur.attn_spf,
                    deps: take_deps(spare),
                    priority: m as i64,
                    tag: Tag::AttnCompute,
                    bytes: 0.0,
                    flops,
                });
                *prev = Some(t);
            }
        }

        for l in 0..n_layers {
            let cells = &workload.cells[l];

            // attention weight load (one per layer)
            let attn_wload = plan.add_task(TaskSpec {
                resource: Some(res.attn_dram),
                duration: lb.attn_bytes * dur.attn_dram_spb,
                deps: take_deps(spare),
                priority: l as i64,
                tag: Tag::AttnWeightLoad,
                bytes: lb.attn_bytes,
                flops: 0.0,
            });

            // expert weight streaming: per-expert chunks on the group channel,
            // hot clusters first (streaming experts). Cross-layer prefetch is
            // bounded by the SRAM double-buffer: an expert's layer-(l) weights
            // can start loading once its layer-(l-1) compute finished.
            let mut chiplet_loaded: Vec<Vec<TaskId>> =
                vec![Vec::new(); hw.n_moe_chiplets];
            let mut load_barrier_deps: Vec<TaskId> = Vec::new();
            for c in 0..hw.n_moe_chiplets {
                let g = group_of[l][c];
                for (slot, &_e) in experts_on[l][c].iter().enumerate() {
                    let mut deps = take_deps(spare);
                    if overlap {
                        if let Some(&prev_use) = weight_free[c].get(slot) {
                            deps.push(prev_use); // double-buffer constraint
                        }
                    }
                    // baseline: no prefetch — loads wait for the layer's last
                    // dispatch (strict phase order), wired below via barrier.
                    let t = plan.add_task(TaskSpec {
                        resource: Some(res.group_stream[g]),
                        duration: lb.expert_bytes * dur.group_stream_spb[g]
                            + dur.chunk_overhead,
                        deps,
                        priority: if overlap {
                            load_prio[l][c] * 1000 + l as i64
                        } else {
                            0
                        },
                        tag: Tag::WeightStream,
                        bytes: lb.expert_bytes,
                        flops: 0.0,
                    });
                    chiplet_loaded[c].push(t);
                    load_barrier_deps.push(t);
                }
            }

            let mut attn_tasks: Vec<TaskId> = Vec::with_capacity(n_mb);
            let mut dispatch_tasks: Vec<TaskId> = Vec::with_capacity(n_mb);
            let mut occupancy: Vec<TaskId> = Vec::new();
            let mut layer_combines: Vec<TaskId> = Vec::with_capacity(n_mb);
            let mut layer_actsaves: Vec<TaskId> = Vec::new();
            let mut new_weight_free: Vec<Vec<TaskId>> =
                vec![Vec::new(); hw.n_moe_chiplets];

            // phase barrier chain for the baseline
            let mut phase_gate: Option<TaskId> = None;

            for m in 0..n_mb {
                // attention + router (+ shared experts)
                let mut deps = take_deps(spare);
                deps.push(attn_wload);
                if let Some(p) = prev_out[m] {
                    deps.push(p);
                }
                if !overlap {
                    if let Some(g) = phase_gate {
                        deps.push(g);
                    }
                }
                let flops = tokens_mb * (attn_flops_tok + shared_flops_tok)
                    + tokens_mb * (model.hidden * model.n_experts) as f64 * 2.0;
                let attn = plan.add_task(TaskSpec {
                    resource: Some(res.attn_compute),
                    duration: flops * dur.attn_spf,
                    deps,
                    priority: (l * 16 + m) as i64,
                    tag: Tag::AttnCompute,
                    bytes: 0.0,
                    flops,
                });
                attn_tasks.push(attn);

                // attention activation save (for backward)
                let asave = plan.add_task(TaskSpec {
                    resource: Some(res.attn_dram),
                    duration: tokens_mb * lb.attn_act_bytes_per_token * dur.attn_dram_spb,
                    deps: deps_from(spare, &[attn]),
                    priority: (l * 16 + m) as i64 + 1,
                    tag: Tag::ActSave,
                    bytes: tokens_mb * lb.attn_act_bytes_per_token,
                    flops: 0.0,
                });
                layer_actsaves.push(asave);
            }

            if !overlap {
                // phase: all attention done before any dispatch
                let gate = emit_simple(plan, spare, Tag::Barrier, None, 0.0, &attn_tasks);
                phase_gate = Some(gate);
            }

            for m in 0..n_mb {
                let cell = &cells[m];
                let dispatch_bytes = cell.replicas as f64 * token_bytes;
                let deps: &[TaskId] = if overlap {
                    &attn_tasks[m..m + 1]
                } else {
                    std::slice::from_ref(phase_gate.as_ref().unwrap())
                };
                let d = a2a_phase(
                    plan,
                    spare,
                    res,
                    dur,
                    Tag::A2aDispatch,
                    dispatch_bytes,
                    deps,
                    &mut occupancy,
                    (l * 16 + m) as i64,
                );
                dispatch_tasks.push(d);
            }

            if !overlap {
                // phase: weight loads happen after all dispatches (no prefetch)
                let mut gd = deps_from(spare, &dispatch_tasks);
                gd.push(phase_gate.unwrap());
                let gate = plan.add_task(TaskSpec {
                    resource: None,
                    duration: 0.0,
                    deps: gd,
                    priority: 0,
                    tag: Tag::Barrier,
                    bytes: 0.0,
                    flops: 0.0,
                });
                // loads were created dep-free in baseline mode; patch the
                // phase gate in as a dependency now.
                for loaded in chiplet_loaded.iter().take(hw.n_moe_chiplets) {
                    for &t in loaded {
                        plan.tasks[t].deps.push(gate);
                    }
                }
            }

            // expert compute: per (chiplet, expert, micro-batch); an expert's
            // compute needs its own weights only (fine-grained streaming).
            let load_gate = if overlap {
                None
            } else {
                // baseline: all weights of the layer loaded before any compute
                Some(emit_simple(
                    plan,
                    spare,
                    Tag::Barrier,
                    None,
                    0.0,
                    &load_barrier_deps,
                ))
            };
            let mut mb_compute: Vec<Vec<TaskId>> = vec![Vec::new(); n_mb];
            for c in 0..hw.n_moe_chiplets {
                for (slot, &e) in experts_on[l][c].iter().enumerate() {
                    for m in 0..n_mb {
                        let slots = cells[m].expert_slots[e] as f64;
                        if slots == 0.0 && overlap {
                            continue; // no tokens for this expert in this mb
                        }
                        let mut deps = take_deps(spare);
                        deps.push(dispatch_tasks[m]);
                        match load_gate {
                            Some(g) => deps.push(g),
                            None => deps.push(chiplet_loaded[c][slot]),
                        }
                        let flops = slots * expert_flops;
                        let t = plan.add_task(TaskSpec {
                            resource: Some(res.moe_compute[c]),
                            duration: flops * dur.moe_spf[c],
                            deps,
                            priority: (m * 64 + slot) as i64,
                            tag: Tag::MoeCompute,
                            bytes: 0.0,
                            flops,
                        });
                        mb_compute[m].push(t);
                        if m == n_mb - 1 {
                            new_weight_free[c].push(t);
                        }
                    }
                }
                // chiplets whose experts saw no tokens still free their buffers
                for slot in 0..experts_on[l][c].len() {
                    if new_weight_free[c].len() <= slot {
                        new_weight_free[c].push(chiplet_loaded[c][slot]);
                    }
                }
            }

            // MoE activation saves: per (group, mb) on the group channel
            for m in 0..n_mb {
                let per = hw.chiplets_per_group();
                for g in 0..hw.n_groups {
                    let slots: u64 = cells[m].chiplet_slots[g * per..(g + 1) * per]
                        .iter()
                        .sum();
                    if slots == 0 {
                        continue;
                    }
                    let bytes = slots as f64 * lb.moe_act_bytes_per_slot;
                    let deps = deps_from(spare, &mb_compute[m]);
                    let t = plan.add_task(TaskSpec {
                        resource: Some(res.group_stream[g]),
                        duration: bytes * dur.group_stream_spb[g],
                        deps,
                        priority: 500_000 + (l * 16 + m) as i64,
                        tag: Tag::ActSave,
                        bytes,
                        flops: 0.0,
                    });
                    layer_actsaves.push(t);
                }
            }

            // combine: switch-aggregated return of expert outputs
            for m in 0..n_mb {
                let cell = &cells[m];
                let combine_bytes = cell.replicas as f64 * token_bytes / dur.switch_agg;
                let mut deps = deps_from(spare, &mb_compute[m]);
                if !overlap {
                    // phase order: activation saves complete before combine
                    deps.extend(layer_actsaves.iter());
                }
                let cmb = a2a_phase(
                    plan,
                    spare,
                    res,
                    dur,
                    Tag::A2aCombine,
                    combine_bytes,
                    &deps,
                    &mut occupancy,
                    (l * 16 + m) as i64 + 8,
                );
                spare.push({
                    let mut d = deps;
                    d.clear();
                    d
                });
                layer_combines.push(cmb);
                prev_out[m] = Some(cmb);
            }

            weight_free = new_weight_free;
            fwd_combine.push(layer_combines);
            fwd_actsaves.push(layer_actsaves);
            let _ = occupancy; // occupancy tasks gate resources only
        }

        // loss boundary: all final-layer outputs
        let last_deps: &[TaskId] = fwd_combine.last().map(|v| v.as_slice()).unwrap_or(&[]);
        let loss = {
            let deps = deps_from(spare, last_deps);
            plan.add_task(TaskSpec {
                resource: None,
                duration: 0.0,
                deps,
                priority: 0,
                tag: Tag::Barrier,
                bytes: 0.0,
                flops: 0.0,
            })
        };

        // ---------- backward ----------
        let mut grad_in: Vec<TaskId> = vec![loss; n_mb]; // upstream grad per mb
        let mut bwd_weight_free: Vec<Vec<TaskId>> = vec![Vec::new(); hw.n_moe_chiplets];

        for l in (0..n_layers).rev() {
            let cells = &workload.cells[l];
            let mut occupancy: Vec<TaskId> = Vec::new();

            // activation re-load (attention side)
            let attn_aload_deps = {
                let mut d = deps_from(spare, &fwd_actsaves[l]);
                if !overlap {
                    d.push(grad_in[0]);
                }
                d
            };
            let attn_aload = plan.add_task(TaskSpec {
                resource: Some(res.attn_dram),
                duration: cfg.tokens_per_step() as f64
                    * lb.attn_act_bytes_per_token
                    * dur.attn_dram_spb,
                deps: attn_aload_deps,
                priority: ((n_layers - l) * 16) as i64,
                tag: Tag::ActLoad,
                bytes: cfg.tokens_per_step() as f64 * lb.attn_act_bytes_per_token,
                flops: 0.0,
            });

            // grad dispatch happens first in a bwd layer; in baseline the weight
            // reloads and activation loads are phase-ordered behind it (no
            // prefetch), so build the dispatches first and wire the gate below.
            let bwd_gate = if overlap {
                None
            } else {
                // all upstream grads of this layer available = previous bwd
                // layer fully done (grad_in is the same task for every mb)
                Some(grad_in[0])
            };

            // weight reload for dgrad (streaming, same chunking as fwd)
            let mut chiplet_loaded: Vec<Vec<TaskId>> =
                vec![Vec::new(); hw.n_moe_chiplets];
            let mut load_barrier_deps: Vec<TaskId> = Vec::new();
            for c in 0..hw.n_moe_chiplets {
                let g = group_of[l][c];
                for slot in 0..experts_on[l][c].len() {
                    let mut deps = take_deps(spare);
                    if overlap {
                        if let Some(&prev_use) = bwd_weight_free[c].get(slot) {
                            deps.push(prev_use);
                        }
                    } else {
                        deps.push(bwd_gate.unwrap());
                    }
                    let t = plan.add_task(TaskSpec {
                        resource: Some(res.group_stream[g]),
                        duration: lb.expert_bytes * dur.group_stream_spb[g]
                            + dur.chunk_overhead,
                        deps,
                        priority: if overlap {
                            load_prio[l][c] * 1000 + (n_layers - l) as i64
                        } else {
                            0
                        },
                        tag: Tag::WeightStream,
                        bytes: lb.expert_bytes,
                        flops: 0.0,
                    });
                    chiplet_loaded[c].push(t);
                    load_barrier_deps.push(t);
                }
            }

            // MoE activation re-load per group
            let per = hw.chiplets_per_group();
            let mut act_loads: Vec<TaskId> = Vec::new();
            for g in 0..hw.n_groups {
                let slots: u64 = cells
                    .iter()
                    .map(|cell| {
                        cell.chiplet_slots[g * per..(g + 1) * per]
                            .iter()
                            .sum::<u64>()
                    })
                    .sum();
                if slots == 0 {
                    continue;
                }
                let bytes = slots as f64 * lb.moe_act_bytes_per_slot;
                let deps = {
                    let mut d = deps_from(spare, &fwd_actsaves[l]);
                    if !overlap {
                        d.push(bwd_gate.unwrap());
                    }
                    d
                };
                let t = plan.add_task(TaskSpec {
                    resource: Some(res.group_stream[g]),
                    duration: bytes * dur.group_stream_spb[g],
                    deps,
                    priority: 100 + (n_layers - l) as i64,
                    tag: Tag::ActLoad,
                    bytes,
                    flops: 0.0,
                });
                act_loads.push(t);
            }

            // grad dispatch: output-grads attention -> chiplets
            let mut grad_dispatch = Vec::with_capacity(n_mb);
            for m in 0..n_mb {
                let cell = &cells[m];
                let bytes = cell.replicas as f64 * token_bytes / dur.switch_agg;
                let d = a2a_phase(
                    plan,
                    spare,
                    res,
                    dur,
                    Tag::A2aDispatch,
                    bytes,
                    &grad_in[m..m + 1],
                    &mut occupancy,
                    ((n_layers - l) * 16 + m) as i64,
                );
                grad_dispatch.push(d);
            }

            let load_gate = if overlap {
                None
            } else {
                Some(emit_simple(
                    plan,
                    spare,
                    Tag::Barrier,
                    None,
                    0.0,
                    &load_barrier_deps,
                ))
            };
            if !overlap {
                // strict phase order: nothing streams while the grad all-to-all
                // is in flight
                let dispatch_gate =
                    emit_simple(plan, spare, Tag::Barrier, None, 0.0, &grad_dispatch);
                for c in 0..hw.n_moe_chiplets {
                    for &t in &chiplet_loaded[c] {
                        plan.tasks[t].deps.push(dispatch_gate);
                    }
                }
                for &t in &act_loads {
                    plan.tasks[t].deps.push(dispatch_gate);
                }
            }

            // expert backward: dgrad + wgrad, 2x forward FLOPs
            let mut mb_bwd: Vec<Vec<TaskId>> = vec![Vec::new(); n_mb];
            let mut group_bwd: Vec<Vec<TaskId>> = vec![Vec::new(); hw.n_groups];
            let mut new_bwd_free: Vec<Vec<TaskId>> = vec![Vec::new(); hw.n_moe_chiplets];
            for c in 0..hw.n_moe_chiplets {
                let g = group_of[l][c];
                for (slot, &e) in experts_on[l][c].iter().enumerate() {
                    for m in 0..n_mb {
                        let slots = cells[m].expert_slots[e] as f64;
                        if slots == 0.0 && overlap {
                            continue;
                        }
                        let mut deps = take_deps(spare);
                        deps.push(grad_dispatch[m]);
                        match load_gate {
                            Some(gate) => deps.push(gate),
                            None => deps.push(chiplet_loaded[c][slot]),
                        }
                        deps.extend(act_loads.iter());
                        let flops = 2.0 * slots * expert_flops;
                        let t = plan.add_task(TaskSpec {
                            resource: Some(res.moe_compute[c]),
                            duration: flops * dur.moe_spf[c],
                            deps,
                            priority: (m * 64 + slot) as i64,
                            tag: Tag::MoeCompute,
                            bytes: 0.0,
                            flops,
                        });
                        mb_bwd[m].push(t);
                        group_bwd[g].push(t);
                        if m == n_mb - 1 {
                            new_bwd_free[c].push(t);
                        }
                    }
                }
                for slot in 0..experts_on[l][c].len() {
                    if new_bwd_free[c].len() <= slot {
                        new_bwd_free[c].push(chiplet_loaded[c][slot]);
                    }
                }
            }
            bwd_weight_free = new_bwd_free;

            // grad return: input-grads chiplets -> attention
            let mut grad_return = Vec::with_capacity(n_mb);
            for m in 0..n_mb {
                let cell = &cells[m];
                let bytes = cell.replicas as f64 * token_bytes;
                let r = a2a_phase(
                    plan,
                    spare,
                    res,
                    dur,
                    Tag::A2aCombine,
                    bytes,
                    &mb_bwd[m],
                    &mut occupancy,
                    ((n_layers - l) * 16 + m) as i64 + 8,
                );
                grad_return.push(r);
            }

            // expert wgrad writeback + optimizer update per group
            let mut optim_tasks: Vec<TaskId> = Vec::new();
            for g in 0..hw.n_groups {
                if group_bwd[g].is_empty() {
                    continue;
                }
                let group_weight_bytes = lb.cluster_bytes * hw.chiplets_per_group() as f64;
                let mut wb_deps = deps_from(spare, &group_bwd[g]);
                if !overlap {
                    wb_deps.extend(grad_return.iter());
                }
                let wb = plan.add_task(TaskSpec {
                    resource: Some(res.group_stream[g]),
                    duration: group_weight_bytes * dur.group_stream_spb[g],
                    deps: wb_deps,
                    priority: 200 + (n_layers - l) as i64,
                    tag: Tag::GradWriteback,
                    bytes: group_weight_bytes,
                    flops: 0.0,
                });
                let opt = plan.add_task(TaskSpec {
                    resource: Some(res.group_stream[g]),
                    duration: group_weight_bytes * dur.opt_factor * dur.group_stream_spb[g],
                    deps: deps_from(spare, &[wb]),
                    priority: 300 + (n_layers - l) as i64,
                    tag: Tag::OptimUpdate,
                    bytes: group_weight_bytes * dur.opt_factor,
                    flops: 0.0,
                });
                optim_tasks.push(opt);
            }

            // attention backward per mb (2x fwd flops) + attn weight traffic
            let attn_flops_bwd = 2.0 * tokens_mb * (attn_flops_tok + shared_flops_tok);
            let mut next_grad = Vec::with_capacity(n_mb);
            for m in 0..n_mb {
                let t = plan.add_task(TaskSpec {
                    resource: Some(res.attn_compute),
                    duration: attn_flops_bwd * dur.attn_spf,
                    deps: deps_from(spare, &[grad_return[m], attn_aload]),
                    priority: ((n_layers - l) * 16 + m) as i64,
                    tag: Tag::AttnCompute,
                    bytes: 0.0,
                    flops: attn_flops_bwd,
                });
                next_grad.push(t);
            }
            // attention wgrad + update on the attention channel
            let awb = plan.add_task(TaskSpec {
                resource: Some(res.attn_dram),
                duration: lb.attn_bytes * (1.0 + dur.opt_factor) * dur.attn_dram_spb,
                deps: deps_from(spare, &next_grad),
                priority: 400 + (n_layers - l) as i64,
                tag: Tag::OptimUpdate,
                bytes: lb.attn_bytes * (1.0 + dur.opt_factor),
                flops: 0.0,
            });
            if !overlap {
                // serialize the next (lower) layer behind this layer's full
                // update phase (attention + expert optimizer writebacks)
                let mut gate_deps = deps_from(spare, &[awb]);
                gate_deps.extend(optim_tasks.iter());
                let gate = plan.add_task(TaskSpec {
                    resource: None,
                    duration: 0.0,
                    deps: gate_deps,
                    priority: 0,
                    tag: Tag::Barrier,
                    bytes: 0.0,
                    flops: 0.0,
                });
                grad_in.clear();
                grad_in.resize(n_mb, gate);
            } else {
                grad_in = next_grad;
            }
            let _ = occupancy;
        }

        &self.plan
    }
}

/// Build the full step plan (one-shot convenience over [`PlanCache`]).
pub fn build_step_plan(inp: &StepInputs) -> Plan {
    let mut cache = PlanCache::new(inp.cfg, inp.layouts);
    cache.rebuild(inp.workload);
    cache.into_plan()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::ExpertLayout;
    use crate::config::{ExperimentConfig, Method, MethodConfig, ModelConfig, ModelId};
    use crate::sim::Simulator;
    use crate::trace::TraceGen;
    use crate::util::rng::Rng;

    fn small_cfg(method: MethodConfig) -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::OlmoE_1B_7B),
            method,
        );
        c.seq_len = 32;
        c.batch_size = 8;
        c.micro_batch = 2;
        c
    }

    fn run(method: Method) -> f64 {
        let cfg = small_cfg(method.config());
        let gen = TraceGen::for_model(&cfg.model, 5);
        let layouts = vec![
            ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
            cfg.model.n_moe_layers()
        ];
        let mut rng = Rng::new(6);
        let coalesce = cfg.method.efficient_a2a;
        let w = crate::pipeline::StepWorkload::sample(&cfg, &gen, &layouts, coalesce, &mut rng);
        let plan = build_step_plan(&StepInputs {
            cfg: &cfg,
            layouts: &layouts,
            workload: &w,
        });
        plan.validate().unwrap();
        Simulator::run(&plan).makespan
    }

    #[test]
    fn plans_validate_and_run() {
        for m in Method::ALL {
            let t = run(m);
            assert!(t.is_finite() && t > 0.0, "{}: {t}", m.name());
        }
    }

    /// Every dispatch policy must produce a valid schedule for a REAL step
    /// plan, not just the synthetic fixtures in `sim::sched`: run the traced
    /// engine under each policy and hand the trace to the schedule-validity
    /// oracle. Streaming must reproduce the default engine path bit for bit.
    #[test]
    fn every_policy_schedules_a_real_step_plan_validly() {
        use crate::config::SchedPolicy;
        use crate::sim::SimScratch;
        let cfg = small_cfg(Method::MozartC.config());
        let gen = TraceGen::for_model(&cfg.model, 5);
        let layouts = vec![
            ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
            cfg.model.n_moe_layers()
        ];
        let mut rng = Rng::new(6);
        let w = crate::pipeline::StepWorkload::sample(
            &cfg,
            &gen,
            &layouts,
            cfg.method.efficient_a2a,
            &mut rng,
        );
        let plan = build_step_plan(&StepInputs {
            cfg: &cfg,
            layouts: &layouts,
            workload: &w,
        });
        let reference = Simulator::run(&plan);
        let mut scratch = SimScratch::new();
        for policy in SchedPolicy::ALL {
            let (res, trace) =
                Simulator::run_policy_traced(&plan, policy, cfg.seed, &mut scratch);
            trace
                .validate(&plan)
                .unwrap_or_else(|e| panic!("{}: oracle rejected: {e}", policy.name()));
            assert!(
                res.makespan.is_finite() && res.makespan > 0.0,
                "{}: empty schedule",
                policy.name()
            );
            if policy == SchedPolicy::Streaming {
                assert_eq!(
                    res.makespan.to_bits(),
                    reference.makespan.to_bits(),
                    "streaming diverged from the default engine path"
                );
            }
        }
    }

    #[test]
    fn ablation_is_monotone() {
        // each added optimization must not slow the step down
        let base = run(Method::Baseline);
        let a = run(Method::MozartA);
        let b = run(Method::MozartB);
        let c = run(Method::MozartC);
        assert!(a < base, "A {a} !< baseline {base}");
        assert!(b <= a * 1.001, "B {b} !<= A {a}");
        assert!(c <= b * 1.02, "C {c} !<= B {b}");
    }

    #[test]
    fn overlap_hides_work() {
        // with overlap, busy time exceeds makespan on some resources
        let cfg = small_cfg(MethodConfig::mozart_a());
        let gen = TraceGen::for_model(&cfg.model, 7);
        let layouts = vec![
            ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
            cfg.model.n_moe_layers()
        ];
        let mut rng = Rng::new(8);
        let w = crate::pipeline::StepWorkload::sample(&cfg, &gen, &layouts, false, &mut rng);
        let plan = build_step_plan(&StepInputs {
            cfg: &cfg,
            layouts: &layouts,
            workload: &w,
        });
        let res = Simulator::run(&plan);
        let total_busy: f64 = res.tag_busy.iter().map(|(_, v)| v).sum();
        assert!(total_busy > res.makespan, "nothing overlapped");
    }

    /// The cache's per-iteration re-emission over the recycled arena must
    /// produce exactly the plan a fresh one-shot build produces, for every
    /// method (baseline exercises the dep-patching barrier paths) and
    /// across repeated rebuilds with different workloads.
    #[test]
    fn cached_rebuild_matches_fresh_build() {
        for m in Method::ALL {
            let cfg = small_cfg(m.config());
            let gen = TraceGen::for_model(&cfg.model, 5);
            let layouts = vec![
                ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
                cfg.model.n_moe_layers()
            ];
            let coalesce = cfg.method.efficient_a2a;
            let mut cache = PlanCache::new(&cfg, &layouts);
            let mut rng = Rng::new(11);
            for it in 0..3 {
                let mut step_rng = rng.fork(it);
                let w = crate::pipeline::StepWorkload::sample(
                    &cfg, &gen, &layouts, coalesce, &mut step_rng,
                );
                let fresh = build_step_plan(&StepInputs {
                    cfg: &cfg,
                    layouts: &layouts,
                    workload: &w,
                });
                let cached = cache.rebuild(&w);
                assert_eq!(
                    cached, &fresh,
                    "{}: rebuild {it} diverged from fresh build",
                    m.name()
                );
            }
        }
    }

    /// Delta re-timing contract: a `PlanCache` built for one platform and
    /// re-timed to a knob/frequency/fault-severity variant emits plans
    /// bit-identical to a cache freshly built for that variant.
    #[test]
    fn retimed_cache_matches_fresh_build() {
        use crate::config::{HwOverride, KnobId};
        let base = small_cfg(Method::MozartC.config());
        let gen = TraceGen::for_model(&base.model, 5);
        let layouts = vec![
            ExpertLayout::contiguous(base.model.n_experts, 16, 4);
            base.model.n_moe_layers()
        ];
        let coalesce = base.method.efficient_a2a;

        // knob, frequency, and bandwidth-fault-severity variants of the
        // same topology (no dead chiplets -> layouts unchanged)
        let mut variants: Vec<ExperimentConfig> = vec![
            {
                let mut c = base.clone();
                c.hw = c.hw.with_overrides(&[HwOverride::FreqGhz(1.3)]);
                c
            },
            {
                let mut c = base.clone();
                c.hw = c.hw.with_overrides(&[
                    HwOverride::Knob(KnobId::MxuUtil, 0.5),
                    HwOverride::Knob(KnobId::ChunkOverheadUs, 0.7),
                ]);
                c
            },
        ];
        let mut faulted = base.clone();
        faulted.fault = crate::comm::FaultScenario::parse(
            "nop-degrade:0.4,dram-throttle:0.3",
            faulted.seed,
        )
        .unwrap();
        variants.push(faulted);

        let mut cache = PlanCache::new(&base, &layouts);
        for (vi, cfg) in variants.iter().enumerate() {
            cache.retime(cfg);
            let mut rng = Rng::new(11);
            for it in 0..2 {
                let mut step_rng = rng.fork(it);
                let w = crate::pipeline::StepWorkload::sample(
                    cfg, &gen, &layouts, coalesce, &mut step_rng,
                );
                let fresh = build_step_plan(&StepInputs {
                    cfg,
                    layouts: &layouts,
                    workload: &w,
                });
                let cached = cache.rebuild(&w);
                assert_eq!(
                    cached, &fresh,
                    "variant {vi}: retimed rebuild {it} diverged from fresh build"
                );
            }
        }
        // re-timing back to the base restores the original emission exactly
        cache.retime(&base);
        let mut rng = Rng::new(11);
        let mut step_rng = rng.fork(0);
        let w = crate::pipeline::StepWorkload::sample(
            &base, &gen, &layouts, coalesce, &mut step_rng,
        );
        let fresh = build_step_plan(&StepInputs {
            cfg: &base,
            layouts: &layouts,
            workload: &w,
        });
        assert_eq!(cache.rebuild(&w), &fresh, "round-trip retime diverged");
    }

    fn run_with_fault(method: Method, fault: &str) -> f64 {
        let mut cfg = small_cfg(method.config());
        cfg.fault = crate::comm::FaultScenario::parse(fault, cfg.seed).unwrap();
        let gen = TraceGen::for_model(&cfg.model, 5);
        let mut layouts = vec![
            ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
            cfg.model.n_moe_layers()
        ];
        let fx = cfg.fault.effects(cfg.hw.n_moe_chiplets, cfg.hw.n_groups);
        for layout in &mut layouts {
            layout.spill_dead(&fx.dead());
        }
        let mut rng = Rng::new(6);
        let coalesce = cfg.method.efficient_a2a;
        let w = crate::pipeline::StepWorkload::sample(&cfg, &gen, &layouts, coalesce, &mut rng);
        let plan = build_step_plan(&StepInputs {
            cfg: &cfg,
            layouts: &layouts,
            workload: &w,
        });
        plan.validate().unwrap();
        Simulator::run(&plan).makespan
    }

    /// A scenario whose faults are all present but at health 1.0 exercises
    /// the fault-aware code path end to end and must still be bit-identical
    /// to the healthy build (health factors are no-op multiplications).
    #[test]
    fn all_ones_fault_scenario_is_bit_identical() {
        for m in Method::ALL {
            let healthy = run(m);
            let faulted = run_with_fault(m, "nop-degrade:1,hb-degrade:1,dram-throttle:1");
            assert_eq!(healthy.to_bits(), faulted.to_bits(), "{}", m.name());
        }
    }

    /// Severe (20x) degradations cannot hide under pipeline slack on any
    /// resource, so each one must strictly stretch the step. (Mild faults
    /// on off-critical-path resources may legitimately be absorbed.)
    #[test]
    fn real_faults_stretch_the_step() {
        let healthy = run(Method::MozartC);
        for fault in [
            "dead-chiplet:4",
            "nop-degrade:0.05",
            "hb-degrade:0.05",
            "dram-throttle:0.05",
        ] {
            let faulted = run_with_fault(Method::MozartC, fault);
            assert!(
                faulted > healthy,
                "{fault}: faulted {faulted} !> healthy {healthy}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "apply ExpertLayout::spill_dead")]
    fn dead_chiplet_without_spill_is_rejected() {
        let mut cfg = small_cfg(MethodConfig::mozart_c());
        cfg.fault = crate::comm::FaultScenario::parse("dead-chiplet:1", cfg.seed).unwrap();
        let layouts = vec![
            ExpertLayout::contiguous(cfg.model.n_experts, 16, 4);
            cfg.model.n_moe_layers()
        ];
        let _ = PlanCache::new(&cfg, &layouts);
    }
}
