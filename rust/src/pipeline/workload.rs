//! Per-step workload statistics: routing-trace-derived quantities for every
//! (MoE layer, micro-batch) cell, plus the byte/FLOP model shared by the
//! plan builder and the energy accounting.

use crate::allocation::ExpertLayout;
use crate::comm::A2aStats;
use crate::config::ExperimentConfig;
use crate::trace::TraceGen;
use crate::util::rng::Rng;

/// Routing-derived statistics for one (layer, micro-batch) cell.
#[derive(Clone, Debug)]
pub struct LayerMbStats {
    /// Dispatch replicas after (optional) co-location elision.
    pub replicas: u64,
    /// Token-slots per expert (compute workload of each expert).
    pub expert_slots: Vec<u64>,
    /// Token-slots per chiplet.
    pub chiplet_slots: Vec<u64>,
    /// C_T of this cell.
    pub c_t: f64,
    /// Tokens routed in this cell.
    pub n_tokens: u64,
}

/// All routing statistics for one simulated training step.
#[derive(Clone, Debug)]
pub struct StepWorkload {
    /// `cells[layer][mb]`.
    pub cells: Vec<Vec<LayerMbStats>>,
    /// Mean C_T over all cells (the Table 4 metric).
    pub mean_c_t: f64,
}

impl StepWorkload {
    /// Sample a fresh step's routing and evaluate it against the per-layer
    /// expert layouts (the paper places each decoder layer's experts
    /// independently; `layouts[l]` is layer l's placement).
    ///
    /// `coalesce` mirrors `A2aStats::evaluate`: replica elision on
    /// co-located experts (the `efficient_a2a` feature).
    pub fn sample(
        cfg: &ExperimentConfig,
        gen: &TraceGen,
        layouts: &[ExpertLayout],
        coalesce: bool,
        rng: &mut Rng,
    ) -> StepWorkload {
        let n_layers = cfg.model.n_moe_layers();
        let n_mb = cfg.n_micro_batches();
        let tokens_mb = cfg.tokens_per_micro_batch();
        assert_eq!(layouts.len(), n_layers, "one layout per MoE layer");
        let mut cells = Vec::with_capacity(n_layers);
        let mut ct_sum = 0.0;
        for l in 0..n_layers {
            let mut row = Vec::with_capacity(n_mb);
            for m in 0..n_mb {
                let mut r = rng.fork((l * 131 + m) as u64);
                let tr = gen.sample_layer(l, tokens_mb, &mut r);
                let stats = A2aStats::evaluate(&tr, &layouts[l], coalesce);
                ct_sum += stats.c_t;
                row.push(LayerMbStats {
                    replicas: stats.dispatch_replicas,
                    expert_slots: tr.expert_token_counts(),
                    chiplet_slots: stats.chiplet_token_slots,
                    c_t: stats.c_t,
                    n_tokens: stats.n_tokens,
                });
            }
            cells.push(row);
        }
        let mean_c_t = ct_sum / (n_layers * n_mb) as f64;
        StepWorkload { cells, mean_c_t }
    }
}

/// Byte/FLOP model for one decoder layer (shared by plan builder, energy
/// accounting and the roofline study).
#[derive(Clone, Debug)]
pub struct LayerBytes {
    /// Expert weights per chiplet (cluster) in bytes.
    pub cluster_bytes: f64,
    /// Expert weights of one expert in bytes.
    pub expert_bytes: f64,
    /// Attention-side weights (attn + router + shared experts [+ dense
    /// FFN for dense layers]) in bytes.
    pub attn_bytes: f64,
    /// Activation bytes saved per token-slot on MoE chiplets (input +
    /// intermediate + output rows of the expert FFN).
    pub moe_act_bytes_per_slot: f64,
    /// Activation bytes saved per token on the attention chiplet
    /// (q, k, v, attention output, FFN input).
    pub attn_act_bytes_per_token: f64,
}

impl LayerBytes {
    /// Derive the byte model from a model + hardware configuration.
    pub fn of(cfg: &ExperimentConfig) -> LayerBytes {
        let m = &cfg.model;
        let bpp = m.bytes_per_param as f64;
        LayerBytes {
            cluster_bytes: m.expert_layer_bytes() as f64 / cfg.hw.n_moe_chiplets as f64,
            expert_bytes: m.expert_bytes() as f64,
            attn_bytes: m.attn_layer_bytes() as f64,
            moe_act_bytes_per_slot: (2.0 * m.hidden as f64 + m.expert_intermediate as f64) * bpp,
            attn_act_bytes_per_token: 5.0 * m.hidden as f64 * bpp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::ExpertLayout;
    use crate::config::{ExperimentConfig, MethodConfig, ModelConfig, ModelId};
    use crate::trace::TraceGen;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::OlmoE_1B_7B),
            MethodConfig::mozart_c(),
        );
        c.seq_len = 64;
        c.batch_size = 8;
        c.micro_batch = 2;
        c
    }

    #[test]
    fn sample_covers_all_cells() {
        let c = cfg();
        let gen = TraceGen::for_model(&c.model, 1);
        let layouts = vec![
            ExpertLayout::contiguous(c.model.n_experts, 16, 4);
            c.model.n_moe_layers()
        ];
        let mut rng = Rng::new(2);
        let w = StepWorkload::sample(&c, &gen, &layouts, true, &mut rng);
        assert_eq!(w.cells.len(), c.model.n_moe_layers());
        assert_eq!(w.cells[0].len(), 4);
        for row in &w.cells {
            for cell in row {
                assert_eq!(cell.n_tokens as usize, c.tokens_per_micro_batch());
                assert_eq!(
                    cell.expert_slots.iter().sum::<u64>(),
                    cell.n_tokens * c.model.top_k as u64
                );
                assert_eq!(
                    cell.chiplet_slots.iter().sum::<u64>(),
                    cell.n_tokens * c.model.top_k as u64
                );
                assert!(cell.c_t <= c.model.top_k as f64 + 1e-9);
                assert!(cell.replicas <= cell.n_tokens * c.model.top_k as u64);
            }
        }
        assert!(w.mean_c_t > 1.0 && w.mean_c_t <= c.model.top_k as f64);
    }

    #[test]
    fn no_coalesce_replicas_equal_k_tokens() {
        let c = cfg();
        let gen = TraceGen::for_model(&c.model, 1);
        let layouts = vec![
            ExpertLayout::contiguous(c.model.n_experts, 16, 4);
            c.model.n_moe_layers()
        ];
        let mut rng = Rng::new(3);
        let w = StepWorkload::sample(&c, &gen, &layouts, false, &mut rng);
        assert!((w.mean_c_t - c.model.top_k as f64).abs() < 1e-12);
    }

    #[test]
    fn layer_bytes_qwen3() {
        let c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::Qwen3_30B_A3B),
            MethodConfig::baseline(),
        );
        let lb = LayerBytes::of(&c);
        // 1.208 GB of expert weights across 16 chiplets
        assert!((lb.cluster_bytes - 1.208e9 / 16.0).abs() / lb.cluster_bytes < 0.01);
        assert!((lb.expert_bytes - 3.0 * 2048.0 * 768.0 * 2.0).abs() < 1.0);
        assert!(lb.moe_act_bytes_per_slot > 0.0);
    }
}
