//! Training-step pipeline: builds the task-DAG plan the simulator executes
//! (paper §4.3 fine-grained scheduling + §4.4 algorithm-to-hardware
//! mapping), and the per-step byte/FLOP workload model behind it.

pub mod epsim;
pub mod plan_builder;
pub mod workload;

pub use plan_builder::{build_step_plan, PlanCache, StepInputs};
pub use workload::{LayerMbStats, StepWorkload};
