//! Deterministic PRNG (splitmix64 + xoshiro256**) with the sampling helpers
//! the trace generator needs. `rand` is not available offline; this is a
//! faithful, tested implementation of the standard public-domain generators.

/// xoshiro256** seeded via splitmix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per layer / per worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with all-zero weights");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1 // numerical tail
    }

    /// Sample `k` distinct indices from unnormalized weights (without
    /// replacement), by iterative masked draws. O(k * n).
    pub fn weighted_distinct(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        assert!(k <= weights.len());
        let mut w = weights.to_vec();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let idx = self.weighted(&w);
            out.push(idx);
            w[idx] = 0.0;
        }
        out
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

/// Walker alias table for O(1) sampling from a fixed discrete distribution.
/// Used by the trace generator's global popularity draws (the hot path of
/// every simulated experiment).
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build the table from unnormalized positive weights.
    pub fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        assert!(n > 0);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0);
        let mut prob: Vec<f64> = weights.iter().map(|&w| w * n as f64 / total).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // leftovers are 1.0 up to rounding
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Whether the table has no outcomes (never true post-construction).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

/// Zipf-like unnormalized weights: w_i = 1 / (i + 1)^alpha over a permuted
/// index order, so popularity is not correlated with expert index.
pub fn zipf_weights(n: usize, alpha: f64, perm: &[usize]) -> Vec<f64> {
    assert_eq!(perm.len(), n);
    let mut w = vec![0.0; n];
    for (rank, &i) in perm.iter().enumerate() {
        w[i] = 1.0 / ((rank + 1) as f64).powf(alpha);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_respects_zero_mass() {
        let mut r = Rng::new(9);
        for _ in 0..1_000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_distinct_no_dupes() {
        let mut r = Rng::new(11);
        let w = vec![1.0; 16];
        for _ in 0..200 {
            let picks = r.weighted_distinct(&w, 8);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
        }
    }

    #[test]
    fn weighted_roughly_proportional() {
        let mut r = Rng::new(13);
        let w = [1.0, 3.0];
        let mut c = [0usize; 2];
        for _ in 0..40_000 {
            c[r.weighted(&w)] += 1;
        }
        let frac = c[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(19);
        let p = r.permutation(100);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn alias_table_matches_distribution() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let at = AliasTable::new(&w);
        let mut r = Rng::new(23);
        let mut c = [0usize; 4];
        let n = 100_000;
        for _ in 0..n {
            c[at.sample(&mut r)] += 1;
        }
        for i in 0..4 {
            let expect = w[i] / 10.0;
            let got = c[i] as f64 / n as f64;
            assert!((got - expect).abs() < 0.01, "i={i} got={got} expect={expect}");
        }
    }

    #[test]
    fn alias_table_degenerate_mass() {
        let at = AliasTable::new(&[0.0, 5.0, 0.0]);
        let mut r = Rng::new(29);
        for _ in 0..1_000 {
            assert_eq!(at.sample(&mut r), 1);
        }
    }

    #[test]
    fn zipf_sums_and_skews() {
        let perm: Vec<usize> = (0..8).collect();
        let w = zipf_weights(8, 1.0, &perm);
        assert!(w[0] > w[7]);
        assert!((w[0] - 1.0).abs() < 1e-12);
    }
}
