//! Minimal JSON emission (`serde_json` is not in the offline crate set).
//! Write-only: enough to serialize bench reports like `BENCH_sweep.json`.

use std::fmt::Write as _;

/// A JSON value. Objects preserve insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (non-finite values render as `null`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (ordered key/value pairs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// String value constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number value constructor.
    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    /// Integer value constructor (exact below 2^53).
    pub fn int(v: usize) -> Json {
        Json::Num(v as f64)
    }

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Append a field to an object; panics on non-objects.
    pub fn push(&mut self, key: impl Into<String>, value: Json) -> &mut Json {
        match self {
            Json::Obj(fields) => fields.push((key.into(), value)),
            other => panic!("Json::push on non-object {other:?}"),
        }
        self
    }

    /// Render as compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Render with 2-space indentation (human-readable artifacts).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // f64 Display round-trips; integral values print bare
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                write_seq(out, indent, pretty, '[', ']', items.len(), |out, i, ind| {
                    items[i].write(out, ind, pretty);
                });
            }
            Json::Obj(fields) => {
                write_seq(out, indent, pretty, '{', '}', fields.len(), |out, i, ind| {
                    let (k, v) = &fields[i];
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, ind, pretty);
                });
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: usize,
    pretty: bool,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize, usize),
) {
    out.push(open);
    if len == 0 {
        out.push(close);
        return;
    }
    for i in 0..len {
        if i > 0 {
            out.push(',');
        }
        if pretty {
            out.push('\n');
            for _ in 0..(indent + 1) * 2 {
                out.push(' ');
            }
        }
        item(out, i, indent + 1);
    }
    if pretty {
        out.push('\n');
        for _ in 0..indent * 2 {
            out.push(' ');
        }
    }
    out.push(close);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::int(12).render(), "12");
        assert_eq!(Json::num(1.5).render(), "1.5");
        assert_eq!(Json::num(f64::NAN).render(), "null");
        assert_eq!(Json::str("a\"b\n").render(), "\"a\\\"b\\n\"");
    }

    #[test]
    fn nested_object_renders() {
        let mut o = Json::obj([("name", Json::str("sweep")), ("cells", Json::int(24))]);
        o.push("grids", Json::Arr(vec![Json::num(0.25), Json::Bool(false)]));
        assert_eq!(
            o.render(),
            r#"{"name":"sweep","cells":24,"grids":[0.25,false]}"#
        );
    }

    #[test]
    fn pretty_rendering_is_indented_and_reparses_shape() {
        let o = Json::obj([
            ("a", Json::Arr(vec![Json::int(1), Json::int(2)])),
            ("b", Json::obj([("c", Json::Null)])),
        ]);
        let s = o.render_pretty();
        assert!(s.contains("\n  \"a\": [\n    1,\n    2\n  ]"));
        assert!(s.ends_with("}\n"));
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }
}
