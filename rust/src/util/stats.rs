//! Summary statistics over f64 slices: mean/std/min/max/percentiles, plus a
//! streaming Welford accumulator used by the simulator's metrics plumbing.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum; NaN for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NAN, f64::min)
}

/// Maximum; NaN for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NAN, f64::max)
}

/// Percentile in [0, 100] with linear interpolation (sorts a copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Coefficient of variation (std / mean); 0.0 if the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std(xs) / m
    }
}

/// Load imbalance ratio `max/mean` (1.0 = perfectly balanced).
pub fn imbalance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        1.0
    } else {
        max(xs) / m
    }
}

/// Average ranks (1-based, ties share the mean of their rank block).
fn average_ranks(xs: &[f64]) -> Vec<f64> {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0.0; xs.len()];
    let mut i = 0;
    while i < order.len() {
        let mut j = i;
        while j + 1 < order.len() && xs[order[j + 1]] == xs[order[i]] {
            j += 1;
        }
        // positions i..=j are tied; each gets the mean 1-based rank
        let r = (i + j) as f64 / 2.0 + 1.0;
        for &k in &order[i..=j] {
            ranks[k] = r;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman rank correlation between two paired samples (ties averaged).
/// `None` when the lengths differ, fewer than two pairs exist, or either
/// side has zero rank variance (correlation undefined).
pub fn spearman(xs: &[f64], ys: &[f64]) -> Option<f64> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let rx = average_ranks(xs);
    let ry = average_ranks(ys);
    let mx = mean(&rx);
    let my = mean(&ry);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (&a, &b) in rx.iter().zip(ry.iter()) {
        cov += (a - mx) * (b - my);
        vx += (a - mx) * (a - mx);
        vy += (b - my) * (b - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return None;
    }
    Some(cov / (vx * vy).sqrt())
}

/// Streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 below two observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn imbalance_balanced_is_one() {
        assert_eq!(imbalance(&[2.0, 2.0, 2.0]), 1.0);
        assert!((imbalance(&[1.0, 3.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn spearman_rank_correlation() {
        // perfect monotone (nonlinear) relation -> exactly 1
        let xs = [1.0, 2.0, 3.0, 4.0];
        let cubes = [1.0, 8.0, 27.0, 64.0];
        assert_eq!(spearman(&xs, &cubes), Some(1.0));
        // perfect inverse -> exactly -1
        let rev = [64.0, 27.0, 8.0, 1.0];
        assert_eq!(spearman(&xs, &rev), Some(-1.0));
        // ties share averaged ranks: rho stays in (-1, 1) but positive
        let tied = [1.0, 1.0, 2.0, 3.0];
        let r = spearman(&tied, &xs).unwrap();
        assert!(r > 0.8 && r < 1.0, "rho {r}");
        // undefined cases
        assert_eq!(spearman(&xs, &[1.0, 2.0]), None);
        assert_eq!(spearman(&[1.0], &[2.0]), None);
        assert_eq!(spearman(&[5.0, 5.0, 5.0], &xs), None);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 5.0, 2.0, 8.0, 3.5];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 8.0);
        assert_eq!(w.count(), 5);
    }
}
