//! Minimal CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals plus key/value options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: everything after is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 && !a[1..].chars().next().unwrap().is_ascii_digit() {
                bail!("short options are not supported: {a}");
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether the bare switch `--name` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of option `--name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option lookup with a default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .with_context(|| format!("invalid value for --{name}: {s}")),
        }
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        let s = self
            .get(name)
            .with_context(|| format!("missing required option --{name}"))?;
        s.parse::<T>()
            .with_context(|| format!("invalid value for --{name}: {s}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["report", "table3", "--seed", "7", "--seq=256", "--verbose"]);
        assert_eq!(a.positional, vec!["report", "table3"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("seq"), Some("256"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--steps", "100"]);
        assert_eq!(a.get_parse::<u32>("steps", 5).unwrap(), 100);
        assert_eq!(a.get_parse::<u32>("other", 5).unwrap(), 5);
        assert!(a.get_parse::<u32>("steps", 5).is_ok());
        assert!(a.require::<u32>("missing").is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--x", "1", "--", "--not-an-option"]);
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn negative_number_is_positional() {
        let a = parse(&["-3.5"]);
        assert_eq!(a.positional, vec!["-3.5"]);
    }

    #[test]
    fn short_options_rejected() {
        assert!(Args::parse(vec!["-v".to_string()]).is_err());
    }
}
