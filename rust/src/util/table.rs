//! ASCII table printer used by all report generators. Produces GitHub-style
//! markdown tables so the benchmark harness output can be pasted straight
//! into the reports.

/// A simple column-aligned markdown table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title line and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for rows of &str.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as a markdown table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len()));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Render a series as a compact ASCII bar chart (one bar per label), used by
/// the figure generators where the paper shows bar plots.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], unit: &str) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let width = 48usize;
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("### {title}\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / maxv) * width as f64).round() as usize;
        out.push_str(&format!(
            "{l:<label_w$} | {} {v:.4} {unit}\n",
            "#".repeat(n.max(if v > 0.0 { 1 } else { 0 }))
        ));
    }
    out
}

/// Render an ASCII scatter plot of `(x, y, mark)` points on a fixed-size
/// character grid, used by the design-space explorer to sketch the Pareto
/// frontier. Later points overwrite earlier ones on collisions, so callers
/// should order the most important marks last. Both axes are linear;
/// degenerate (single-valued) ranges are widened so the points still render.
pub fn scatter_plot(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    points: &[(f64, f64, char)],
) -> String {
    const W: usize = 60;
    const H: usize = 16;
    if points.is_empty() {
        return format!("### {title}\n(no points)\n");
    }
    let mut xmin = f64::INFINITY;
    let mut xmax = f64::NEG_INFINITY;
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for &(x, y, _) in points {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if !(xmax - xmin).is_finite() || xmax - xmin < 1e-12 {
        xmax = xmin + 1.0;
    }
    if !(ymax - ymin).is_finite() || ymax - ymin < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![[' '; W]; H];
    for &(x, y, c) in points {
        let xi = (((x - xmin) / (xmax - xmin)) * (W - 1) as f64).round() as usize;
        let yi = (((y - ymin) / (ymax - ymin)) * (H - 1) as f64).round() as usize;
        grid[H - 1 - yi.min(H - 1)][xi.min(W - 1)] = c;
    }
    let mut out = format!("### {title}\n");
    out.push_str(&format!("{ylabel}: {ymin:.4} (bottom) .. {ymax:.4} (top)\n"));
    for row in &grid {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(W));
    out.push('\n');
    out.push_str(&format!("{xlabel}: {xmin:.4} (left) .. {xmax:.4} (right)\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row_str(&["xx", "y"]);
        let r = t.render();
        assert!(r.contains("### T"));
        assert!(r.contains("| a  | bbbb |"));
        assert!(r.contains("| xx | y    |"));
        assert!(r.contains("|----|------|"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn scatter_places_extremes_in_corners() {
        let s = scatter_plot(
            "S",
            "x",
            "y",
            &[(0.0, 0.0, 'a'), (1.0, 1.0, 'b'), (0.5, 0.5, 'c')],
        );
        assert!(s.contains("### S"));
        let rows: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(rows.len(), 16);
        // max-y point lands on the top row, min-y on the bottom
        assert!(rows[0].ends_with('b'));
        assert!(rows[15].starts_with("|a"));
        assert!(s.contains('c'));
        assert!(s.contains("x: 0.0000 (left) .. 1.0000 (right)"));
    }

    #[test]
    fn scatter_handles_degenerate_and_empty_input() {
        let s = scatter_plot("D", "x", "y", &[(2.0, 3.0, '*')]);
        assert!(s.contains('*')); // single point still renders
        assert!(scatter_plot("E", "x", "y", &[]).contains("no points"));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "B",
            &["x".into(), "y".into()],
            &[1.0, 2.0],
            "s",
        );
        assert!(s.contains("### B"));
        // the larger value gets the full-width bar
        assert!(s.contains(&"#".repeat(48)));
    }
}
