//! ASCII table printer used by all report generators. Produces GitHub-style
//! markdown tables so the benchmark harness output can be pasted straight
//! into the reports.

/// A simple column-aligned markdown table builder.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for rows of &str.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as a markdown table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len()));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Render a series as a compact ASCII bar chart (one bar per label), used by
/// the figure generators where the paper shows bar plots.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], unit: &str) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let width = 48usize;
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("### {title}\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / maxv) * width as f64).round() as usize;
        out.push_str(&format!(
            "{l:<label_w$} | {} {v:.4} {unit}\n",
            "#".repeat(n.max(if v > 0.0 { 1 } else { 0 }))
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_alignment() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row_str(&["xx", "y"]);
        let r = t.render();
        assert!(r.contains("### T"));
        assert!(r.contains("| a  | bbbb |"));
        assert!(r.contains("| xx | y    |"));
        assert!(r.contains("|----|------|"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "B",
            &["x".into(), "y".into()],
            &[1.0, 2.0],
            "s",
        );
        assert!(s.contains("### B"));
        // the larger value gets the full-width bar
        assert!(s.contains(&"#".repeat(48)));
    }
}
