//! Small self-contained utilities.
//!
//! This repo builds fully offline against a vendored crate set that does not
//! include `rand`, `serde`, `clap`, or `criterion`, so the handful of
//! facilities we need from those crates are implemented here from scratch:
//! a counter-based PRNG ([`rng`]), summary statistics ([`stats`]), an ASCII
//! table printer ([`table`]), and a tiny CLI argument parser ([`cli`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a byte count with binary units (e.g. `1.21 GiB`).
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut i = 0;
    while v >= 1024.0 && i + 1 < UNITS.len() {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{v:.0} {}", UNITS[i])
    } else {
        format!("{v:.2} {}", UNITS[i])
    }
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Integer ceiling division.
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(1.5 * 1024.0 * 1024.0 * 1024.0), "1.50 GiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert_eq!(fmt_secs(2.5e-8), "25.0 ns");
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(0, 3), 0);
        assert_eq!(div_ceil(1, 1), 1);
    }
}
