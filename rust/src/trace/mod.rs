//! Routing traces and expert-activation priors (paper §3.2).
//!
//! The paper profiles pre-trained MoE-LLMs on Alpaca with A100 servers to
//! obtain (a) the per-expert workload distribution `V` (Eq. 3) and (b) the
//! pairwise co-activation matrix `C`/`P` (Eq. 4). We cannot run 30B-param
//! models here, so [`gen::TraceGen`] synthesizes routing traces with the two
//! empirical properties the paper's Figure 3 documents — *expert
//! specialization* (power-law activation frequencies) and *expert
//! collaboration* (latent groups of co-activated experts, scattered across
//! the arbitrary expert-index order) — and the tiny real model trained in
//! `examples/train_tiny_moe.rs` provides a real-trace cross-check.
//!
//! [`arrivals`] adds the *serving* side of trace generation: seeded
//! open-loop request-arrival processes (Poisson / MMPP / diurnal / file
//! replay) feeding the `mozart serve` queueing simulator.

pub mod arrivals;
pub mod gen;
pub mod prior;

pub use arrivals::{emit_trace, parse_trace, ArrivalProcess, Request, RequestShape};
pub use gen::{TraceGen, TraceParams};
pub use prior::{coactivation, workload_vector, Priors};

/// Routing decisions for one MoE layer over a batch of tokens: `choices`
/// holds `n_tokens * top_k` expert indices (row-major per token). Within a
/// token the k experts are distinct.
#[derive(Clone, Debug)]
pub struct RoutingTrace {
    /// Routed experts per MoE layer.
    pub n_experts: usize,
    /// Routing fanout per token.
    pub top_k: usize,
    /// `n_tokens * top_k` expert indices, row-major per token.
    pub choices: Vec<u32>,
}

impl RoutingTrace {
    /// Tokens in the trace.
    pub fn n_tokens(&self) -> usize {
        debug_assert_eq!(self.choices.len() % self.top_k, 0);
        self.choices.len() / self.top_k
    }

    /// The k experts chosen by token `t`.
    pub fn token(&self, t: usize) -> &[u32] {
        &self.choices[t * self.top_k..(t + 1) * self.top_k]
    }

    /// Tokens routed to each expert (the per-expert workload in tokens).
    pub fn expert_token_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_experts];
        for &e in &self.choices {
            counts[e as usize] += 1;
        }
        counts
    }

    /// Validate structural invariants (indices in range, distinct within a
    /// token). Used by tests and debug assertions.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.top_k >= 1 && self.top_k <= self.n_experts);
        anyhow::ensure!(self.choices.len() % self.top_k == 0);
        let mut seen = vec![u32::MAX; self.n_experts];
        for t in 0..self.n_tokens() {
            for &e in self.token(t) {
                anyhow::ensure!(
                    (e as usize) < self.n_experts,
                    "expert index {e} out of range"
                );
                anyhow::ensure!(
                    seen[e as usize] != t as u32,
                    "token {t} routed to expert {e} twice"
                );
                seen[e as usize] = t as u32;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accessors() {
        let tr = RoutingTrace {
            n_experts: 4,
            top_k: 2,
            choices: vec![0, 1, 2, 3, 0, 2],
        };
        assert_eq!(tr.n_tokens(), 3);
        assert_eq!(tr.token(1), &[2, 3]);
        assert_eq!(tr.expert_token_counts(), vec![2, 1, 2, 1]);
        tr.validate().unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let tr = RoutingTrace {
            n_experts: 2,
            top_k: 1,
            choices: vec![5],
        };
        assert!(tr.validate().is_err());
    }

    #[test]
    fn validate_rejects_duplicates_within_token() {
        let tr = RoutingTrace {
            n_experts: 4,
            top_k: 2,
            choices: vec![1, 1],
        };
        assert!(tr.validate().is_err());
    }
}
