//! Expert-activation priors (paper §3.2, Eq. 3 and Eq. 4).

use super::RoutingTrace;

/// The two profiling statistics the paper's algorithms consume.
#[derive(Clone, Debug)]
pub struct Priors {
    /// Normalized workload distribution V (Eq. 3): fraction of routed
    /// token-slots landing on each expert. Sums to 1.
    pub workload: Vec<f64>,
    /// Raw co-activation counts C (Eq. 4, left).
    pub coact_counts: Vec<u64>,
    /// Max-normalized co-activation matrix P in [0,1] (Eq. 4, right).
    pub coact: Vec<f64>,
    /// Experts per layer (matrix dimension).
    pub n_experts: usize,
}

impl Priors {
    /// Compute priors over a profiling batch (one or more layer traces with
    /// identical shapes — the paper computes per-layer priors; callers pass
    /// a single layer's trace, or several to pool).
    pub fn from_traces(traces: &[&RoutingTrace]) -> Priors {
        assert!(!traces.is_empty());
        let n = traces[0].n_experts;
        let mut v = vec![0u64; n];
        let mut c = vec![0u64; n * n];
        for tr in traces {
            assert_eq!(tr.n_experts, n, "mixed trace widths");
            for t in 0..tr.n_tokens() {
                let picks = tr.token(t);
                for &e in picks {
                    v[e as usize] += 1;
                }
                for i in 0..picks.len() {
                    for j in (i + 1)..picks.len() {
                        let (a, b) = (picks[i] as usize, picks[j] as usize);
                        c[a * n + b] += 1;
                        c[b * n + a] += 1;
                    }
                }
            }
        }
        let total: u64 = v.iter().sum();
        let workload: Vec<f64> = v
            .iter()
            .map(|&x| {
                if total == 0 {
                    0.0
                } else {
                    x as f64 / total as f64
                }
            })
            .collect();
        let cmax = c.iter().copied().max().unwrap_or(0).max(1) as f64;
        let coact: Vec<f64> = c.iter().map(|&x| x as f64 / cmax).collect();
        Priors {
            workload,
            coact_counts: c,
            coact,
            n_experts: n,
        }
    }

    /// Priors of a single layer's trace.
    pub fn from_trace(tr: &RoutingTrace) -> Priors {
        Priors::from_traces(&[tr])
    }

    /// P[i,j] accessor.
    pub fn p(&self, i: usize, j: usize) -> f64 {
        self.coact[i * self.n_experts + j]
    }

    /// The (i, j) pair with the highest co-activation, i < j.
    pub fn hottest_pair(&self) -> (usize, usize) {
        let n = self.n_experts;
        let mut best = (0, 1);
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..n {
            for j in (i + 1)..n {
                if self.p(i, j) > best_v {
                    best_v = self.p(i, j);
                    best = (i, j);
                }
            }
        }
        best
    }

    /// Workload share of a set of experts.
    pub fn set_workload(&self, experts: &[usize]) -> f64 {
        experts.iter().map(|&e| self.workload[e]).sum()
    }

    /// Average pairwise co-activation within a set (intra-cluster
    /// collaboration, paper §4.2 stage 1).
    pub fn intra_collab(&self, set: &[usize]) -> f64 {
        if set.len() < 2 {
            return 0.0;
        }
        let mut s = 0.0;
        let mut pairs = 0usize;
        for i in 0..set.len() {
            for j in (i + 1)..set.len() {
                s += self.p(set[i], set[j]);
                pairs += 1;
            }
        }
        s / pairs as f64
    }

    /// Average pairwise co-activation across two disjoint sets
    /// (inter-cluster collaboration).
    pub fn inter_collab(&self, a: &[usize], b: &[usize]) -> f64 {
        if a.is_empty() || b.is_empty() {
            return 0.0;
        }
        let mut s = 0.0;
        for &i in a {
            for &j in b {
                s += self.p(i, j);
            }
        }
        s / (a.len() * b.len()) as f64
    }
}

/// Eq. 3 standalone helper.
pub fn workload_vector(tr: &RoutingTrace) -> Vec<f64> {
    Priors::from_trace(tr).workload
}

/// Eq. 4 standalone helper: max-normalized co-activation matrix.
pub fn coactivation(tr: &RoutingTrace) -> Vec<f64> {
    Priors::from_trace(tr).coact
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> RoutingTrace {
        // 3 tokens, k=2, 4 experts: (0,1) (0,1) (2,3)
        RoutingTrace {
            n_experts: 4,
            top_k: 2,
            choices: vec![0, 1, 0, 1, 2, 3],
        }
    }

    #[test]
    fn workload_normalized() {
        let p = Priors::from_trace(&toy());
        let sum: f64 = p.workload.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((p.workload[0] - 2.0 / 6.0).abs() < 1e-12);
        assert!((p.workload[3] - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn coactivation_symmetric_and_normalized() {
        let p = Priors::from_trace(&toy());
        assert_eq!(p.p(0, 1), 1.0); // hottest pair (2 co-activations)
        assert_eq!(p.p(1, 0), 1.0);
        assert_eq!(p.p(2, 3), 0.5);
        assert_eq!(p.p(0, 2), 0.0);
        assert_eq!(p.hottest_pair(), (0, 1));
        for i in 0..4 {
            for j in 0..4 {
                assert!((p.p(i, j) - p.p(j, i)).abs() < 1e-12);
                assert!((0.0..=1.0).contains(&p.p(i, j)));
            }
        }
    }

    #[test]
    fn collab_metrics() {
        let p = Priors::from_trace(&toy());
        assert_eq!(p.intra_collab(&[0, 1]), 1.0);
        assert_eq!(p.intra_collab(&[0]), 0.0);
        assert_eq!(p.inter_collab(&[0, 1], &[2, 3]), 0.0);
        assert!((p.set_workload(&[0, 1]) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn pooling_traces_accumulates() {
        let t = toy();
        let single = Priors::from_trace(&t);
        let double = Priors::from_traces(&[&t, &t]);
        // normalized quantities are invariant under pooling identical traces
        for i in 0..4 {
            assert!((single.workload[i] - double.workload[i]).abs() < 1e-12);
        }
        assert_eq!(
            double.coact_counts[1], // (0,1) counted 4 times
            4
        );
    }
}
