//! Synthetic routing-trace generator.
//!
//! Generative model per MoE layer (see DESIGN.md §Substitutions):
//!
//! - **Specialization**: expert popularity follows a Zipf law over a random
//!   permutation of the index space (popularity is uncorrelated with index,
//!   as in real checkpoints).
//! - **Collaboration**: `n_topics` latent topics, each with an affinity set
//!   of experts chosen by *stratified* sampling over the index space — one
//!   expert per contiguous index stratum — so co-activated experts are
//!   spread out in the arbitrary index order (the paper's Figure 3 shows
//!   off-diagonal co-activation mass; a default contiguous expert layout
//!   therefore co-locates slightly *worse* than chance, consistent with
//!   Table 4 where the un-clustered Mozart-B C_T sits above the uniform
//!   expectation).
//! - A token is *topical* with probability `topic_prob`; a topical token
//!   draws `in_topic` of its k experts from its topic's affinity set
//!   (popularity-weighted) and the rest globally; a non-topical token draws
//!   all k globally by popularity.

use crate::config::ModelConfig;
use crate::util::rng::{zipf_weights, AliasTable, Rng};

use super::RoutingTrace;

/// Generator parameters; tuned per model so the derived C_T statistics land
/// on the paper's Table 4 anchors (see `report::table4`).
#[derive(Clone, Debug)]
pub struct TraceParams {
    /// Zipf exponent for expert popularity.
    pub alpha: f64,
    /// Number of latent collaboration topics per layer.
    pub n_topics: usize,
    /// Affinity-set size of each topic.
    pub topic_size: usize,
    /// Probability a token is topical.
    pub topic_prob: f64,
    /// How many of a topical token's k picks come from its topic set.
    pub in_topic: usize,
}

impl TraceParams {
    /// Defaults tuned against Table 4:
    /// topics partition the expert space into `n_experts / topic_size`
    /// disjoint affinity sets of one expert per stratum; a topical token
    /// takes `in_topic` picks from its set.
    pub fn for_model(m: &ModelConfig) -> TraceParams {
        let topic_size = (m.n_experts / 16).max(2);
        TraceParams {
            alpha: 0.45,
            n_topics: m.n_experts / topic_size,
            topic_size,
            topic_prob: 0.42,
            in_topic: topic_size.min((m.top_k / 2).max(2)).min(m.top_k),
        }
    }
}

/// Per-layer latent state.
#[derive(Clone, Debug)]
struct LayerModel {
    /// Unnormalized popularity weights.
    popularity: Vec<f64>,
    /// O(1) sampler over `popularity` (the hot path).
    popularity_alias: AliasTable,
    /// Affinity sets, one per topic.
    topics: Vec<Vec<usize>>,
    /// Topic draw weights (some topics are hotter than others).
    topic_weights: Vec<f64>,
}

/// Deterministic trace generator for all MoE layers of one model.
#[derive(Clone, Debug)]
pub struct TraceGen {
    /// Routed experts per MoE layer.
    pub n_experts: usize,
    /// Routing fanout per token.
    pub top_k: usize,
    /// Generator parameters (skew, topic structure).
    pub params: TraceParams,
    layers: Vec<LayerModel>,
}

impl TraceGen {
    /// Build the latent per-layer models from `seed`.
    pub fn new(model: &ModelConfig, params: TraceParams, seed: u64) -> TraceGen {
        let mut root = Rng::new(seed);
        let n = model.n_experts;
        let layers = (0..model.n_moe_layers())
            .map(|l| {
                let mut rng = root.fork(l as u64);
                let perm = rng.permutation(n);
                let popularity = zipf_weights(n, params.alpha, &perm);
                // Stratified *partition* into affinity sets: the index
                // space splits into `topic_size` strata of `n_topics`
                // experts; a random within-stratum permutation deals one
                // member of every stratum to each topic. Topics are
                // disjoint, jointly exhaustive, and spread across the
                // arbitrary index order.
                let n_strata = params.topic_size;
                let stratum = n / n_strata; // experts per stratum == n_topics
                assert_eq!(stratum, params.n_topics, "topics must partition");
                let deals: Vec<Vec<usize>> =
                    (0..n_strata).map(|_| rng.permutation(stratum)).collect();
                let topics = (0..params.n_topics)
                    .map(|t| {
                        (0..n_strata)
                            .map(|s| s * stratum + deals[s][t])
                            .collect::<Vec<_>>()
                    })
                    .collect::<Vec<_>>();
                let topic_perm = rng.permutation(params.n_topics);
                let topic_weights = zipf_weights(params.n_topics, 0.5, &topic_perm);
                LayerModel {
                    popularity_alias: AliasTable::new(&popularity),
                    popularity,
                    topics,
                    topic_weights,
                }
            })
            .collect();
        TraceGen {
            n_experts: n,
            top_k: model.top_k,
            params,
            layers,
        }
    }

    /// Convenience: default params for the model.
    pub fn for_model(model: &ModelConfig, seed: u64) -> TraceGen {
        TraceGen::new(model, TraceParams::for_model(model), seed)
    }

    /// Number of MoE layers modeled.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Sample the routing of `n_tokens` tokens through MoE layer `layer`.
    /// `rng` carries the per-step randomness so successive training steps
    /// see fresh tokens from the same stationary distribution.
    pub fn sample_layer(&self, layer: usize, n_tokens: usize, rng: &mut Rng) -> RoutingTrace {
        let lm = &self.layers[layer % self.layers.len()];
        let k = self.top_k;
        let mut choices = Vec::with_capacity(n_tokens * k);
        let mut mask = vec![false; self.n_experts];
        // scratch buffers hoisted out of the token loop (this is the hot
        // path of every simulated experiment)
        let mut picked: Vec<u32> = Vec::with_capacity(k);
        let max_topic = self.params.topic_size;
        let mut topic_w: Vec<f64> = vec![0.0; max_topic];
        for _ in 0..n_tokens {
            picked.clear();
            let topical = rng.f64() < self.params.topic_prob;
            if topical {
                let t = rng.weighted(&lm.topic_weights);
                let set = &lm.topics[t];
                // popularity-weighted draw within the affinity set,
                // in-place masked sampling without replacement
                let take = self.params.in_topic.min(k).min(set.len());
                for (slot, &e) in set.iter().enumerate() {
                    topic_w[slot] = lm.popularity[e];
                }
                for _ in 0..take {
                    let idx = rng.weighted(&topic_w[..set.len()]);
                    topic_w[idx] = 0.0;
                    let e = set[idx] as u32;
                    if !mask[e as usize] {
                        mask[e as usize] = true;
                        picked.push(e);
                    }
                }
            }
            // fill the remaining slots from the global popularity law
            while picked.len() < k {
                let e = lm.popularity_alias.sample(rng) as u32;
                if !mask[e as usize] {
                    mask[e as usize] = true;
                    picked.push(e);
                }
            }
            for &e in &picked {
                mask[e as usize] = false;
            }
            choices.extend_from_slice(&picked);
        }
        RoutingTrace {
            n_experts: self.n_experts,
            top_k: k,
            choices,
        }
    }

    /// Sample a profiling batch: all layers, `n_tokens` each (the paper runs
    /// the prefill of an instruction-tuning set through the model once).
    pub fn profile(&self, n_tokens: usize, seed: u64) -> Vec<RoutingTrace> {
        let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
        (0..self.n_layers())
            .map(|l| {
                let mut r = rng.fork(l as u64);
                self.sample_layer(l, n_tokens, &mut r)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelId};

    fn qwen_gen() -> TraceGen {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        TraceGen::for_model(&m, 7)
    }

    #[test]
    fn traces_are_structurally_valid() {
        let g = qwen_gen();
        let mut rng = Rng::new(1);
        let tr = g.sample_layer(0, 500, &mut rng);
        assert_eq!(tr.n_tokens(), 500);
        tr.validate().unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let g1 = qwen_gen();
        let g2 = qwen_gen();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(
            g1.sample_layer(3, 100, &mut r1).choices,
            g2.sample_layer(3, 100, &mut r2).choices
        );
    }

    #[test]
    fn popularity_is_skewed() {
        let g = qwen_gen();
        let mut rng = Rng::new(2);
        let tr = g.sample_layer(0, 20_000, &mut rng);
        let counts = tr.expert_token_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        // Figure 3 shows clearly unbalanced activation frequencies.
        assert!(max / min.max(1.0) > 2.0, "max={max} min={min}");
    }

    #[test]
    fn all_layers_profile() {
        let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
        let g = TraceGen::for_model(&m, 11);
        let prof = g.profile(64, 3);
        assert_eq!(prof.len(), m.n_moe_layers());
        for tr in &prof {
            tr.validate().unwrap();
        }
    }

    #[test]
    fn topics_are_stratified() {
        // every stratum of the index space contributes exactly one member
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        let p = TraceParams::for_model(&m);
        let g = TraceGen::new(&m, p.clone(), 13);
        let stratum = m.n_experts / p.topic_size;
        for lm_topic in &g.layers[0].topics {
            let mut strata: Vec<usize> = lm_topic.iter().map(|e| e / stratum).collect();
            strata.sort_unstable();
            strata.dedup();
            assert_eq!(strata.len(), p.topic_size);
        }
    }
}
