//! Open-loop request-arrival processes for the serving workload.
//!
//! A serving experiment replays *traffic*, not a fixed batch: requests
//! arrive according to a stochastic process regardless of whether the
//! server keeps up (open-loop — the generator never waits for the
//! system, which is what makes saturation visible). Four processes are
//! supported, all seeded and bit-reproducible:
//!
//! * **Poisson** — memoryless baseline with exponential interarrivals
//!   (CV = 1). The closed-form M/D/1 differential test anchors on it.
//! * **MMPP** (`bursty`) — a two-state interrupted Poisson process: the
//!   source alternates between an ON state emitting at `rate * burst`
//!   and a silent OFF state, with exponential dwell times chosen so the
//!   ON fraction is `1/burst`. The long-run mean rate equals `rate`,
//!   but interarrivals are overdispersed (CV > 1) — the "burstier than
//!   Poisson at the same rate" property the statistical tests assert.
//! * **Diurnal** — a nonhomogeneous Poisson process with sinusoidally
//!   modulated intensity `rate * (1 + amplitude * sin(2πt/period))`,
//!   sampled exactly by thinning against the peak intensity.
//! * **Trace** — timestamps and token counts loaded from a file, for
//!   replaying recorded traffic (format: [`emit_trace`]).
//!
//! [`ArrivalProcess::generate`] turns a process plus a [`RequestShape`]
//! into a sorted [`Request`] stream; [`ArrivalProcess::at_load`] scales
//! the offered load for saturation sweeps.

use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};

/// One serving request: an arrival timestamp plus its token footprint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Arrival-order index (0-based; ties broken by generation order).
    pub id: u64,
    /// Arrival time in seconds from the start of the experiment.
    pub arrival_s: f64,
    /// Prompt tokens processed in the prefill pass.
    pub prefill_tokens: u32,
    /// Output tokens produced by the decode loop.
    pub decode_tokens: u32,
}

/// Token-count distribution for generated requests: prefill and decode
/// lengths drawn log-uniformly from inclusive ranges (log-uniform because
/// real prompt-length distributions are heavy-tailed — a uniform draw
/// over [16, 2048] would make almost every prompt long).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestShape {
    /// Minimum prefill (prompt) tokens, inclusive.
    pub prefill_min: u32,
    /// Maximum prefill (prompt) tokens, inclusive.
    pub prefill_max: u32,
    /// Minimum decode (output) tokens, inclusive.
    pub decode_min: u32,
    /// Maximum decode (output) tokens, inclusive.
    pub decode_max: u32,
}

impl Default for RequestShape {
    fn default() -> Self {
        RequestShape {
            prefill_min: 64,
            prefill_max: 1024,
            decode_min: 16,
            decode_max: 256,
        }
    }
}

impl RequestShape {
    /// Degenerate shape: every request carries exactly `prefill` prompt
    /// tokens and `decode` output tokens. Deterministic service demand is
    /// what the M/D/1 Pollaczek–Khinchine differential test requires.
    pub fn fixed(prefill: u32, decode: u32) -> Self {
        RequestShape {
            prefill_min: prefill,
            prefill_max: prefill,
            decode_min: decode,
            decode_max: decode,
        }
    }

    fn draw(&self, lo: u32, hi: u32, rng: &mut Rng) -> u32 {
        assert!(hi >= lo, "token range [{lo}, {hi}]");
        if lo == hi {
            // degenerate range: any fixed value is fine, including 0
            // (decode_tokens = 0 models single-shot prefill-only requests)
            return lo;
        }
        assert!(lo >= 1, "log-uniform range needs lo >= 1, got [{lo}, {hi}]");
        // log-uniform over [lo, hi], rounded to the nearest integer
        let (a, b) = (lo as f64, hi as f64);
        let v = a * (b / a).powf(rng.f64());
        (v.round() as u32).clamp(lo, hi)
    }

    /// Draw one (prefill, decode) pair.
    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        let p = self.draw(self.prefill_min, self.prefill_max, rng);
        let d = self.draw(self.decode_min, self.decode_max, rng);
        (p, d)
    }
}

/// A seeded open-loop arrival process (see the module docs for the four
/// variants and their statistical contracts).
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `rate` requests/s.
    Poisson {
        /// Mean arrival rate in requests per second.
        rate: f64,
    },
    /// Two-state Markov-modulated (interrupted) Poisson process with the
    /// same long-run mean rate as `Poisson { rate }` but burstier
    /// interarrivals (CV > 1).
    Mmpp {
        /// Long-run mean arrival rate in requests per second.
        rate: f64,
        /// Burstiness factor (> 1): the ON state emits at `rate * burst`
        /// and occupies a `1/burst` fraction of time.
        burst: f64,
        /// Mean dwell time in the ON state, seconds (OFF dwells are
        /// `dwell_s * (burst - 1)` so the ON fraction is `1/burst`).
        dwell_s: f64,
    },
    /// Nonhomogeneous Poisson with sinusoidal intensity
    /// `rate * (1 + amplitude * sin(2πt/period_s))`, sampled by thinning.
    Diurnal {
        /// Mean arrival rate in requests per second (the sinusoid's mean).
        rate: f64,
        /// Modulation period in seconds (a compressed "day").
        period_s: f64,
        /// Relative modulation depth in [0, 1].
        amplitude: f64,
    },
    /// Arrivals replayed from a file (see [`emit_trace`] for the format).
    Trace {
        /// Path the trace was loaded from (for labels and artifacts).
        path: String,
        /// `(arrival_s, prefill_tokens, decode_tokens)` rows, sorted by
        /// arrival time.
        rows: Vec<(f64, u32, u32)>,
    },
}

impl ArrivalProcess {
    /// Parse a CLI spec:
    ///
    /// * `poisson:RATE`
    /// * `mmpp:RATE[:BURST[:DWELL_S]]` (alias `bursty:`; defaults
    ///   `BURST=4`, `DWELL_S=1`)
    /// * `diurnal:RATE[:PERIOD_S[:AMPLITUDE]]` (defaults `PERIOD_S=60`,
    ///   `AMPLITUDE=0.8`)
    /// * `trace:FILE` (loads the file eagerly)
    pub fn parse(spec: &str) -> Result<ArrivalProcess> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();
        let num = |i: usize, name: &str, default: Option<f64>| -> Result<f64> {
            match rest.get(i) {
                Some(s) => s
                    .parse::<f64>()
                    .with_context(|| format!("bad {name} `{s}` in arrival spec `{spec}`")),
                None => default
                    .with_context(|| format!("arrival spec `{spec}` is missing {name}")),
            }
        };
        let proc = match kind {
            "poisson" => {
                ensure!(rest.len() <= 1, "poisson takes one field: poisson:RATE");
                ArrivalProcess::Poisson {
                    rate: num(0, "RATE", None)?,
                }
            }
            "mmpp" | "bursty" => {
                ensure!(rest.len() <= 3, "{kind} takes mmpp:RATE[:BURST[:DWELL_S]]");
                ArrivalProcess::Mmpp {
                    rate: num(0, "RATE", None)?,
                    burst: num(1, "BURST", Some(4.0))?,
                    dwell_s: num(2, "DWELL_S", Some(1.0))?,
                }
            }
            "diurnal" => {
                ensure!(
                    rest.len() <= 3,
                    "diurnal takes diurnal:RATE[:PERIOD_S[:AMPLITUDE]]"
                );
                ArrivalProcess::Diurnal {
                    rate: num(0, "RATE", None)?,
                    period_s: num(1, "PERIOD_S", Some(60.0))?,
                    amplitude: num(2, "AMPLITUDE", Some(0.8))?,
                }
            }
            "trace" => {
                ensure!(rest.len() == 1, "trace takes one field: trace:FILE");
                let path = rest[0].to_string();
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading arrival trace `{path}`"))?;
                let rows = parse_trace(&text)
                    .with_context(|| format!("parsing arrival trace `{path}`"))?;
                ArrivalProcess::Trace { path, rows }
            }
            other => bail!(
                "unknown arrival process `{other}` in `{spec}` \
                 (expected poisson | mmpp | bursty | diurnal | trace)"
            ),
        };
        proc.check()?;
        Ok(proc)
    }

    fn check(&self) -> Result<()> {
        match *self {
            ArrivalProcess::Poisson { rate } => {
                ensure!(rate > 0.0 && rate.is_finite(), "poisson rate must be > 0");
            }
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => {
                ensure!(rate > 0.0 && rate.is_finite(), "mmpp rate must be > 0");
                ensure!(burst > 1.0 && burst.is_finite(), "mmpp burst must be > 1");
                ensure!(dwell_s > 0.0 && dwell_s.is_finite(), "mmpp dwell must be > 0");
            }
            ArrivalProcess::Diurnal { rate, period_s, amplitude } => {
                ensure!(rate > 0.0 && rate.is_finite(), "diurnal rate must be > 0");
                ensure!(period_s > 0.0 && period_s.is_finite(), "diurnal period must be > 0");
                ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "diurnal amplitude must be in [0, 1]"
                );
            }
            ArrivalProcess::Trace { ref rows, .. } => {
                ensure!(!rows.is_empty(), "arrival trace is empty");
            }
        }
        Ok(())
    }

    /// Short human label for tables and artifacts (e.g. `poisson:100`).
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { rate } => format!("poisson:{rate}"),
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => {
                format!("mmpp:{rate}:{burst}:{dwell_s}")
            }
            ArrivalProcess::Diurnal { rate, period_s, amplitude } => {
                format!("diurnal:{rate}:{period_s}:{amplitude}")
            }
            ArrivalProcess::Trace { path, .. } => format!("trace:{path}"),
        }
    }

    /// Long-run mean arrival rate in requests per second. For a file
    /// trace this is the empirical rate over the recorded span.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate }
            | ArrivalProcess::Mmpp { rate, .. }
            | ArrivalProcess::Diurnal { rate, .. } => *rate,
            ArrivalProcess::Trace { rows, .. } => {
                let span = rows.last().map_or(0.0, |r| r.0);
                if span > 0.0 {
                    rows.len() as f64 / span
                } else {
                    rows.len() as f64
                }
            }
        }
    }

    /// The same process at `mult` times the offered load: synthetic
    /// processes scale their rate; a file trace compresses its
    /// timestamps by `mult` (the standard trace-replay speedup).
    pub fn at_load(&self, mult: f64) -> ArrivalProcess {
        assert!(mult > 0.0, "load multiplier must be > 0");
        match self.clone() {
            ArrivalProcess::Poisson { rate } => ArrivalProcess::Poisson { rate: rate * mult },
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => ArrivalProcess::Mmpp {
                rate: rate * mult,
                burst,
                dwell_s,
            },
            ArrivalProcess::Diurnal { rate, period_s, amplitude } => ArrivalProcess::Diurnal {
                rate: rate * mult,
                period_s,
                amplitude,
            },
            ArrivalProcess::Trace { path, rows } => ArrivalProcess::Trace {
                path,
                rows: rows.into_iter().map(|(t, p, d)| (t / mult, p, d)).collect(),
            },
        }
    }

    /// Generate the request stream over `[0, duration_s)`.
    ///
    /// Deterministic in `(process, duration_s, shape, seed)` alone: the
    /// arrival-time stream and the token-shape stream are independent
    /// forks of one seeded [`Rng`], so the result is bit-identical
    /// regardless of thread count or call site.
    pub fn generate(&self, duration_s: f64, shape: &RequestShape, seed: u64) -> Vec<Request> {
        assert!(duration_s > 0.0, "duration must be > 0");
        let mut root = Rng::new(seed ^ 0x5e7e_a9b1_03d4_c2f7);
        let mut time_rng = root.fork(1);
        let mut shape_rng = root.fork(2);

        let mut out = Vec::new();
        match self {
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0;
                loop {
                    t += exp_sample(&mut time_rng, 1.0 / rate);
                    if t >= duration_s {
                        break;
                    }
                    out.push((t, 0, 0));
                }
            }
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => {
                // interrupted Poisson: ON emits at rate*burst for mean
                // dwell_s, OFF emits nothing for mean dwell_s*(burst-1)
                let on_rate = rate * burst;
                let on_dwell = *dwell_s;
                let off_dwell = dwell_s * (burst - 1.0);
                // start in the stationary state distribution
                let mut on = time_rng.f64() < 1.0 / burst;
                let mut t = 0.0;
                while t < duration_s {
                    let dwell = exp_sample(&mut time_rng, if on { on_dwell } else { off_dwell });
                    let end = (t + dwell).min(duration_s);
                    if on {
                        let mut a = t;
                        loop {
                            a += exp_sample(&mut time_rng, 1.0 / on_rate);
                            if a >= end {
                                break;
                            }
                            out.push((a, 0, 0));
                        }
                    }
                    t = end;
                    on = !on;
                }
            }
            ArrivalProcess::Diurnal { rate, period_s, amplitude } => {
                // exact thinning against the peak intensity
                let lambda_max = rate * (1.0 + amplitude);
                let mut t = 0.0;
                loop {
                    t += exp_sample(&mut time_rng, 1.0 / lambda_max);
                    if t >= duration_s {
                        break;
                    }
                    let lambda_t = rate
                        * (1.0
                            + amplitude
                                * (2.0 * std::f64::consts::PI * t / period_s).sin());
                    if time_rng.f64() * lambda_max < lambda_t {
                        out.push((t, 0, 0));
                    }
                }
            }
            ArrivalProcess::Trace { rows, .. } => {
                for &(t, p, d) in rows {
                    if t < duration_s {
                        out.push((t, p, d));
                    }
                }
            }
        }

        let from_trace = matches!(self, ArrivalProcess::Trace { .. });
        out.iter()
            .enumerate()
            .map(|(i, &(t, p, d))| {
                let (p, d) = if from_trace { (p, d) } else { shape.sample(&mut shape_rng) };
                Request {
                    id: i as u64,
                    arrival_s: t,
                    prefill_tokens: p,
                    decode_tokens: d,
                }
            })
            .collect()
    }
}

/// One exponential sample with the given mean (inverse CDF on `[0, 1)`).
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).ln()
}

/// Render a request stream in the `mozart-serve-trace v1` text format:
/// a magic header line, then one `arrival_s prefill decode` row per
/// request. Round-trips through [`parse_trace`].
pub fn emit_trace(requests: &[Request]) -> String {
    let mut s = String::from("# mozart-serve-trace v1\n# arrival_s prefill_tokens decode_tokens\n");
    for r in requests {
        s.push_str(&format!(
            "{:.9} {} {}\n",
            r.arrival_s, r.prefill_tokens, r.decode_tokens
        ));
    }
    s
}

/// Parse the `mozart-serve-trace v1` text format (see [`emit_trace`]).
/// Comment lines start with `#`; rows must be sorted by arrival time.
pub fn parse_trace(text: &str) -> Result<Vec<(f64, u32, u32)>> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty trace file")?;
    ensure!(
        header.trim() == "# mozart-serve-trace v1",
        "bad trace header `{header}` (expected `# mozart-serve-trace v1`)"
    );
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        let line = line.trim();
        if line.starts_with('#') {
            continue;
        }
        let mut f = line.split_whitespace();
        let t: f64 = f
            .next()
            .context("missing arrival_s")?
            .parse()
            .with_context(|| format!("trace row {i}: bad arrival_s in `{line}`"))?;
        let p: u32 = f
            .next()
            .context("missing prefill_tokens")?
            .parse()
            .with_context(|| format!("trace row {i}: bad prefill_tokens in `{line}`"))?;
        let d: u32 = f
            .next()
            .context("missing decode_tokens")?
            .parse()
            .with_context(|| format!("trace row {i}: bad decode_tokens in `{line}`"))?;
        ensure!(f.next().is_none(), "trace row {i}: extra fields in `{line}`");
        ensure!(t >= 0.0 && t.is_finite(), "trace row {i}: arrival_s {t} < 0");
        // decode 0 is legal (prefill-only request); prefill 0 is not
        ensure!(p >= 1, "trace row {i}: prefill_tokens must be >= 1");
        if let Some(&(prev, _, _)) = rows.last() {
            ensure!(
                t >= prev,
                "trace row {i}: arrivals out of order ({t} < {prev})"
            );
        }
        rows.push((t, p, d));
    }
    ensure!(!rows.is_empty(), "trace has no rows");
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn interarrivals(reqs: &[Request]) -> Vec<f64> {
        reqs.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect()
    }

    #[test]
    fn parse_grammar_and_labels() {
        match ArrivalProcess::parse("poisson:100").unwrap() {
            ArrivalProcess::Poisson { rate } => assert_eq!(rate, 100.0),
            p => panic!("{p:?}"),
        }
        match ArrivalProcess::parse("bursty:50").unwrap() {
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => {
                assert_eq!((rate, burst, dwell_s), (50.0, 4.0, 1.0));
            }
            p => panic!("{p:?}"),
        }
        match ArrivalProcess::parse("mmpp:50:8:0.5").unwrap() {
            ArrivalProcess::Mmpp { rate, burst, dwell_s } => {
                assert_eq!((rate, burst, dwell_s), (50.0, 8.0, 0.5));
            }
            p => panic!("{p:?}"),
        }
        match ArrivalProcess::parse("diurnal:20:30:0.5").unwrap() {
            ArrivalProcess::Diurnal { rate, period_s, amplitude } => {
                assert_eq!((rate, period_s, amplitude), (20.0, 30.0, 0.5));
            }
            p => panic!("{p:?}"),
        }
        assert_eq!(
            ArrivalProcess::parse("poisson:100").unwrap().label(),
            "poisson:100"
        );
        for bad in [
            "poisson", "poisson:0", "poisson:-3", "mmpp:10:1", "mmpp:10:4:0",
            "diurnal:10:60:1.5", "uniform:5", "", "poisson:abc",
        ] {
            assert!(ArrivalProcess::parse(bad).is_err(), "`{bad}` should fail");
        }
    }

    #[test]
    fn generation_is_seeded_and_sorted() {
        let shape = RequestShape::default();
        for spec in ["poisson:200", "mmpp:200:4:0.2", "diurnal:200:10:0.8"] {
            let p = ArrivalProcess::parse(spec).unwrap();
            let a = p.generate(5.0, &shape, 42);
            let b = p.generate(5.0, &shape, 42);
            assert_eq!(a, b, "{spec} not reproducible");
            let c = p.generate(5.0, &shape, 43);
            assert_ne!(a, c, "{spec} ignores the seed");
            assert!(!a.is_empty(), "{spec} generated nothing");
            for w in a.windows(2) {
                assert!(w[0].arrival_s <= w[1].arrival_s, "{spec} out of order");
                assert_eq!(w[0].id + 1, w[1].id);
            }
            for r in &a {
                assert!(r.arrival_s >= 0.0 && r.arrival_s < 5.0);
                assert!((shape.prefill_min..=shape.prefill_max).contains(&r.prefill_tokens));
                assert!((shape.decode_min..=shape.decode_max).contains(&r.decode_tokens));
            }
        }
    }

    /// Satellite 1: Poisson interarrival mean and CV within tolerance at a
    /// fixed seed (exponential interarrivals: mean 1/rate, CV 1).
    #[test]
    fn poisson_interarrival_moments() {
        let p = ArrivalProcess::Poisson { rate: 100.0 };
        let reqs = p.generate(200.0, &RequestShape::fixed(64, 16), 7);
        let gaps = interarrivals(&reqs);
        assert!(gaps.len() > 10_000, "n={}", gaps.len());
        let mean = stats::mean(&gaps);
        let cv = stats::cv(&gaps);
        assert!((mean - 0.01).abs() / 0.01 < 0.05, "mean={mean}");
        assert!((cv - 1.0).abs() < 0.05, "cv={cv}");
    }

    /// Satellite 1: the MMPP is provably burstier than Poisson at the
    /// same mean rate — interarrival CV well above 1 — while preserving
    /// the long-run rate.
    #[test]
    fn mmpp_is_burstier_than_poisson_at_same_rate() {
        let rate = 100.0;
        let dur = 200.0;
        let poisson = ArrivalProcess::Poisson { rate }
            .generate(dur, &RequestShape::fixed(64, 16), 7);
        let mmpp = ArrivalProcess::Mmpp { rate, burst: 8.0, dwell_s: 0.5 }
            .generate(dur, &RequestShape::fixed(64, 16), 7);
        // long-run mean rate preserved within 10%
        let got_rate = mmpp.len() as f64 / dur;
        assert!((got_rate - rate).abs() / rate < 0.10, "rate={got_rate}");
        let cv_p = stats::cv(&interarrivals(&poisson));
        let cv_m = stats::cv(&interarrivals(&mmpp));
        assert!(cv_m > 1.5, "mmpp cv={cv_m} not bursty");
        assert!(cv_m > cv_p + 0.3, "mmpp cv={cv_m} vs poisson cv={cv_p}");
    }

    #[test]
    fn diurnal_mean_rate_and_modulation() {
        let p = ArrivalProcess::Diurnal { rate: 100.0, period_s: 10.0, amplitude: 0.9 };
        let reqs = p.generate(100.0, &RequestShape::fixed(64, 16), 11);
        let got = reqs.len() as f64 / 100.0;
        assert!((got - 100.0).abs() / 100.0 < 0.1, "rate={got}");
        // peak half-periods (sin > 0) must carry more arrivals than troughs
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in &reqs {
            let phase = (r.arrival_s / 10.0).fract();
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak={peak} trough={trough}: no visible modulation"
        );
    }

    #[test]
    fn at_load_scales_offered_rate() {
        let p = ArrivalProcess::Poisson { rate: 50.0 };
        let lo = p.generate(100.0, &RequestShape::default(), 3).len() as f64;
        let hi = p.at_load(2.0).generate(100.0, &RequestShape::default(), 3).len() as f64;
        assert!((hi / lo - 2.0).abs() < 0.15, "lo={lo} hi={hi}");
    }

    /// Satellite 1: file-trace round trip — emit, parse, regenerate.
    #[test]
    fn trace_round_trips_through_emit_and_parse() {
        let p = ArrivalProcess::Poisson { rate: 40.0 };
        let reqs = p.generate(2.0, &RequestShape::default(), 5);
        let text = emit_trace(&reqs);
        let rows = parse_trace(&text).unwrap();
        assert_eq!(rows.len(), reqs.len());
        let replay = ArrivalProcess::Trace { path: "mem".into(), rows };
        let again = replay.generate(2.0, &RequestShape::default(), 999);
        assert_eq!(again.len(), reqs.len());
        for (a, b) in reqs.iter().zip(again.iter()) {
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-8);
            assert_eq!(a.prefill_tokens, b.prefill_tokens);
            assert_eq!(a.decode_tokens, b.decode_tokens);
        }
        // at_load on a trace compresses timestamps
        let fast = replay.at_load(2.0).generate(2.0, &RequestShape::default(), 0);
        assert_eq!(fast.len(), reqs.len());
        assert!((fast[1].arrival_s - reqs[1].arrival_s / 2.0).abs() < 1e-12);
    }

    #[test]
    fn parse_trace_rejects_malformed_input() {
        for bad in [
            "",
            "0.1 64 16\n",                              // no header
            "# mozart-serve-trace v1\n",                // no rows
            "# mozart-serve-trace v1\nnope 64 16\n",    // bad float
            "# mozart-serve-trace v1\n0.1 64\n",        // missing field
            "# mozart-serve-trace v1\n0.1 64 16 9\n",   // extra field
            "# mozart-serve-trace v1\n0.2 64 16\n0.1 64 16\n", // out of order
            "# mozart-serve-trace v1\n0.1 0 16\n",      // zero prefill
            "# mozart-serve-trace v2\n0.1 64 16\n",     // wrong version
        ] {
            assert!(parse_trace(bad).is_err(), "should reject: {bad:?}");
        }
        // decode 0 is a legal prefill-only request
        let rows = parse_trace("# mozart-serve-trace v1\n0.1 64 0\n").unwrap();
        assert_eq!(rows, vec![(0.1, 64, 0)]);
    }

    #[test]
    fn fixed_shape_is_degenerate() {
        let mut rng = Rng::new(1);
        let s = RequestShape::fixed(128, 32);
        for _ in 0..100 {
            assert_eq!(s.sample(&mut rng), (128, 32));
        }
    }
}
