//! Seeded property-testing helper: runs a property over `cases` random
//! inputs generated from a deterministic RNG; on failure, reports the case
//! seed so the exact input reproduces with `forall_seeded`.

use crate::util::rng::Rng;

/// Run `property(rng)` for `cases` independent seeded RNGs; panics with the
/// failing seed on the first error.
pub fn forall(name: &str, cases: usize, property: impl Fn(&mut Rng) -> Result<(), String>) {
    let mut root = Rng::new(0xF0_4A11 ^ name.len() as u64);
    for case in 0..cases {
        let seed = root.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random cloud of `n` objective vectors of arity `dims` for Pareto
/// property tests: coordinates are small integers plus a tiny jitter, so
/// one cloud carries long dominance chains, incomparable trade-offs, and
/// near-ties — the regimes a Pareto selection has to get right. Callers
/// that need *exact* duplicates copy a point afterwards. Shared by the
/// frontier properties in `tests/prop_invariants.rs`.
pub fn objective_cloud(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| {
            (0..dims)
                .map(|_| rng.below(8) as f64 + rng.f64() * 0.01)
                .collect()
        })
        .collect()
}

/// Random objective cloud with a *known* feasible/infeasible split for
/// constraint-handling tests: returns `(points, violation)` where
/// `violation[i] == 0.0` marks point `i` feasible and a positive value is
/// its (ranking-relevant) constraint violation. Roughly half the cloud is
/// infeasible; the first point is always feasible and (for `n >= 2`) the
/// second always infeasible, so both sides of the split are guaranteed
/// non-empty. Shared by the NSGA-II selection properties in
/// `tests/prop_invariants.rs` and the `metrics::pareto` unit tests.
pub fn constrained_objective_cloud(
    rng: &mut Rng,
    n: usize,
    dims: usize,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let points = objective_cloud(rng, n, dims);
    let mut violation: Vec<f64> = (0..n)
        .map(|_| {
            if rng.f64() < 0.5 {
                0.0
            } else {
                rng.f64() + 0.1
            }
        })
        .collect();
    if n >= 1 {
        violation[0] = 0.0;
    }
    if n >= 2 {
        violation[1] = rng.f64() + 0.1;
    }
    (points, violation)
}

/// Re-run a single failing case by seed.
pub fn forall_seeded(
    name: &str,
    seed: u64,
    property: impl Fn(&mut Rng) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = property(&mut rng) {
        panic!("property `{name}` failed (seed {seed:#x}): {msg}");
    }
}

/// Assertion helpers for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn passing_property() {
        super::forall("commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert!(a + b == b + a, "addition must commute");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        super::forall("always-fails", 5, |_| Err("nope".to_string()));
    }

    #[test]
    fn objective_cloud_shape_and_range() {
        let mut rng = crate::util::rng::Rng::new(5);
        let pts = super::objective_cloud(&mut rng, 17, 3);
        assert_eq!(pts.len(), 17);
        for p in &pts {
            assert_eq!(p.len(), 3);
            for &v in p {
                assert!((0.0..8.01).contains(&v), "coordinate out of range: {v}");
            }
        }
    }

    #[test]
    fn constrained_cloud_always_splits() {
        let mut rng = crate::util::rng::Rng::new(11);
        for n in [2usize, 3, 10, 40] {
            let (pts, viol) = super::constrained_objective_cloud(&mut rng, n, 3);
            assert_eq!(pts.len(), n);
            assert_eq!(viol.len(), n);
            assert_eq!(viol[0], 0.0, "first point must be feasible");
            assert!(viol[1] > 0.0, "second point must be infeasible");
            assert!(viol.iter().all(|&v| v >= 0.0));
        }
        let (pts, viol) = super::constrained_objective_cloud(&mut rng, 1, 2);
        assert_eq!((pts.len(), viol.len()), (1, 1));
        assert_eq!(viol[0], 0.0);
    }

    #[test]
    fn seeded_reproduction() {
        super::forall_seeded("det", 42, |rng| {
            let v = rng.below(10);
            let mut rng2 = crate::util::rng::Rng::new(42);
            prop_assert!(v == rng2.below(10), "same seed, same draw");
            Ok(())
        });
    }
}
