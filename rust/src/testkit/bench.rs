//! Minimal timing harness for `harness = false` benches.

use crate::util::stats;

/// Timing summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Timed passes (after one warmup).
    pub iters: usize,
    /// Mean wall time per pass (s).
    pub mean_s: f64,
    /// Standard deviation of the pass times (s).
    pub std_s: f64,
    /// Fastest pass (s).
    pub min_s: f64,
    /// Median pass (s).
    pub p50_s: f64,
    /// 95th-percentile pass (s).
    pub p95_s: f64,
}

impl BenchResult {
    /// Serialize for machine-readable bench artifacts (`BENCH_*.json`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("iters", Json::int(self.iters)),
            ("mean_s", Json::num(self.mean_s)),
            ("std_s", Json::num(self.std_s)),
            ("min_s", Json::num(self.min_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
        ])
    }

    /// One-line human-readable summary.
    pub fn render(&self) -> String {
        format!(
            "{:<44} iters={:<3} mean={:<12} p50={:<12} p95={:<12} min={}",
            self.name,
            self.iters,
            crate::util::fmt_secs(self.mean_s),
            crate::util::fmt_secs(self.p50_s),
            crate::util::fmt_secs(self.p95_s),
            crate::util::fmt_secs(self.min_s),
        )
    }
}

/// Run `f` with one warmup pass, then time `iters` passes and print a
/// summary line. The closure's return value is black-boxed to prevent the
/// optimizer from eliding the work.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters >= 1);
    std::hint::black_box(f()); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        std_s: stats::std(&samples),
        min_s: stats::min(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
    };
    println!("{}", r.render());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let r = bench("spin", 3, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.iters, 3);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
        assert!(r.p50_s <= r.p95_s + 1e-12);
    }

    #[test]
    fn json_serialization_carries_fields() {
        let r = BenchResult {
            name: "x".to_string(),
            iters: 2,
            mean_s: 1.0,
            std_s: 0.0,
            min_s: 1.0,
            p50_s: 1.0,
            p95_s: 1.0,
        };
        let s = r.to_json().render();
        assert!(s.contains("\"name\":\"x\""));
        assert!(s.contains("\"iters\":2"));
        assert!(s.contains("\"mean_s\":1"));
    }
}
