//! Test/bench substrate: a small timing harness and a property-testing
//! helper. The offline crate set has neither `criterion` nor `proptest`;
//! these provide the subset we need — warmup + repeated timing with summary
//! statistics for `cargo bench` (benches declare `harness = false`), and
//! seeded random-case generation with failure reproduction for property
//! tests.

pub mod bench;
pub mod prop;

pub use bench::{bench, BenchResult};
pub use prop::{constrained_objective_cloud, forall, objective_cloud};
