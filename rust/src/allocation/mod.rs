//! Cluster → group allocation (paper §4.2 Stage-2, Eq. 5).
//!
//! Every group of `chiplets_per_group` MoE chiplets shares one DRAM I/O, so
//! the per-*group* workload must be balanced. The paper formalizes the
//! assignment as a binary integer program: assign the `N_c` clusters to
//! `N_g` groups (each group takes exactly `N_c / N_g` clusters) minimizing
//! the deviation of per-group workload from the uniform `1/N_g` target.
//!
//! We provide an exact branch-and-bound solver for the paper-scale instance
//! (16 clusters → 4 groups ≈ 2.6M partitions before pruning, ~ms after) and
//! a greedy LPT + pairwise-refinement fallback for larger instances, with a
//! property test asserting the exact solver never loses to the greedy one.

use crate::clustering::Clustering;
use crate::trace::Priors;

/// The assignment result: `groups[g]` lists the cluster ids in group `g`;
/// `chiplet_of_cluster[c]` is the flat chiplet index assigned to cluster `c`
/// (clusters within a group are mapped to the group's chiplets in order).
#[derive(Clone, Debug)]
pub struct Allocation {
    /// `groups[g]` lists the cluster ids assigned to group `g`.
    pub groups: Vec<Vec<usize>>,
    /// Total number of clusters assigned.
    pub n_clusters: usize,
}

impl Allocation {
    /// Clusters per group (uniform by the Eq. 5 cardinality constraint).
    pub fn clusters_per_group(&self) -> usize {
        self.n_clusters / self.groups.len()
    }

    /// Flat chiplet index for each cluster: group-major order.
    pub fn chiplet_of_cluster(&self) -> Vec<usize> {
        let per = self.clusters_per_group();
        let mut map = vec![usize::MAX; self.n_clusters];
        for (g, cs) in self.groups.iter().enumerate() {
            for (slot, &c) in cs.iter().enumerate() {
                map[c] = g * per + slot;
            }
        }
        map
    }

    /// Identity allocation: cluster c -> chiplet c (default layout).
    pub fn identity(n_clusters: usize, n_groups: usize) -> Allocation {
        assert_eq!(n_clusters % n_groups, 0);
        let per = n_clusters / n_groups;
        Allocation {
            groups: (0..n_groups)
                .map(|g| (g * per..(g + 1) * per).collect())
                .collect(),
            n_clusters,
        }
    }

    /// Eq. 5 objective: L1 deviation of per-group workload from uniform.
    pub fn objective(&self, cluster_workloads: &[f64]) -> f64 {
        let ng = self.groups.len();
        let target = cluster_workloads.iter().sum::<f64>() / ng as f64;
        self.groups
            .iter()
            .map(|cs| {
                let w: f64 = cs.iter().map(|&c| cluster_workloads[c]).sum();
                (w - target).abs()
            })
            .sum()
    }

    /// Per-group workloads.
    pub fn group_workloads(&self, cluster_workloads: &[f64]) -> Vec<f64> {
        self.groups
            .iter()
            .map(|cs| cs.iter().map(|&c| cluster_workloads[c]).sum())
            .collect()
    }

    /// Structural invariants (Eq. 5 constraints): every cluster in exactly
    /// one group, every group holding exactly `N_c / N_g` clusters.
    pub fn validate(&self) -> anyhow::Result<()> {
        let per = self.clusters_per_group();
        anyhow::ensure!(per * self.groups.len() == self.n_clusters);
        let mut seen = vec![false; self.n_clusters];
        for g in &self.groups {
            anyhow::ensure!(g.len() == per, "group size {} != {per}", g.len());
            for &c in g {
                anyhow::ensure!(c < self.n_clusters, "cluster {c} out of range");
                anyhow::ensure!(!seen[c], "cluster {c} assigned twice");
                seen[c] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&b| b), "cluster unassigned");
        Ok(())
    }
}

/// Exact solver: branch and bound over the (N_c choose per-group)
/// multinomial with a best-so-far prune. Suitable for the paper scale
/// (16 clusters / 4 groups); falls back to greedy above
/// `EXACT_LIMIT` clusters.
const EXACT_LIMIT: usize = 20;

/// Solve Eq. 5. Clusters are assigned to groups balancing workload; exact
/// for small instances, greedy-with-refinement beyond.
pub fn allocate(cluster_workloads: &[f64], n_groups: usize) -> Allocation {
    let n = cluster_workloads.len();
    assert!(n_groups >= 1 && n % n_groups == 0, "N_c % N_g != 0");
    if n <= EXACT_LIMIT {
        exact(cluster_workloads, n_groups)
    } else {
        greedy_refined(cluster_workloads, n_groups)
    }
}

/// Exact branch-and-bound. Clusters are considered in decreasing workload
/// order (stronger pruning); symmetry between groups with equal occupancy is
/// broken by only allowing a cluster into the first empty group.
fn exact(w: &[f64], n_groups: usize) -> Allocation {
    let n = w.len();
    let per = n / n_groups;
    let target = w.iter().sum::<f64>() / n_groups as f64;

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());

    // start from the greedy solution as the incumbent; when its residual
    // deviation is already below 0.2% of the total workload the exact
    // search cannot buy anything the per-step routing noise would not wash
    // out, so return it (saves ~50 ms per layer)
    let incumbent = greedy_refined(w, n_groups);
    let mut best_obj = incumbent.objective(w);
    if best_obj <= 2e-3 * w.iter().sum::<f64>() {
        return incumbent;
    }
    let mut best: Vec<Vec<usize>> = incumbent.groups.clone();

    struct St<'a> {
        w: &'a [f64],
        order: &'a [usize],
        per: usize,
        target: f64,
        loads: Vec<f64>,
        counts: Vec<usize>,
        assign: Vec<Vec<usize>>,
        /// node budget: bounds worst-case search on adversarial inputs
        /// (the incumbent is returned if exhausted)
        nodes_left: u64,
    }

    fn lower_bound(st: &St) -> f64 {
        // groups already at capacity contribute their final deviation;
        // others contribute at least max(0, load - target) (workload only
        // increases as clusters are added).
        st.loads
            .iter()
            .zip(&st.counts)
            .map(|(&l, &c)| {
                if c == st.per {
                    (l - st.target).abs()
                } else {
                    (l - st.target).max(0.0)
                }
            })
            .sum()
    }

    fn rec(st: &mut St, idx: usize, best_obj: &mut f64, best: &mut Vec<Vec<usize>>) {
        if st.nodes_left == 0 {
            return;
        }
        st.nodes_left -= 1;
        if idx == st.order.len() {
            let obj: f64 = st
                .loads
                .iter()
                .map(|&l| (l - st.target).abs())
                .sum();
            if obj < *best_obj {
                *best_obj = obj;
                *best = st.assign.clone();
            }
            return;
        }
        if lower_bound(st) >= *best_obj {
            return;
        }
        let c = st.order[idx];
        let mut seen_empty = false;
        for g in 0..st.loads.len() {
            if st.counts[g] == st.per {
                continue;
            }
            if st.counts[g] == 0 {
                if seen_empty {
                    continue; // symmetry: identical empty groups
                }
                seen_empty = true;
            }
            st.loads[g] += st.w[c];
            st.counts[g] += 1;
            st.assign[g].push(c);
            rec(st, idx + 1, best_obj, best);
            st.assign[g].pop();
            st.counts[g] -= 1;
            st.loads[g] -= st.w[c];
        }
    }

    let mut st = St {
        w,
        order: &order,
        per,
        target,
        loads: vec![0.0; n_groups],
        counts: vec![0; n_groups],
        assign: vec![Vec::new(); n_groups],
        nodes_left: 100_000,
    };
    rec(&mut st, 0, &mut best_obj, &mut best);

    let out = Allocation {
        groups: best,
        n_clusters: n,
    };
    debug_assert!(out.validate().is_ok());
    out
}

/// Greedy longest-processing-time assignment followed by pairwise swap
/// refinement.
fn greedy_refined(w: &[f64], n_groups: usize) -> Allocation {
    let n = w.len();
    let per = n / n_groups;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());

    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
    let mut loads = vec![0.0f64; n_groups];
    for &c in &order {
        // lightest group with remaining capacity
        let g = (0..n_groups)
            .filter(|&g| groups[g].len() < per)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        groups[g].push(c);
        loads[g] += w[c];
    }

    // pairwise swap refinement until no improving swap exists
    let target = w.iter().sum::<f64>() / n_groups as f64;
    let obj = |loads: &[f64]| -> f64 { loads.iter().map(|&l| (l - target).abs()).sum() };
    let mut improved = true;
    while improved {
        improved = false;
        for ga in 0..n_groups {
            for gb in (ga + 1)..n_groups {
                for ia in 0..per {
                    for ib in 0..per {
                        let (ca, cb) = (groups[ga][ia], groups[gb][ib]);
                        let delta = w[cb] - w[ca];
                        let mut new_loads = loads.clone();
                        new_loads[ga] += delta;
                        new_loads[gb] -= delta;
                        if obj(&new_loads) + 1e-15 < obj(&loads) {
                            groups[ga][ia] = cb;
                            groups[gb][ib] = ca;
                            loads = new_loads;
                            improved = true;
                        }
                    }
                }
            }
        }
    }

    let out = Allocation {
        groups,
        n_clusters: n,
    };
    debug_assert!(out.validate().is_ok());
    out
}

/// Full §4.2 pipeline: cluster the experts (stage 1), then allocate clusters
/// to groups balancing workload (stage 2). Returns the expert → chiplet map
/// alongside the intermediate structures.
#[derive(Clone, Debug)]
pub struct ExpertLayout {
    /// Stage-1 result: expert clusters (Algorithm 1).
    pub clustering: Clustering,
    /// Stage-2 result: cluster → group assignment (Eq. 5).
    pub allocation: Allocation,
    /// expert -> chiplet (flat index, group-major).
    pub expert_to_chiplet: Vec<usize>,
    /// Number of MoE chiplets (one cluster each).
    pub n_chiplets: usize,
    /// Number of switch groups.
    pub n_groups: usize,
    /// Whether [`ExpertLayout::spill_dead`] re-homed experts off dead
    /// chiplets: the uniform experts-per-chiplet invariant is relaxed.
    pub degraded: bool,
}

impl ExpertLayout {
    /// Compose a clustering and an allocation into the expert → chiplet map.
    pub fn new(clustering: Clustering, allocation: Allocation, n_groups: usize) -> ExpertLayout {
        let n_chiplets = clustering.clusters.len();
        let chiplet_of_cluster = allocation.chiplet_of_cluster();
        let mut expert_to_chiplet = vec![usize::MAX; clustering.n_experts];
        for (c, members) in clustering.clusters.iter().enumerate() {
            for &e in members {
                expert_to_chiplet[e] = chiplet_of_cluster[c];
            }
        }
        ExpertLayout {
            clustering,
            allocation,
            expert_to_chiplet,
            n_chiplets,
            n_groups,
            degraded: false,
        }
    }

    /// Re-home every expert living on a dead chiplet onto the surviving
    /// chiplets (fault tolerance for `dead-chiplet` scenarios): each orphan
    /// expert moves to the currently least-loaded survivor (group balance
    /// first, matching the Eq. 5 objective), preferring survivors in the
    /// dead chiplet's own group on load ties (locality keeps the spill off
    /// the cross-group trunks), with remaining ties broken by chiplet index.
    /// Deterministic — the randomness lives in the seeded choice of *which*
    /// chiplets die, not in where their experts land.
    ///
    /// Marks the layout [`degraded`](ExpertLayout::degraded), which relaxes
    /// the uniform experts-per-chiplet invariant in
    /// [`validate`](ExpertLayout::validate). Panics if no chiplet survives.
    pub fn spill_dead(&mut self, dead: &[usize]) {
        if dead.is_empty() {
            return;
        }
        let is_dead = |c: usize| dead.contains(&c);
        assert!(
            (0..self.n_chiplets).any(|c| !is_dead(c)),
            "spill_dead: every chiplet is dead"
        );
        let mut counts = vec![0usize; self.n_chiplets];
        for &c in &self.expert_to_chiplet {
            counts[c] += 1;
        }
        // orphans in ascending expert order for determinism
        for e in 0..self.expert_to_chiplet.len() {
            let home = self.expert_to_chiplet[e];
            if !is_dead(home) {
                continue;
            }
            let home_group = self.group_of_chiplet(home);
            let target = (0..self.n_chiplets)
                .filter(|&c| !is_dead(c))
                .min_by_key(|&c| {
                    let foreign = usize::from(self.group_of_chiplet(c) != home_group);
                    (counts[c], foreign, c)
                })
                .expect("a survivor exists");
            counts[home] -= 1;
            counts[target] += 1;
            self.expert_to_chiplet[e] = target;
        }
        self.degraded = true;
    }

    /// Number of experts currently homed on chiplet `c`.
    pub fn experts_on_chiplet(&self, c: usize) -> usize {
        self.expert_to_chiplet.iter().filter(|&&x| x == c).count()
    }

    /// The optimized layout of Mozart-C: Algorithm 1 + Eq. 5.
    pub fn mozart(priors: &Priors, n_chiplets: usize, n_groups: usize) -> ExpertLayout {
        let clustering = crate::clustering::cluster_experts(priors, n_chiplets);
        let workloads = clustering.cluster_workloads(priors);
        let allocation = allocate(&workloads, n_groups);
        ExpertLayout::new(clustering, allocation, n_groups)
    }

    /// The default layout (Baseline / A / B): contiguous expert blocks on
    /// chiplets in index order.
    pub fn contiguous(n_experts: usize, n_chiplets: usize, n_groups: usize) -> ExpertLayout {
        let clustering = Clustering::contiguous(n_experts, n_chiplets);
        let allocation = Allocation::identity(n_chiplets, n_groups);
        ExpertLayout::new(clustering, allocation, n_groups)
    }

    /// Group index of each chiplet.
    pub fn group_of_chiplet(&self, chiplet: usize) -> usize {
        chiplet / (self.n_chiplets / self.n_groups)
    }

    /// Experts per chiplet.
    pub fn experts_per_chiplet(&self) -> usize {
        self.clustering.n_experts / self.n_chiplets
    }

    /// Structural invariants of the composed layout: valid clustering and
    /// allocation, every expert mapped, uniform experts per chiplet. A
    /// [`degraded`](ExpertLayout::degraded) layout (post-spill) relaxes
    /// uniformity: every expert must still land on a valid chiplet and the
    /// total must be preserved, but survivors may hold extra experts.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.clustering.validate()?;
        self.allocation.validate()?;
        anyhow::ensure!(self.expert_to_chiplet.iter().all(|&c| c < self.n_chiplets));
        let mut counts = vec![0usize; self.n_chiplets];
        for &c in &self.expert_to_chiplet {
            counts[c] += 1;
        }
        if self.degraded {
            // spill preserves the expert population; placement is non-uniform
            anyhow::ensure!(
                counts.iter().sum::<usize>() == self.clustering.n_experts,
                "spill lost an expert"
            );
        } else {
            // every chiplet holds exactly n_experts / n_chiplets experts
            anyhow::ensure!(counts.iter().all(|&c| c == self.experts_per_chiplet()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelId};
    use crate::trace::{Priors, TraceGen};
    use crate::util::rng::Rng;

    #[test]
    fn exact_beats_or_matches_greedy_small() {
        let w = [0.30, 0.25, 0.20, 0.10, 0.08, 0.04, 0.02, 0.01];
        let ex = exact(&w, 4);
        let gr = greedy_refined(&w, 4);
        ex.validate().unwrap();
        gr.validate().unwrap();
        assert!(ex.objective(&w) <= gr.objective(&w) + 1e-12);
    }

    #[test]
    fn perfectly_balanceable_reaches_zero() {
        // pairs summing to 0.25 each
        let w = [0.2, 0.05, 0.15, 0.1, 0.13, 0.12, 0.24, 0.01];
        let a = allocate(&w, 4);
        assert!(a.objective(&w) < 1e-9, "obj={}", a.objective(&w));
    }

    #[test]
    fn identity_allocation_shape() {
        let a = Allocation::identity(16, 4);
        a.validate().unwrap();
        assert_eq!(a.groups[2], vec![8, 9, 10, 11]);
        let map = a.chiplet_of_cluster();
        assert_eq!(map[9], 9);
    }

    #[test]
    fn paper_scale_allocation_balances() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        let g = TraceGen::for_model(&m, 21);
        let mut rng = Rng::new(22);
        let tr = g.sample_layer(0, 8_000, &mut rng);
        let p = Priors::from_trace(&tr);
        let layout = ExpertLayout::mozart(&p, 16, 4);
        layout.validate().unwrap();
        // Eq. 5 optimality: for the clustering's own workloads, the chosen
        // assignment must beat (or tie) the identity assignment, and sit
        // within a sane balance envelope (clustering concentrates hot
        // experts, so perfect balance is not generally reachable).
        let wl = layout.clustering.cluster_workloads(&p);
        let ident = Allocation::identity(16, 4);
        assert!(
            layout.allocation.objective(&wl) <= ident.objective(&wl) + 1e-12,
            "allocation {} worse than identity {}",
            layout.allocation.objective(&wl),
            ident.objective(&wl)
        );
        let imb = crate::util::stats::imbalance(&layout.allocation.group_workloads(&wl));
        assert!(imb < 1.3, "group imbalance {imb}");
    }

    #[test]
    fn expert_to_chiplet_covers_all() {
        let layout = ExpertLayout::contiguous(64, 16, 4);
        layout.validate().unwrap();
        assert_eq!(layout.experts_per_chiplet(), 4);
        assert_eq!(layout.group_of_chiplet(0), 0);
        assert_eq!(layout.group_of_chiplet(15), 3);
        // contiguous: expert 5 lives on chiplet 1
        assert_eq!(layout.expert_to_chiplet[5], 1);
    }

    #[test]
    fn spill_rehomes_orphans_onto_survivors() {
        let mut layout = ExpertLayout::contiguous(64, 16, 4);
        layout.spill_dead(&[1, 5]);
        assert!(layout.degraded);
        layout.validate().unwrap();
        // no expert remains on a dead chiplet, none were lost
        assert!(layout.expert_to_chiplet.iter().all(|&c| c != 1 && c != 5));
        assert_eq!(layout.expert_to_chiplet.len(), 64);
        assert_eq!(layout.experts_on_chiplet(1), 0);
        // the 8 orphans spread over the 14 survivors: max load 5, and the
        // total is preserved
        let total: usize = (0..16).map(|c| layout.experts_on_chiplet(c)).sum();
        assert_eq!(total, 64);
        let max = (0..16).map(|c| layout.experts_on_chiplet(c)).max().unwrap();
        assert_eq!(max, 5, "orphans balance onto least-loaded survivors");
        // spill is deterministic
        let mut again = ExpertLayout::contiguous(64, 16, 4);
        again.spill_dead(&[1, 5]);
        assert_eq!(layout.expert_to_chiplet, again.expert_to_chiplet);
    }

    #[test]
    fn spill_of_nothing_keeps_the_layout_strict() {
        let mut layout = ExpertLayout::contiguous(64, 16, 4);
        let before = layout.expert_to_chiplet.clone();
        layout.spill_dead(&[]);
        assert!(!layout.degraded);
        assert_eq!(layout.expert_to_chiplet, before);
        layout.validate().unwrap();
    }

    #[test]
    fn undegraded_validate_still_requires_uniformity() {
        let mut layout = ExpertLayout::contiguous(64, 16, 4);
        layout.expert_to_chiplet[0] = 3; // non-uniform without the flag
        assert!(layout.validate().is_err());
        layout.degraded = true;
        layout.validate().unwrap();
    }

    #[test]
    fn greedy_handles_large_instances() {
        // mildly-skewed workloads (a 1/sqrt zipf): balanceable under the
        // equal-cardinality constraint, so greedy+refinement should land
        // close to uniform and never lose to the identity assignment.
        let w: Vec<f64> = (0..64).map(|i| 1.0 / ((i + 1) as f64).sqrt()).collect();
        let a = allocate(&w, 8);
        a.validate().unwrap();
        let loads = a.group_workloads(&w);
        assert!(crate::util::stats::imbalance(&loads) < 1.1);
        let id = Allocation::identity(64, 8);
        assert!(a.objective(&w) <= id.objective(&w) + 1e-12);
    }
}
