//! # Mozart — reproduction of *Modularized and Efficient MoE Training on
//! # 3.5D Wafer-Scale Chiplet Architectures* (NeurIPS 2025)
//!
//! An algorithm–hardware co-design framework for efficient post-training of
//! MoE-LLMs on a 3.5D wafer-scale chiplet platform, implemented as a
//! three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: the coordinator — the paper's expert clustering /
//!   allocation / all-to-all / fine-grained-scheduling algorithms, the
//!   wafer-scale platform's discrete-event simulator, the multi-tenant
//!   wafer partitioner with its partition-isolation oracle
//!   (`coordinator::tenants`), the report generators for every table and
//!   figure of the paper, and the PJRT runtime that executes real
//!   AOT-compiled MoE training steps.
//! - **L2** (`python/compile/model.py`): the JAX MoE transformer, lowered
//!   once to HLO text by `python/compile/aot.py`.
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the expert-FFN
//!   hot path, verified against a pure-jnp oracle.
//!
//! See `README.md` at the repo root for the project overview and
//! quickstart, `docs/GUIDE.md` for the end-to-end user guide (build →
//! sweep → explore → search → bench → report, with annotated artifact
//! schemas), and `rust/DESIGN.md` for the system inventory, the
//! sweep/simulation hot-path design (parallel executor, plan-topology
//! cache, indexed tag accounting), the design-space **Exploration** and
//! **Search strategies** sections (axis-grid format, Pareto definition,
//! archive invariants, joint-frontier semantics), the offline dependency
//! policy, and the per-experiment index.

#![warn(missing_docs)]

pub mod allocation;
pub mod arch;
pub mod clustering;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod pipeline;
pub mod report;
pub mod sim;
pub mod runtime;
pub mod testkit;
pub mod trace;
pub mod train;
pub mod util;
