//! Synthetic token corpus for the end-to-end training example.
//!
//! A learnable language with real structure (so the loss curve is
//! meaningful, not noise): a Zipf unigram distribution combined with a
//! sparse Markov bigram table — each token strongly predicts a small set of
//! successors, giving the model something a next-token objective can learn
//! well below the unigram entropy floor.

use crate::util::rng::{AliasTable, Rng};

/// Streaming corpus generator.
pub struct Corpus {
    vocab: usize,
    rng: Rng,
    unigram: AliasTable,
    /// successor table: token -> 4 preferred next tokens
    successors: Vec<[u32; 4]>,
    /// probability of following the bigram structure vs unigram noise
    coherence: f64,
    state: u32,
}

impl Corpus {
    /// Build the language (unigram law + bigram table) from `seed`.
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0_FFEE);
        let perm = rng.permutation(vocab);
        let weights = crate::util::rng::zipf_weights(vocab, 1.0, &perm);
        let unigram = AliasTable::new(&weights);
        let successors = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                ]
            })
            .collect();
        Corpus {
            vocab,
            rng,
            unigram,
            successors,
            coherence: 0.8,
            state: 0,
        }
    }

    fn next_token(&mut self) -> u32 {
        let t = if self.rng.f64() < self.coherence {
            let succ = &self.successors[self.state as usize];
            succ[self.rng.below(4)]
        } else {
            self.unigram.sample(&mut self.rng) as u32
        };
        self.state = t;
        t
    }

    /// One (tokens, targets) pair: targets are tokens shifted by one.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let next = self.next_token();
                tokens.push(prev as i32);
                targets.push(next as i32);
                prev = next;
            }
        }
        (tokens, targets)
    }

    /// Vocabulary size of the language.
    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut c = Corpus::new(512, 3);
        let (x, y) = c.batch(4, 16);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(x.iter().all(|&t| (0..512).contains(&t)));
        assert!(y.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn targets_shift_tokens() {
        let mut c = Corpus::new(128, 5);
        let (x, y) = c.batch(1, 32);
        // within a row, target[i] == token[i+1]
        for i in 0..31 {
            assert_eq!(y[i], x[i + 1]);
        }
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successors of a token should cover a small set: measure that the
        // empirical conditional entropy is far below the unigram entropy
        let mut c = Corpus::new(256, 7);
        let (x, y) = c.batch(64, 64);
        use std::collections::HashMap;
        let mut pair: HashMap<(i32, i32), usize> = HashMap::new();
        let mut uni: HashMap<i32, usize> = HashMap::new();
        for (&a, &b) in x.iter().zip(&y) {
            *pair.entry((a, b)).or_default() += 1;
            *uni.entry(a).or_default() += 1;
        }
        // average number of distinct successors per frequent token is small
        let mut succ_count: HashMap<i32, usize> = HashMap::new();
        for &(a, _) in pair.keys() {
            *succ_count.entry(a).or_default() += 1;
        }
        let frequent: Vec<i32> = uni
            .iter()
            .filter(|(_, &c)| c > 20)
            .map(|(&t, _)| t)
            .collect();
        assert!(!frequent.is_empty());
        let avg: f64 = frequent
            .iter()
            .map(|t| succ_count[t] as f64)
            .sum::<f64>()
            / frequent.len() as f64;
        assert!(avg < 40.0, "avg distinct successors {avg} (too random)");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(64, 11);
        let mut b = Corpus::new(64, 11);
        assert_eq!(a.batch(2, 8), b.batch(2, 8));
    }
}
