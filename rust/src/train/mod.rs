//! End-to-end trainer: drives real MoE training steps through the PJRT
//! runtime using the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`). Python is not involved at run time.
//!
//! Artifact contract (see `python/compile/aot.py`):
//! - `tiny_moe_init.hlo.txt` — `() -> (param_0, ..., param_{P-1})`
//! - `tiny_moe_step.hlo.txt` — `(params..., tokens i32[B,T], targets
//!   i32[B,T]) -> (new_params..., loss f32[], router_counts f32[L, E])`
//! - `tiny_moe_meta.kv` — key=value metadata (`n_params`, `batch`, `seq`,
//!   `vocab`, `n_layers`, `n_experts`, `top_k`).
//!
//! The router counts stream back per step, giving the coordinator a *real*
//! activation prior (the paper's §3.2 profiling) that the codesign example
//! feeds into clustering/allocation.

pub mod data;

use anyhow::{Context, Result};
#[cfg(feature = "pjrt")]
use anyhow::ensure;

#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::util::table::Table;

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Directory holding the AOT artifacts (`make artifacts` output).
    pub artifacts_dir: String,
    /// Training steps to run.
    pub steps: usize,
    /// Record the loss every this many steps.
    pub log_every: usize,
    /// Data-sampling seed.
    pub seed: u64,
}

/// Metadata written by aot.py.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Parameter (+ optimizer state) tensors in the training state.
    pub n_params: usize,
    /// Batch size the step function was compiled for.
    pub batch: usize,
    /// Sequence length the step function was compiled for.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// MoE layers in the tiny model.
    pub n_layers: usize,
    /// Routed experts per layer.
    pub n_experts: usize,
    /// Routing fanout.
    pub top_k: usize,
}

impl ArtifactMeta {
    /// Load `tiny_moe_meta.kv` from the artifact directory.
    pub fn load(dir: &str) -> Result<ArtifactMeta> {
        let kv = crate::config::parse::KvConfig::load(&format!("{dir}/tiny_moe_meta.kv"))
            .context("loading artifact metadata (run `make artifacts` first)")?;
        let need = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("meta missing key {k}"))?
                .parse()
                .with_context(|| format!("meta key {k} not an integer"))
        };
        Ok(ArtifactMeta {
            n_params: need("n_params")?,
            batch: need("batch")?,
            seq: need("seq")?,
            vocab: need("vocab")?,
            n_layers: need("n_layers")?,
            n_experts: need("n_experts")?,
            top_k: need("top_k")?,
        })
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    /// `(step, loss)` samples at the logging cadence.
    pub losses: Vec<(usize, f64)>,
    /// Steps executed.
    pub steps: usize,
    /// Wall-clock time of the run (seconds).
    pub wall_s: f64,
    /// Training throughput.
    pub steps_per_sec: f64,
    /// Aggregated router counts per (layer, expert) over the whole run.
    pub router_counts: Vec<Vec<f64>>,
    /// Routed experts per layer (shape of `router_counts` rows).
    pub meta_n_experts: usize,
}

impl TrainSummary {
    /// Last recorded loss (NaN if nothing was recorded).
    pub fn final_loss(&self) -> f64 {
        self.losses.last().map(|&(_, l)| l).unwrap_or(f64::NAN)
    }

    /// First recorded loss (NaN if nothing was recorded).
    pub fn initial_loss(&self) -> f64 {
        self.losses.first().map(|&(_, l)| l).unwrap_or(f64::NAN)
    }

    /// Workload vector V (Eq. 3) of the run's real routing, per layer.
    pub fn workload_vectors(&self) -> Vec<Vec<f64>> {
        self.router_counts
            .iter()
            .map(|layer| {
                let total: f64 = layer.iter().sum();
                layer
                    .iter()
                    .map(|&c| if total > 0.0 { c / total } else { 0.0 })
                    .collect()
            })
            .collect()
    }

    /// Human-readable run summary (loss table + throughput).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "End-to-end training (tiny MoE through PJRT, real compute)",
            &["step", "loss"],
        );
        for &(s, l) in &self.losses {
            t.row(&[s.to_string(), format!("{l:.4}")]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "steps: {}   wall: {:.1} s   throughput: {:.2} steps/s\n",
            self.steps, self.wall_s, self.steps_per_sec
        ));
        out.push_str(&format!(
            "loss: {:.4} -> {:.4} ({})\n",
            self.initial_loss(),
            self.final_loss(),
            if self.final_loss() < self.initial_loss() {
                "decreasing - training works"
            } else {
                "NOT decreasing"
            }
        ));
        out
    }
}

/// Stub when built without the `pjrt` feature: real training needs the
/// PJRT runtime, which needs the `xla` crate (absent from the offline
/// crate set).
#[cfg(not(feature = "pjrt"))]
pub fn run(_cfg: &TrainConfig) -> Result<TrainSummary> {
    anyhow::bail!(
        "end-to-end training unavailable: this build has no PJRT runtime. \
         Add the `xla` dependency and rebuild with `--features pjrt` (see rust/DESIGN.md)."
    )
}

/// Run the training loop.
#[cfg(feature = "pjrt")]
pub fn run(cfg: &TrainConfig) -> Result<TrainSummary> {
    let meta = ArtifactMeta::load(&cfg.artifacts_dir)?;
    let rt = Runtime::cpu()?;
    let init = rt.load_hlo_text(format!("{}/tiny_moe_init.hlo.txt", cfg.artifacts_dir))?;
    let step = rt.load_hlo_text(format!("{}/tiny_moe_step.hlo.txt", cfg.artifacts_dir))?;

    // initialize the training state (params + optimizer moments + step)
    let mut state = init.run(&[])?;
    ensure!(
        state.len() == meta.n_params,
        "init returned {} params, meta says {}",
        state.len(),
        meta.n_params
    );

    let mut corpus = data::Corpus::new(meta.vocab, cfg.seed);
    let mut losses = Vec::new();
    let mut router_counts = vec![vec![0.0f64; meta.n_experts]; meta.n_layers];
    let t0 = std::time::Instant::now();

    for s in 0..cfg.steps {
        let (tokens, targets) = corpus.batch(meta.batch, meta.seq);
        let tok_lit = xla::Literal::vec1(&tokens)
            .reshape(&[meta.batch as i64, meta.seq as i64])?;
        let tgt_lit = xla::Literal::vec1(&targets)
            .reshape(&[meta.batch as i64, meta.seq as i64])?;
        let mut args = state;
        args.push(tok_lit);
        args.push(tgt_lit);

        let mut outs = step.run(&args)?;
        ensure!(
            outs.len() == meta.n_params + 2,
            "step returned {} outputs, expected {}",
            outs.len(),
            meta.n_params + 2
        );
        let counts_lit = outs.pop().unwrap();
        let loss_lit = outs.pop().unwrap();
        state = outs;

        let loss = loss_lit.get_first_element::<f32>()? as f64;
        ensure!(loss.is_finite(), "loss diverged at step {s}: {loss}");
        if s % cfg.log_every == 0 || s + 1 == cfg.steps {
            losses.push((s, loss));
        }
        let counts: Vec<f32> = counts_lit.to_vec()?;
        for l in 0..meta.n_layers {
            for e in 0..meta.n_experts {
                router_counts[l][e] += counts[l * meta.n_experts + e] as f64;
            }
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    Ok(TrainSummary {
        losses,
        steps: cfg.steps,
        wall_s: wall,
        steps_per_sec: cfg.steps as f64 / wall,
        router_counts,
        meta_n_experts: meta.n_experts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_load_rejects_missing_dir() {
        assert!(ArtifactMeta::load("/nonexistent").is_err());
    }

    #[test]
    fn summary_rendering_and_priors() {
        let s = TrainSummary {
            losses: vec![(0, 6.2), (10, 4.0)],
            steps: 11,
            wall_s: 2.0,
            steps_per_sec: 5.5,
            router_counts: vec![vec![3.0, 1.0], vec![0.0, 0.0]],
            meta_n_experts: 2,
        };
        let r = s.render();
        assert!(r.contains("decreasing"));
        assert_eq!(s.final_loss(), 4.0);
        let v = s.workload_vectors();
        assert_eq!(v[0], vec![0.75, 0.25]);
        assert_eq!(v[1], vec![0.0, 0.0]); // no-activation layer stays zero
    }
}
