//! Expert clustering (paper §4.2 Stage-1, Algorithm 1).
//!
//! Farthest-point-sampling-inspired greedy: the first cluster is seeded with
//! the two most co-activated experts; each subsequent cluster is seeded with
//! the unselected expert *least* co-activated with everything selected so
//! far; clusters are then filled greedily with the unselected expert of
//! highest average co-activation with the cluster's current members. All
//! clusters have exactly `n_experts / n_clusters` members.

use crate::trace::Priors;

/// The result of Algorithm 1: `clusters[c]` lists the expert ids of cluster
/// `c`; every expert appears in exactly one cluster.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// `clusters[c]` lists the expert ids of cluster `c`.
    pub clusters: Vec<Vec<usize>>,
    /// Total number of experts partitioned.
    pub n_experts: usize,
}

impl Clustering {
    /// Cluster size (uniform by construction).
    pub fn cluster_size(&self) -> usize {
        self.n_experts / self.clusters.len()
    }

    /// Inverse map: expert -> cluster index.
    pub fn expert_to_cluster(&self) -> Vec<usize> {
        let mut map = vec![usize::MAX; self.n_experts];
        for (c, members) in self.clusters.iter().enumerate() {
            for &e in members {
                map[e] = c;
            }
        }
        map
    }

    /// The trivial contiguous clustering (experts 0..s to cluster 0, etc.) —
    /// the default layout used by Baseline / Mozart-A / Mozart-B.
    pub fn contiguous(n_experts: usize, n_clusters: usize) -> Clustering {
        assert_eq!(n_experts % n_clusters, 0);
        let s = n_experts / n_clusters;
        Clustering {
            clusters: (0..n_clusters)
                .map(|c| (c * s..(c + 1) * s).collect())
                .collect(),
            n_experts,
        }
    }

    /// Structural invariants: partition of 0..n_experts into equal parts.
    pub fn validate(&self) -> anyhow::Result<()> {
        let s = self.cluster_size();
        anyhow::ensure!(s * self.clusters.len() == self.n_experts, "uneven sizes");
        let mut seen = vec![false; self.n_experts];
        for cl in &self.clusters {
            anyhow::ensure!(cl.len() == s, "cluster size {} != {s}", cl.len());
            for &e in cl {
                anyhow::ensure!(e < self.n_experts, "expert {e} out of range");
                anyhow::ensure!(!seen[e], "expert {e} in two clusters");
                seen[e] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&b| b), "some expert unassigned");
        Ok(())
    }

    /// Mean intra-cluster collaboration (higher is better).
    pub fn intra_collab(&self, priors: &Priors) -> f64 {
        let s: f64 = self
            .clusters
            .iter()
            .map(|c| priors.intra_collab(c))
            .sum::<f64>();
        s / self.clusters.len() as f64
    }

    /// Mean inter-cluster collaboration over all cluster pairs (lower is
    /// better).
    pub fn inter_collab(&self, priors: &Priors) -> f64 {
        let nc = self.clusters.len();
        if nc < 2 {
            return 0.0;
        }
        let mut s = 0.0;
        let mut pairs = 0usize;
        for a in 0..nc {
            for b in (a + 1)..nc {
                s += priors.inter_collab(&self.clusters[a], &self.clusters[b]);
                pairs += 1;
            }
        }
        s / pairs as f64
    }

    /// Per-cluster workload shares under the priors.
    pub fn cluster_workloads(&self, priors: &Priors) -> Vec<f64> {
        self.clusters
            .iter()
            .map(|c| priors.set_workload(c))
            .collect()
    }
}

/// Algorithm 1 (paper §4.2). `n_clusters` equals the number of MoE chiplets;
/// `n_experts` must be divisible by `n_clusters` (the paper asserts both).
pub fn cluster_experts(priors: &Priors, n_clusters: usize) -> Clustering {
    let n = priors.n_experts;
    assert!(n_clusters >= 1 && n % n_clusters == 0, "N_e % N_c != 0");
    let size = n / n_clusters;
    let mut selected = vec![false; n];
    let mut clusters: Vec<Vec<usize>> = Vec::with_capacity(n_clusters);

    for c in 0..n_clusters {
        let mut members: Vec<usize> = Vec::with_capacity(size);
        if c == 0 {
            // seed with the two most highly co-activated experts
            let (i, j) = priors.hottest_pair();
            members.push(i);
            selected[i] = true;
            if size > 1 {
                members.push(j);
                selected[j] = true;
            }
        } else {
            // farthest-point step: the unselected expert with the lowest
            // total co-activation with everything already selected
            let all_selected: Vec<usize> =
                (0..n).filter(|&e| selected[e]).collect();
            let seed = (0..n)
                .filter(|&e| !selected[e])
                .min_by(|&a, &b| {
                    let fa: f64 = all_selected.iter().map(|&s| priors.p(a, s)).sum();
                    let fb: f64 = all_selected.iter().map(|&s| priors.p(b, s)).sum();
                    fa.partial_cmp(&fb).unwrap().then(a.cmp(&b))
                })
                .expect("experts remain");
            members.push(seed);
            selected[seed] = true;
        }
        // fill: unselected expert with the highest average co-activation
        // with the cluster's current members
        while members.len() < size {
            let next = (0..n)
                .filter(|&e| !selected[e])
                .max_by(|&a, &b| {
                    let fa: f64 =
                        members.iter().map(|&m| priors.p(a, m)).sum::<f64>();
                    let fb: f64 =
                        members.iter().map(|&m| priors.p(b, m)).sum::<f64>();
                    fa.partial_cmp(&fb).unwrap().then(b.cmp(&a))
                })
                .expect("experts remain");
            members.push(next);
            selected[next] = true;
        }
        clusters.push(members);
    }

    let out = Clustering {
        clusters,
        n_experts: n,
    };
    debug_assert!(out.validate().is_ok());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, ModelId};
    use crate::trace::{Priors, TraceGen};
    use crate::util::rng::Rng;

    /// Priors with two perfectly-collaborating planted blocks {0,1} {2,3}.
    fn planted_priors() -> Priors {
        use crate::trace::RoutingTrace;
        let mut choices = Vec::new();
        for _ in 0..50 {
            choices.extend_from_slice(&[0, 1]);
            choices.extend_from_slice(&[2, 3]);
        }
        // a little cross-noise
        choices.extend_from_slice(&[0, 2]);
        Priors::from_trace(&RoutingTrace {
            n_experts: 4,
            top_k: 2,
            choices,
        })
    }

    #[test]
    fn recovers_planted_blocks() {
        let p = planted_priors();
        let cl = cluster_experts(&p, 2);
        cl.validate().unwrap();
        let mut sets: Vec<Vec<usize>> = cl
            .clusters
            .iter()
            .map(|c| {
                let mut v = c.clone();
                v.sort_unstable();
                v
            })
            .collect();
        sets.sort();
        assert_eq!(sets, vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn clustered_beats_contiguous_on_synthetic_traces() {
        let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
        let g = TraceGen::for_model(&m, 3);
        let mut rng = Rng::new(4);
        let tr = g.sample_layer(0, 6_000, &mut rng);
        let p = Priors::from_trace(&tr);
        let clustered = cluster_experts(&p, 16);
        let contiguous = Clustering::contiguous(m.n_experts, 16);
        assert!(
            clustered.intra_collab(&p) > contiguous.intra_collab(&p),
            "clustered {} <= contiguous {}",
            clustered.intra_collab(&p),
            contiguous.intra_collab(&p)
        );
    }

    #[test]
    fn partition_invariants_on_all_models() {
        for id in ModelId::PAPER_MODELS {
            let m = ModelConfig::preset(id);
            let g = TraceGen::for_model(&m, 9);
            let mut rng = Rng::new(10);
            let tr = g.sample_layer(0, 2_000, &mut rng);
            let p = Priors::from_trace(&tr);
            let cl = cluster_experts(&p, 16);
            cl.validate().unwrap();
            assert_eq!(cl.cluster_size(), m.n_experts / 16);
            // inverse map covers everyone
            let inv = cl.expert_to_cluster();
            assert!(inv.iter().all(|&c| c < 16));
        }
    }

    #[test]
    fn contiguous_layout_shape() {
        let c = Clustering::contiguous(8, 4);
        c.validate().unwrap();
        assert_eq!(c.clusters[1], vec![2, 3]);
    }

    #[test]
    fn degenerate_single_cluster() {
        let p = planted_priors();
        let cl = cluster_experts(&p, 1);
        cl.validate().unwrap();
        assert_eq!(cl.clusters[0].len(), 4);
    }
}
