//! 2.5D NoP-tree interconnect model (paper §4.4 ②).
//!
//! Three-level tree: the attention chiplet at the root, `n_groups` switch
//! nodes, and `chiplets_per_group` MoE chiplets under each switch. Switches
//! have in-network compute to aggregate MoE outputs locally. DRAM stacks
//! attach at the switches (group channels) and at the root (attention
//! channels).
//!
//! The tree is link-level: every edge (group trunk, chiplet leaf) carries
//! an explicit capacity and a fractional *health* multiplier in `(0, 1]`
//! (see [`crate::comm::fault`]). All healths default to `1.0`, in which
//! case every time formula below is bitwise identical to the original
//! healthy-path analytics. Concurrent flows can be evaluated under max-min
//! fair sharing ([`NopTree::max_min_rates`]) instead of the single-phase
//! max-leaf analytics, which is what models contention between all-to-all
//! phases on a partially degraded tree.

use crate::comm::fault::FaultEffects;
use crate::config::HwConfig;

/// Node identifiers in the tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Node {
    /// The central attention chiplet (root, dispatcher).
    Attention,
    /// Switch `g` (one per MoE group).
    Switch(usize),
    /// MoE chiplet (flat index, group-major).
    Moe(usize),
    /// DRAM stack attached to switch `g`.
    GroupDram(usize),
    /// DRAM stacks attached to the attention chiplet.
    AttnDram,
}

/// The NoP-tree topology with per-hop bandwidths.
#[derive(Clone, Debug)]
pub struct NopTree {
    /// Switch nodes (one per MoE group).
    pub n_groups: usize,
    /// MoE chiplets under each switch.
    pub chiplets_per_group: usize,
    /// Root <-> switch bandwidth (GB/s), one trunk per group.
    pub trunk_bw: f64,
    /// Switch <-> leaf bandwidth (GB/s), per chiplet.
    pub leaf_bw: f64,
    /// Per-hop latency (s): router traversal + serialization setup.
    pub hop_latency: f64,
    /// Per-group trunk health multiplier in `(0, 1]` (all `1.0` = healthy).
    pub trunk_health: Vec<f64>,
    /// Per-chiplet leaf-link health multiplier in `(0, 1]`.
    pub leaf_health: Vec<f64>,
}

impl NopTree {
    /// Derive the tree topology and effective bandwidths from a platform
    /// (all link healths `1.0`).
    pub fn from_hw(hw: &HwConfig) -> NopTree {
        NopTree {
            n_groups: hw.n_groups,
            chiplets_per_group: hw.chiplets_per_group(),
            // the root fans its edges across the group trunks
            trunk_bw: hw.attn_nop_bw() / hw.n_groups as f64,
            leaf_bw: hw.chiplet_nop_bw(),
            hop_latency: 50e-9, // ~50 ns per NoP router hop at 1 GHz
            trunk_health: vec![1.0; hw.n_groups],
            leaf_health: vec![1.0; hw.n_moe_chiplets],
        }
    }

    /// Derive the tree with the link healths of a lowered fault scenario
    /// installed (dead chiplets keep their nominal leaf health — they carry
    /// no traffic at all).
    pub fn with_faults(hw: &HwConfig, fx: &FaultEffects) -> NopTree {
        let mut tree = NopTree::from_hw(hw);
        tree.trunk_health.clone_from(&fx.trunk_health);
        tree.leaf_health.clone_from(&fx.leaf_health);
        assert_eq!(tree.trunk_health.len(), tree.n_groups);
        assert_eq!(tree.leaf_health.len(), tree.n_chiplets());
        tree
    }

    /// Capacity view of the contiguous subtree `[start_group, start_group
    /// + groups)` — the NoP a tenant owns under a multi-tenant partition
    /// (`coordinator::tenants`). Per-link capacities are physical
    /// properties of the wires and carry over unchanged; only the node
    /// counts and the health slices shrink. The partition oracle's
    /// realizability clause is exactly "every tenant's chiplet set is one
    /// such subtree": contiguous groups, whole groups, so no trunk link is
    /// ever shared between tenants.
    pub fn subtree(&self, start_group: usize, groups: usize) -> NopTree {
        assert!(
            groups >= 1 && start_group + groups <= self.n_groups,
            "subtree [{start_group}, +{groups}) outside the {}-group tree",
            self.n_groups
        );
        let c0 = start_group * self.chiplets_per_group;
        let c1 = (start_group + groups) * self.chiplets_per_group;
        NopTree {
            n_groups: groups,
            chiplets_per_group: self.chiplets_per_group,
            trunk_bw: self.trunk_bw,
            leaf_bw: self.leaf_bw,
            hop_latency: self.hop_latency,
            trunk_health: self.trunk_health[start_group..start_group + groups].to_vec(),
            leaf_health: self.leaf_health[c0..c1].to_vec(),
        }
    }

    /// The contiguous group run covered by a set of flat chiplet indices,
    /// if the set is *exactly* a run of whole groups: returns `(start_group,
    /// n_groups)`, or `None` when the set has gaps, partial groups, or is
    /// empty — i.e. when it is not realizable as one [`NopTree::subtree`].
    pub fn group_run_of(&self, chiplets: &[usize]) -> Option<(usize, usize)> {
        if chiplets.is_empty() {
            return None;
        }
        let mut sorted = chiplets.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != chiplets.len() || *sorted.last().unwrap() >= self.n_chiplets() {
            return None;
        }
        let g0 = self.group_of(sorted[0]);
        let g1 = self.group_of(*sorted.last().unwrap());
        let n_run = (g1 - g0 + 1) * self.chiplets_per_group;
        // exactly the whole groups [g0, g1]: contiguous flat indices from
        // the first chiplet of g0 through the last of g1
        let c0 = g0 * self.chiplets_per_group;
        if sorted.len() != n_run {
            return None;
        }
        for (i, &c) in sorted.iter().enumerate() {
            if c != c0 + i {
                return None;
            }
        }
        Some((g0, g1 - g0 + 1))
    }

    /// Effective bandwidth of group `g`'s trunk (GB/s), health applied.
    pub fn trunk_bw_of(&self, g: usize) -> f64 {
        self.trunk_bw * self.trunk_health[g]
    }

    /// Effective bandwidth of chiplet `c`'s leaf link (GB/s), health applied.
    pub fn leaf_bw_of(&self, c: usize) -> f64 {
        self.leaf_bw * self.leaf_health[c]
    }

    /// Total MoE chiplets (leaves) in the tree.
    pub fn n_chiplets(&self) -> usize {
        self.n_groups * self.chiplets_per_group
    }

    /// Group (switch) index of a flat chiplet index.
    pub fn group_of(&self, chiplet: usize) -> usize {
        chiplet / self.chiplets_per_group
    }

    /// Parent of a node in the tree (None for the root).
    pub fn parent(&self, n: Node) -> Option<Node> {
        match n {
            Node::Attention => None,
            Node::AttnDram => Some(Node::Attention),
            Node::Switch(_) => Some(Node::Attention),
            Node::GroupDram(g) => Some(Node::Switch(g)),
            Node::Moe(c) => Some(Node::Switch(self.group_of(c))),
        }
    }

    /// Number of tree hops between two nodes (tree distance via the deepest
    /// common ancestor).
    pub fn hops(&self, a: Node, b: Node) -> usize {
        let path = |mut n: Node| -> Vec<Node> {
            let mut v = vec![n];
            while let Some(p) = self.parent(n) {
                v.push(p);
                n = p;
            }
            v
        };
        let pa = path(a);
        let pb = path(b);
        for (i, x) in pa.iter().enumerate() {
            if let Some(j) = pb.iter().position(|y| y == x) {
                return i + j;
            }
        }
        unreachable!("NoP tree is connected")
    }

    /// Time to move `bytes` from the attention root to chiplets of one
    /// group's switch subtree: limited by the trunk into that group.
    pub fn root_to_group_time(&self, bytes: f64) -> f64 {
        bytes / (self.trunk_bw * 1e9) + 2.0 * self.hop_latency
    }

    /// Time for the all-to-all phase: the per-group trunks run in parallel,
    /// so the finish time is set by the most-loaded group trunk (at its
    /// effective, health-scaled bandwidth); add leaf delivery on the
    /// most-loaded chiplet edge, paced conservatively by the worst leaf.
    ///
    /// `group_bytes[g]` — bytes crossing the root<->switch trunk of group g;
    /// `max_leaf_bytes` — bytes into the most-loaded chiplet.
    ///
    /// With all healths at `1.0` this is bitwise identical to the original
    /// healthy-tree formula (`x * 1.0` is exact, and max/divide commute for
    /// non-negative operands).
    pub fn a2a_phase_time(&self, group_bytes: &[f64], max_leaf_bytes: f64) -> f64 {
        assert_eq!(group_bytes.len(), self.n_groups);
        let trunk = group_bytes
            .iter()
            .enumerate()
            .map(|(g, &b)| b / (self.trunk_bw * self.trunk_health[g] * 1e9))
            .fold(0.0f64, f64::max);
        let min_leaf_health = self.leaf_health.iter().cloned().fold(1.0f64, f64::min);
        let leaf = max_leaf_bytes / (self.leaf_bw * min_leaf_health * 1e9);
        // dispatch pipelines through switch: total ~ max of stages + hops
        trunk.max(leaf) + 2.0 * self.hop_latency
    }

    /// Aggregate bisection bandwidth root<->leaves (GB/s), healths applied.
    /// Computed as `sum(healths) * trunk_bw` so the healthy value is exactly
    /// `trunk_bw * n_groups` (summing small integers first is exact).
    pub fn bisection_bw(&self) -> f64 {
        self.trunk_health.iter().sum::<f64>() * self.trunk_bw
    }

    // ---- link-level flow model -------------------------------------------
    //
    // Edges are flat-indexed: `0..n_chiplets` are the chiplet leaf links,
    // `n_chiplets..n_chiplets + n_groups` are the group trunks, and the
    // last edge is the root's aggregate egress (the attention chiplet's
    // edges toward the switches, whose capacity is the sum of the effective
    // trunk bandwidths).

    /// Edge id of chiplet `c`'s leaf link.
    pub fn leaf_edge(&self, c: usize) -> usize {
        assert!(c < self.n_chiplets());
        c
    }

    /// Edge id of group `g`'s trunk.
    pub fn trunk_edge(&self, g: usize) -> usize {
        assert!(g < self.n_groups);
        self.n_chiplets() + g
    }

    /// Edge id of the root's aggregate egress.
    pub fn root_edge(&self) -> usize {
        self.n_chiplets() + self.n_groups
    }

    /// Total number of edges in the flow model.
    pub fn n_edges(&self) -> usize {
        self.n_chiplets() + self.n_groups + 1
    }

    /// Effective capacity of an edge (GB/s), health applied.
    pub fn edge_capacity(&self, edge: usize) -> f64 {
        let n = self.n_chiplets();
        if edge < n {
            self.leaf_bw_of(edge)
        } else if edge < n + self.n_groups {
            self.trunk_bw_of(edge - n)
        } else {
            assert_eq!(edge, self.root_edge(), "edge id out of range");
            self.bisection_bw()
        }
    }

    /// Max-min fair-share rates (GB/s) for concurrent flows, each described
    /// by the set of edges it crosses. Classic progressive filling: the
    /// tightest edge's equal share freezes the flows crossing it, its
    /// capacity is drained, and the remaining flows re-share what is left.
    /// Deterministic: ties resolve by ascending edge id.
    pub fn max_min_rates(&self, flows: &[Vec<usize>]) -> Vec<f64> {
        let n_edges = self.n_edges();
        for path in flows {
            assert!(!path.is_empty(), "flow with an empty path");
            assert!(path.iter().all(|&e| e < n_edges), "edge id out of range");
        }
        let mut cap: Vec<f64> = (0..n_edges).map(|e| self.edge_capacity(e)).collect();
        let mut rate = vec![0.0f64; flows.len()];
        let mut fixed = vec![false; flows.len()];
        while fixed.iter().any(|&f| !f) {
            let mut users = vec![0usize; n_edges];
            for (i, path) in flows.iter().enumerate() {
                if !fixed[i] {
                    for &e in path {
                        users[e] += 1;
                    }
                }
            }
            let mut bottleneck: Option<(usize, f64)> = None;
            for (e, &u) in users.iter().enumerate() {
                if u > 0 {
                    let share = cap[e] / u as f64;
                    if bottleneck.is_none_or(|(_, s)| share < s) {
                        bottleneck = Some((e, share));
                    }
                }
            }
            let (edge, share) = bottleneck.expect("unfixed flows must use an edge");
            for (i, path) in flows.iter().enumerate() {
                if !fixed[i] && path.contains(&edge) {
                    rate[i] = share;
                    fixed[i] = true;
                    for &e in path {
                        cap[e] = (cap[e] - share).max(0.0);
                    }
                }
            }
        }
        rate
    }

    /// Completion time of one all-to-all phase with the per-group flows run
    /// *concurrently* under max-min fair sharing of the root egress and the
    /// trunks — contention-aware, unlike the serialized-root analytics of
    /// [`NopTree::a2a_phase_time`]. On a healthy tree the fair shares
    /// collapse to one trunk's bandwidth per group, so both models agree.
    pub fn a2a_contended_time(&self, group_bytes: &[f64]) -> f64 {
        assert_eq!(group_bytes.len(), self.n_groups);
        let flows: Vec<Vec<usize>> = (0..self.n_groups)
            .map(|g| vec![self.root_edge(), self.trunk_edge(g)])
            .collect();
        let rates = self.max_min_rates(&flows);
        let xfer = group_bytes
            .iter()
            .zip(&rates)
            .map(|(&b, &r)| if b > 0.0 { b / (r * 1e9) } else { 0.0 })
            .fold(0.0f64, f64::max);
        xfer + 2.0 * self.hop_latency
    }

    /// Slowdown of a uniform concurrent all-to-all phase on this tree
    /// relative to the same tree with every link healthy: the multiplicative
    /// penalty the plan builder applies to the serialized a2a root rate.
    /// Exactly `1.0` on a healthy tree.
    pub fn a2a_slowdown(&self) -> f64 {
        let healthy = NopTree {
            trunk_health: vec![1.0; self.n_groups],
            leaf_health: vec![1.0; self.n_chiplets()],
            ..self.clone()
        };
        let uniform = vec![1e9; self.n_groups];
        // ratio of identical computations is exactly 1.0 when healthy
        self.a2a_contended_time(&uniform) / healthy.a2a_contended_time(&uniform)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramKind, HwConfig};

    fn tree() -> NopTree {
        NopTree::from_hw(&HwConfig::mozart_wafer(DramKind::Hbm2))
    }

    #[test]
    fn shape_matches_paper() {
        let t = tree();
        assert_eq!(t.n_groups, 4);
        assert_eq!(t.chiplets_per_group, 4);
        assert_eq!(t.n_chiplets(), 16);
    }

    #[test]
    fn hop_counts() {
        let t = tree();
        assert_eq!(t.hops(Node::Attention, Node::Switch(0)), 1);
        assert_eq!(t.hops(Node::Attention, Node::Moe(0)), 2);
        assert_eq!(t.hops(Node::Moe(0), Node::Moe(1)), 2); // same switch
        assert_eq!(t.hops(Node::Moe(0), Node::Moe(5)), 4); // cross switch
        assert_eq!(t.hops(Node::GroupDram(1), Node::Moe(4)), 2);
        assert_eq!(t.hops(Node::Moe(4), Node::Moe(4)), 0);
        assert_eq!(t.hops(Node::AttnDram, Node::Attention), 1);
        assert_eq!(t.hops(Node::AttnDram, Node::Moe(0)), 3);
    }

    #[test]
    fn group_membership() {
        let t = tree();
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(7), 1);
        assert_eq!(t.group_of(15), 3);
    }

    #[test]
    fn a2a_time_follows_max_trunk() {
        let t = tree();
        let even = t.a2a_phase_time(&[1e9, 1e9, 1e9, 1e9], 0.25e9);
        let skew = t.a2a_phase_time(&[4e9, 0.0, 0.0, 0.0], 0.25e9);
        assert!(skew > even * 2.0);
    }

    #[test]
    fn bandwidth_sanity() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        let t = tree();
        // leaf edge = 256 links * 0.125 GB/s * nop_eff
        let expect = 256.0 * 0.125 * hw.knobs.nop_eff;
        assert!((t.leaf_bw - expect).abs() < 1e-9, "leaf={}", t.leaf_bw);
        assert!(t.trunk_bw > t.leaf_bw); // root edges are wider
        assert_eq!(t.bisection_bw(), t.trunk_bw * 4.0);
    }

    #[test]
    fn healthy_phase_time_is_bitwise_the_legacy_formula() {
        let t = tree();
        let group_bytes = [4e9, 1e9, 0.0, 2.5e9];
        let legacy = (4e9 / (t.trunk_bw * 1e9)).max(0.25e9 / (t.leaf_bw * 1e9))
            + 2.0 * t.hop_latency;
        assert_eq!(t.a2a_phase_time(&group_bytes, 0.25e9), legacy);
    }

    #[test]
    fn degraded_links_stretch_the_phase() {
        let mut t = tree();
        let healthy = t.a2a_phase_time(&[1e9; 4], 0.25e9);
        t.trunk_health[2] = 0.5;
        let degraded = t.a2a_phase_time(&[1e9; 4], 0.25e9);
        assert!(degraded > healthy, "{degraded} vs {healthy}");
        // the degraded trunk is now the pacing stage
        let expect = 1e9 / (t.trunk_bw * 0.5 * 1e9) + 2.0 * t.hop_latency;
        assert_eq!(degraded, expect);
        // a degraded leaf paces the leaf stage conservatively
        let mut t = tree();
        t.leaf_health[9] = 0.1;
        let leaf_bound = t.a2a_phase_time(&[1e9; 4], 0.25e9);
        assert!(leaf_bound > healthy);
        assert_eq!(t.bisection_bw(), t.trunk_bw * 4.0, "trunks unaffected");
    }

    #[test]
    fn healthy_fair_share_agrees_with_the_serialized_root_model() {
        let t = tree();
        // 4 concurrent uniform flows: root egress splits evenly, each trunk
        // carries exactly one flow -> every rate is one trunk's bandwidth
        // (up to water-filling rounding)
        let contended = t.a2a_contended_time(&[1e9; 4]);
        let serialized = 1e9 / (t.trunk_bw * 1e9) + 2.0 * t.hop_latency;
        assert!(
            ((contended - serialized) / serialized).abs() < 1e-12,
            "{contended} vs {serialized}"
        );
        // self-vs-healthy-clone is a ratio of identical computations, so
        // the healthy slowdown is EXACTLY 1.0 — the bit-identity guarantee
        // the plan builder relies on
        assert_eq!(t.a2a_slowdown(), 1.0);
    }

    #[test]
    fn fair_share_rates_respect_capacities_and_converge() {
        let mut t = tree();
        t.trunk_health[0] = 0.25;
        t.leaf_health[5] = 0.5;
        // per-chiplet flows: leaf + trunk + root for every chiplet
        let flows: Vec<Vec<usize>> = (0..t.n_chiplets())
            .map(|c| vec![t.leaf_edge(c), t.trunk_edge(t.group_of(c)), t.root_edge()])
            .collect();
        let rates = t.max_min_rates(&flows);
        assert_eq!(rates.len(), flows.len());
        for (i, path) in flows.iter().enumerate() {
            assert!(rates[i] > 0.0, "flow {i} starved");
            for &e in path {
                assert!(
                    rates[i] <= t.edge_capacity(e) + 1e-9,
                    "flow {i} exceeds edge {e}"
                );
            }
        }
        // no edge is oversubscribed in aggregate
        for e in 0..t.n_edges() {
            let load: f64 = flows
                .iter()
                .zip(&rates)
                .filter(|(p, _)| p.contains(&e))
                .map(|(_, &r)| r)
                .sum();
            assert!(load <= t.edge_capacity(e) + 1e-9, "edge {e} oversubscribed");
        }
        // the flows behind the degraded trunk split its reduced capacity
        let g0: f64 = (0..4).map(|c| rates[c]).sum();
        assert!((g0 - t.trunk_bw_of(0)).abs() < 1e-9);
    }

    #[test]
    fn slowdown_tracks_the_worst_trunk() {
        let mut t = tree();
        t.trunk_health = vec![0.5, 1.0, 1.0, 1.0];
        let s = t.a2a_slowdown();
        // transfer stretches 2x; hop latency dampens the ratio slightly
        assert!(s > 1.5 && s < 2.0 + 1e-9, "slowdown {s}");
        t.trunk_health = vec![0.5; 4];
        let uniform = t.a2a_slowdown();
        assert!(uniform >= s, "uniform degrade is at least as slow");
    }

    #[test]
    fn subtree_preserves_per_link_capacity() {
        let mut t = tree();
        t.trunk_health = vec![1.0, 0.5, 1.0, 1.0];
        t.leaf_health[5] = 0.25;
        let sub = t.subtree(1, 2);
        assert_eq!(sub.n_groups, 2);
        assert_eq!(sub.n_chiplets(), 8);
        // per-link capacities are physical: unchanged under the view
        assert_eq!(sub.trunk_bw.to_bits(), t.trunk_bw.to_bits());
        assert_eq!(sub.leaf_bw.to_bits(), t.leaf_bw.to_bits());
        // health slices line up with the parent's groups 1..3
        assert_eq!(sub.trunk_health, vec![0.5, 1.0]);
        assert_eq!(sub.leaf_bw_of(1).to_bits(), t.leaf_bw_of(5).to_bits());
        // full-tree view is the identity
        let full = t.subtree(0, 4);
        assert_eq!(full.trunk_health, t.trunk_health);
        assert_eq!(full.leaf_health, t.leaf_health);
    }

    #[test]
    fn group_run_recognizes_exact_whole_group_runs() {
        let t = tree(); // 4 groups x 4 chiplets
        assert_eq!(t.group_run_of(&(0..16).collect::<Vec<_>>()), Some((0, 4)));
        assert_eq!(t.group_run_of(&(4..12).collect::<Vec<_>>()), Some((1, 2)));
        // order does not matter
        let mut rev: Vec<usize> = (8..12).collect();
        rev.reverse();
        assert_eq!(t.group_run_of(&rev), Some((2, 1)));
        // gaps, partial groups, duplicates, out-of-range: not a subtree
        let gap: Vec<usize> = (0..4).chain(8..12).collect();
        assert_eq!(t.group_run_of(&gap), None);
        assert_eq!(t.group_run_of(&[0, 1, 2]), None);
        assert_eq!(t.group_run_of(&[0, 0, 1, 2]), None);
        assert_eq!(t.group_run_of(&[15, 16]), None);
        assert_eq!(t.group_run_of(&[]), None);
    }
}
