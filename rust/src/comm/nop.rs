//! 2.5D NoP-tree interconnect model (paper §4.4 ②).
//!
//! Three-level tree: the attention chiplet at the root, `n_groups` switch
//! nodes, and `chiplets_per_group` MoE chiplets under each switch. Switches
//! have in-network compute to aggregate MoE outputs locally. DRAM stacks
//! attach at the switches (group channels) and at the root (attention
//! channels).

use crate::config::HwConfig;

/// Node identifiers in the tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Node {
    /// The central attention chiplet (root, dispatcher).
    Attention,
    /// Switch `g` (one per MoE group).
    Switch(usize),
    /// MoE chiplet (flat index, group-major).
    Moe(usize),
    /// DRAM stack attached to switch `g`.
    GroupDram(usize),
    /// DRAM stacks attached to the attention chiplet.
    AttnDram,
}

/// The NoP-tree topology with per-hop bandwidths.
#[derive(Clone, Debug)]
pub struct NopTree {
    /// Switch nodes (one per MoE group).
    pub n_groups: usize,
    /// MoE chiplets under each switch.
    pub chiplets_per_group: usize,
    /// Root <-> switch bandwidth (GB/s), one trunk per group.
    pub trunk_bw: f64,
    /// Switch <-> leaf bandwidth (GB/s), per chiplet.
    pub leaf_bw: f64,
    /// Per-hop latency (s): router traversal + serialization setup.
    pub hop_latency: f64,
}

impl NopTree {
    /// Derive the tree topology and effective bandwidths from a platform.
    pub fn from_hw(hw: &HwConfig) -> NopTree {
        NopTree {
            n_groups: hw.n_groups,
            chiplets_per_group: hw.chiplets_per_group(),
            // the root fans its edges across the group trunks
            trunk_bw: hw.attn_nop_bw() / hw.n_groups as f64,
            leaf_bw: hw.chiplet_nop_bw(),
            hop_latency: 50e-9, // ~50 ns per NoP router hop at 1 GHz
        }
    }

    /// Total MoE chiplets (leaves) in the tree.
    pub fn n_chiplets(&self) -> usize {
        self.n_groups * self.chiplets_per_group
    }

    /// Group (switch) index of a flat chiplet index.
    pub fn group_of(&self, chiplet: usize) -> usize {
        chiplet / self.chiplets_per_group
    }

    /// Parent of a node in the tree (None for the root).
    pub fn parent(&self, n: Node) -> Option<Node> {
        match n {
            Node::Attention => None,
            Node::AttnDram => Some(Node::Attention),
            Node::Switch(_) => Some(Node::Attention),
            Node::GroupDram(g) => Some(Node::Switch(g)),
            Node::Moe(c) => Some(Node::Switch(self.group_of(c))),
        }
    }

    /// Number of tree hops between two nodes (tree distance via the deepest
    /// common ancestor).
    pub fn hops(&self, a: Node, b: Node) -> usize {
        let path = |mut n: Node| -> Vec<Node> {
            let mut v = vec![n];
            while let Some(p) = self.parent(n) {
                v.push(p);
                n = p;
            }
            v
        };
        let pa = path(a);
        let pb = path(b);
        for (i, x) in pa.iter().enumerate() {
            if let Some(j) = pb.iter().position(|y| y == x) {
                return i + j;
            }
        }
        unreachable!("NoP tree is connected")
    }

    /// Time to move `bytes` from the attention root to chiplets of one
    /// group's switch subtree: limited by the trunk into that group.
    pub fn root_to_group_time(&self, bytes: f64) -> f64 {
        bytes / (self.trunk_bw * 1e9) + 2.0 * self.hop_latency
    }

    /// Time for the all-to-all phase: the per-group trunks run in parallel,
    /// so the finish time is set by the most-loaded group trunk; add leaf
    /// delivery on the most-loaded chiplet edge.
    ///
    /// `group_bytes[g]` — bytes crossing the root<->switch trunk of group g;
    /// `max_leaf_bytes` — bytes into the most-loaded chiplet.
    pub fn a2a_phase_time(&self, group_bytes: &[f64], max_leaf_bytes: f64) -> f64 {
        assert_eq!(group_bytes.len(), self.n_groups);
        let trunk = group_bytes
            .iter()
            .cloned()
            .fold(0.0f64, f64::max)
            / (self.trunk_bw * 1e9);
        let leaf = max_leaf_bytes / (self.leaf_bw * 1e9);
        // dispatch pipelines through switch: total ~ max of stages + hops
        trunk.max(leaf) + 2.0 * self.hop_latency
    }

    /// Aggregate bisection bandwidth root<->leaves (GB/s).
    pub fn bisection_bw(&self) -> f64 {
        self.trunk_bw * self.n_groups as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramKind, HwConfig};

    fn tree() -> NopTree {
        NopTree::from_hw(&HwConfig::mozart_wafer(DramKind::Hbm2))
    }

    #[test]
    fn shape_matches_paper() {
        let t = tree();
        assert_eq!(t.n_groups, 4);
        assert_eq!(t.chiplets_per_group, 4);
        assert_eq!(t.n_chiplets(), 16);
    }

    #[test]
    fn hop_counts() {
        let t = tree();
        assert_eq!(t.hops(Node::Attention, Node::Switch(0)), 1);
        assert_eq!(t.hops(Node::Attention, Node::Moe(0)), 2);
        assert_eq!(t.hops(Node::Moe(0), Node::Moe(1)), 2); // same switch
        assert_eq!(t.hops(Node::Moe(0), Node::Moe(5)), 4); // cross switch
        assert_eq!(t.hops(Node::GroupDram(1), Node::Moe(4)), 2);
        assert_eq!(t.hops(Node::Moe(4), Node::Moe(4)), 0);
        assert_eq!(t.hops(Node::AttnDram, Node::Attention), 1);
        assert_eq!(t.hops(Node::AttnDram, Node::Moe(0)), 3);
    }

    #[test]
    fn group_membership() {
        let t = tree();
        assert_eq!(t.group_of(0), 0);
        assert_eq!(t.group_of(7), 1);
        assert_eq!(t.group_of(15), 3);
    }

    #[test]
    fn a2a_time_follows_max_trunk() {
        let t = tree();
        let even = t.a2a_phase_time(&[1e9, 1e9, 1e9, 1e9], 0.25e9);
        let skew = t.a2a_phase_time(&[4e9, 0.0, 0.0, 0.0], 0.25e9);
        assert!(skew > even * 2.0);
    }

    #[test]
    fn bandwidth_sanity() {
        let hw = HwConfig::mozart_wafer(DramKind::Hbm2);
        let t = tree();
        // leaf edge = 256 links * 0.125 GB/s * nop_eff
        let expect = 256.0 * 0.125 * hw.knobs.nop_eff;
        assert!((t.leaf_bw - expect).abs() < 1e-9, "leaf={}", t.leaf_bw);
        assert!(t.trunk_bw > t.leaf_bw); // root edges are wider
        assert_eq!(t.bisection_bw(), t.trunk_bw * 4.0);
    }
}
