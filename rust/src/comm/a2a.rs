//! All-to-all communication model (paper §3.3, Appendix D).
//!
//! The paper quantifies dispatch-stage communication by C_T, the average
//! number of replications per token — proven (Appendix D) to be the least
//! upper bound of `actual data volume / token count`. Under standard expert
//! parallelism C_T = k; if several of a token's top-k experts share a
//! chiplet, one replica serves them all, so an expert layout that co-locates
//! co-activated experts drives C_T below k (the `efficient_a2a` feature of
//! Mozart-B/C).

use crate::allocation::ExpertLayout;
use crate::trace::RoutingTrace;

/// Per-trace all-to-all statistics.
#[derive(Clone, Debug)]
pub struct A2aStats {
    /// Average replications per token (C_T).
    pub c_t: f64,
    /// Total dispatch replicas over the trace.
    pub dispatch_replicas: u64,
    /// Token-slots (tokens x experts) handled by each chiplet — the MoE
    /// compute workload distribution.
    pub chiplet_token_slots: Vec<u64>,
    /// Dispatch replicas received by each chiplet (activation transfers in).
    pub chiplet_replicas_in: Vec<u64>,
    /// Tokens in the evaluated trace.
    pub n_tokens: u64,
    /// Routing fanout of the evaluated trace.
    pub top_k: usize,
}

impl A2aStats {
    /// Evaluate a routing trace against an expert layout.
    ///
    /// `coalesce` turns on replica elision (Mozart-B/C): a token routed to
    /// several experts on the same chiplet is shipped there once. Without it
    /// (Baseline / Mozart-A) each of the k routed experts receives its own
    /// replica, so C_T == k exactly.
    pub fn evaluate(trace: &RoutingTrace, layout: &ExpertLayout, coalesce: bool) -> A2aStats {
        let nc = layout.n_chiplets;
        let mut slots = vec![0u64; nc];
        let mut replicas_in = vec![0u64; nc];
        let mut total_replicas = 0u64;
        let mut hit = vec![false; nc];
        for t in 0..trace.n_tokens() {
            let picks = trace.token(t);
            if coalesce {
                let mut touched: Vec<usize> = Vec::with_capacity(picks.len());
                for &e in picks {
                    let c = layout.expert_to_chiplet[e as usize];
                    slots[c] += 1;
                    if !hit[c] {
                        hit[c] = true;
                        touched.push(c);
                        replicas_in[c] += 1;
                        total_replicas += 1;
                    }
                }
                for c in touched {
                    hit[c] = false;
                }
            } else {
                for &e in picks {
                    let c = layout.expert_to_chiplet[e as usize];
                    slots[c] += 1;
                    replicas_in[c] += 1;
                    total_replicas += 1;
                }
            }
        }
        let n_tokens = trace.n_tokens() as u64;
        A2aStats {
            c_t: if n_tokens == 0 {
                0.0
            } else {
                total_replicas as f64 / n_tokens as f64
            },
            dispatch_replicas: total_replicas,
            chiplet_token_slots: slots,
            chiplet_replicas_in: replicas_in,
            n_tokens,
            top_k: trace.top_k,
        }
    }

    /// Per-group token-slot workloads (sums over the group's chiplets).
    pub fn group_token_slots(&self, n_groups: usize) -> Vec<u64> {
        let per = self.chiplet_token_slots.len() / n_groups;
        (0..n_groups)
            .map(|g| {
                self.chiplet_token_slots[g * per..(g + 1) * per]
                    .iter()
                    .sum()
            })
            .collect()
    }
}

/// Byte volumes of one all-to-all phase pair (dispatch + combine) for a
/// micro-batch, derived from C_T and the hidden size.
#[derive(Clone, Copy, Debug)]
pub struct A2aVolume {
    /// Bytes leaving the attention chiplet toward expert chiplets.
    pub dispatch_bytes: f64,
    /// Bytes returning from expert chiplets after (optional) in-network
    /// switch aggregation.
    pub combine_bytes: f64,
}

impl A2aVolume {
    /// `c_t` — measured replication factor; `switch_agg` — in-network
    /// aggregation divisor for the combine stage (1.0 = none; Mozart-B/C use
    /// the switch's reduction capability, paper §4.4 ②).
    pub fn from_c_t(
        n_tokens: usize,
        token_bytes: u64,
        c_t: f64,
        switch_agg: f64,
    ) -> A2aVolume {
        assert!(switch_agg >= 1.0);
        let dispatch = n_tokens as f64 * c_t * token_bytes as f64;
        // combine returns one weighted partial per replica, reduced in the
        // tree by the switch aggregation factor
        let combine = dispatch / switch_agg;
        A2aVolume {
            dispatch_bytes: dispatch,
            combine_bytes: combine,
        }
    }

    /// Dispatch + combine bytes of the phase pair.
    pub fn total_bytes(&self) -> f64 {
        self.dispatch_bytes + self.combine_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::ExpertLayout;
    use crate::config::{ModelConfig, ModelId};
    use crate::trace::{Priors, TraceGen};
    use crate::util::rng::Rng;

    fn toy_trace() -> RoutingTrace {
        // 4 experts on 2 chiplets (contiguous: {0,1} {2,3}), k=2
        // token0 -> (0,1): same chiplet; token1 -> (0,2): two chiplets
        RoutingTrace {
            n_experts: 4,
            top_k: 2,
            choices: vec![0, 1, 0, 2],
        }
    }

    #[test]
    fn ct_equals_k_without_coalescing() {
        let layout = ExpertLayout::contiguous(4, 2, 1);
        let s = A2aStats::evaluate(&toy_trace(), &layout, false);
        assert_eq!(s.c_t, 2.0);
        assert_eq!(s.dispatch_replicas, 4);
    }

    #[test]
    fn coalescing_elides_co_located_replicas() {
        let layout = ExpertLayout::contiguous(4, 2, 1);
        let s = A2aStats::evaluate(&toy_trace(), &layout, true);
        // token0 needs 1 replica, token1 needs 2 -> C_T = 1.5
        assert_eq!(s.c_t, 1.5);
        assert_eq!(s.chiplet_replicas_in, vec![2, 1]);
        // compute workload is unchanged by coalescing
        assert_eq!(s.chiplet_token_slots, vec![3, 1]);
    }

    #[test]
    fn ct_bounds_hold_on_synthetic_traces() {
        // Appendix D: C_T <= k always; >= k/experts_per_chiplet trivially.
        for id in ModelId::PAPER_MODELS {
            let m = ModelConfig::preset(id);
            let g = TraceGen::for_model(&m, 31);
            let mut rng = Rng::new(32);
            let tr = g.sample_layer(0, 4_000, &mut rng);
            let layout = ExpertLayout::contiguous(m.n_experts, 16, 4);
            let s = A2aStats::evaluate(&tr, &layout, true);
            assert!(s.c_t <= m.top_k as f64 + 1e-9);
            assert!(s.c_t >= 1.0);
        }
    }

    #[test]
    fn clustered_layout_reduces_ct() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        let g = TraceGen::for_model(&m, 41);
        let mut rng = Rng::new(42);
        let tr = g.sample_layer(0, 8_000, &mut rng);
        let p = Priors::from_trace(&tr);
        let contiguous = ExpertLayout::contiguous(m.n_experts, 16, 4);
        let clustered = ExpertLayout::mozart(&p, 16, 4);
        let mut r2 = Rng::new(43);
        let fresh = g.sample_layer(0, 8_000, &mut r2); // held-out trace
        let s_cont = A2aStats::evaluate(&fresh, &contiguous, true);
        let s_clus = A2aStats::evaluate(&fresh, &clustered, true);
        assert!(
            s_clus.c_t < s_cont.c_t,
            "clustered {} !< contiguous {}",
            s_clus.c_t,
            s_cont.c_t
        );
    }

    #[test]
    fn volume_scaling() {
        let v = A2aVolume::from_c_t(1000, 4096, 6.0, 3.0);
        assert_eq!(v.dispatch_bytes, 1000.0 * 6.0 * 4096.0);
        assert_eq!(v.combine_bytes, v.dispatch_bytes / 3.0);
        assert_eq!(v.total_bytes(), v.dispatch_bytes + v.combine_bytes);
    }

    #[test]
    fn group_slots_sum() {
        let layout = ExpertLayout::contiguous(4, 2, 2);
        let s = A2aStats::evaluate(&toy_trace(), &layout, true);
        let g = s.group_token_slots(2);
        assert_eq!(g.iter().sum::<u64>(), 4);
    }
}
