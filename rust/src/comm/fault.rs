//! Fault-injection scenarios for the wafer-scale platform (ROADMAP item 4).
//!
//! Real 3.5D integrations fail partially: a chiplet can die outright
//! (known-good-die escapes, power delivery), 2.5D NoP or 3D hybrid-bonding
//! links can degrade to a fraction of their design bandwidth (bump fatigue,
//! electromigration), and DRAM stacks thermally throttle under sustained
//! load (A3D-MoE motivates exactly these heterogeneous-integration failure
//! modes). A [`FaultScenario`] describes such a condition as a composable
//! list of [`Fault`]s; [`FaultScenario::effects`] lowers it to per-resource
//! *health* vectors (fractional multipliers in `(0, 1]` plus a dead-chiplet
//! set) that the plan builder and the [`NopTree`](crate::comm::NopTree)
//! apply to their bandwidth and compute rates.
//!
//! Determinism contract: fault *placement* (which chiplet dies, which stack
//! throttles) is drawn from [`util::rng`](crate::util::rng) seeded by
//! [`FaultScenario::seed`] and the fault's position in the list — never by
//! its severity parameter. Re-scaling a scenario's severity with
//! [`FaultScenario::at_severity`] therefore keeps the placement fixed (and
//! dead-chiplet sets nest as severity grows), which is what makes
//! degradation curves monotone and bit-reproducible.
//!
//! Bit-identity contract: the empty scenario lowers to all-ones health
//! vectors, and every consumer applies healths multiplicatively (`x * 1.0`
//! is bitwise `x` for finite `x`), so a fault-free run is bit-identical to
//! the pre-fault-model code path — the golden anchors do not move.

use crate::util::rng::Rng;

/// Seed salt for fault placement, xored with [`FaultScenario::seed`] so the
/// placement stream is independent of the routing-trace stream.
const PLACEMENT_SALT: u64 = 0xFA_0175;

/// One injected fault. Severity parameters are *fractions of design
/// bandwidth retained* (`frac`, in `(0, 1]`; `1.0` is a no-op) or a count
/// of failed units.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// `count` MoE chiplets are dead: they compute nothing and their
    /// experts spill onto the surviving chiplets
    /// ([`ExpertLayout::spill_dead`](crate::allocation::ExpertLayout::spill_dead)).
    /// Placement is seeded; at least one chiplet always survives.
    DeadChiplets {
        /// Number of MoE chiplets to kill (clamped to `n_chiplets - 1`).
        count: usize,
    },
    /// Every 2.5D NoP-tree edge (group trunks and chiplet leaf links)
    /// retains `frac` of its bandwidth — wafer-wide signaling degradation.
    NopDegrade {
        /// Retained fraction of NoP link bandwidth, in `(0, 1]`.
        frac: f64,
    },
    /// One (seeded) chiplet's 3D hybrid-bonding stack retains `frac` of its
    /// vertical bandwidth. The logic die reads operands from the bonded
    /// SRAM die every cycle, so sustained compute on that chiplet scales
    /// with the bond health.
    HbDegrade {
        /// Retained fraction of hybrid-bonding bandwidth, in `(0, 1]`.
        frac: f64,
    },
    /// One (seeded) group DRAM stack thermally throttles to `frac` of its
    /// design bandwidth, slowing that group's weight-streaming channel.
    DramThrottle {
        /// Retained fraction of the stack's DRAM bandwidth, in `(0, 1]`.
        frac: f64,
    },
}

impl Fault {
    /// Stable CLI/JSON name of the fault kind.
    pub fn kind(&self) -> &'static str {
        match self {
            Fault::DeadChiplets { .. } => "dead-chiplet",
            Fault::NopDegrade { .. } => "nop-degrade",
            Fault::HbDegrade { .. } => "hb-degrade",
            Fault::DramThrottle { .. } => "dram-throttle",
        }
    }

    /// `kind:value` rendering; the inverse of [`Fault::parse`].
    pub fn label(&self) -> String {
        match self {
            Fault::DeadChiplets { count } => format!("{}:{count}", self.kind()),
            Fault::NopDegrade { frac }
            | Fault::HbDegrade { frac }
            | Fault::DramThrottle { frac } => format!("{}:{frac}", self.kind()),
        }
    }

    /// Parse one `kind:value` spec (e.g. `dead-chiplet:3`, `hb-degrade:0.5`).
    pub fn parse(spec: &str) -> Result<Fault, String> {
        let (kind, value) = spec
            .split_once(':')
            .ok_or_else(|| format!("fault `{spec}` is not of the form kind:value"))?;
        let frac = || -> Result<f64, String> {
            let v: f64 = value
                .parse()
                .map_err(|_| format!("fault `{spec}`: `{value}` is not a number"))?;
            if !(v > 0.0 && v <= 1.0) {
                return Err(format!(
                    "fault `{spec}`: retained fraction must be in (0, 1], got {v} \
                     (use dead-chiplet:N for total failures)"
                ));
            }
            Ok(v)
        };
        match kind {
            "dead-chiplet" | "dead-chiplets" => {
                let count: usize = value
                    .parse()
                    .map_err(|_| format!("fault `{spec}`: `{value}` is not a count"))?;
                if count == 0 {
                    return Err(format!("fault `{spec}`: count must be >= 1"));
                }
                Ok(Fault::DeadChiplets { count })
            }
            "nop-degrade" => Ok(Fault::NopDegrade { frac: frac()? }),
            "hb-degrade" => Ok(Fault::HbDegrade { frac: frac()? }),
            "dram-throttle" => Ok(Fault::DramThrottle { frac: frac()? }),
            _ => Err(format!(
                "unknown fault kind `{kind}` (expected dead-chiplet, nop-degrade, \
                 hb-degrade, or dram-throttle)"
            )),
        }
    }

    /// The fault re-scaled to severity `t` in `[0, 1]`: `t = 1` is this
    /// fault verbatim, `t -> 0` approaches healthy. Counts scale as
    /// `ceil(t * count)` and retained fractions interpolate linearly from
    /// `1.0` toward `frac`, so a larger `t` is never less severe.
    pub fn at_severity(&self, t: f64) -> Fault {
        assert!((0.0..=1.0).contains(&t), "severity {t} outside [0, 1]");
        let scale = |frac: f64| 1.0 - t * (1.0 - frac);
        match *self {
            Fault::DeadChiplets { count } => Fault::DeadChiplets {
                count: ((t * count as f64).ceil() as usize).max(1),
            },
            Fault::NopDegrade { frac } => Fault::NopDegrade { frac: scale(frac) },
            Fault::HbDegrade { frac } => Fault::HbDegrade { frac: scale(frac) },
            Fault::DramThrottle { frac } => Fault::DramThrottle { frac: scale(frac) },
        }
    }
}

/// A composable, seeded fault scenario: an ordered list of faults plus the
/// placement seed. The empty scenario is the healthy platform.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultScenario {
    /// Injected faults, applied in order (healths compose multiplicatively).
    pub faults: Vec<Fault>,
    /// Placement seed for randomized fault sites (dead chiplets, throttled
    /// stacks). Independent of the routing-trace seed.
    pub seed: u64,
}

impl FaultScenario {
    /// The healthy platform: no faults.
    pub fn none() -> FaultScenario {
        FaultScenario::default()
    }

    /// Whether the scenario injects nothing (the healthy platform).
    pub fn is_healthy(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse a composite CLI spec: one or more `kind:value` faults joined
    /// by `,` or `+` (e.g. `dead-chiplet:2,nop-degrade:0.5`).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultScenario, String> {
        let mut faults = Vec::new();
        for part in spec.split([',', '+']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            faults.push(Fault::parse(part)?);
        }
        if faults.is_empty() {
            return Err(format!("fault spec `{spec}` names no faults"));
        }
        Ok(FaultScenario { faults, seed })
    }

    /// Canonical `,`-joined label; [`FaultScenario::parse`] round-trips it.
    pub fn label(&self) -> String {
        if self.is_healthy() {
            return "healthy".to_string();
        }
        self.faults
            .iter()
            .map(Fault::label)
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The scenario with every fault re-scaled to severity `t` in `[0, 1]`
    /// (see [`Fault::at_severity`]); placement (the seed) is unchanged, so
    /// severity sweeps degrade the *same* fault sites progressively.
    pub fn at_severity(&self, t: f64) -> FaultScenario {
        FaultScenario {
            faults: self.faults.iter().map(|f| f.at_severity(t)).collect(),
            seed: self.seed,
        }
    }

    /// Lower the scenario to per-resource health vectors for a platform
    /// with `n_chiplets` MoE chiplets in `n_groups` groups.
    ///
    /// Placement determinism: fault `i` draws its sites from a stream
    /// forked off `seed` by list position, so severity parameters never
    /// shift another fault's placement, and [`FaultScenario::at_severity`]
    /// of a `dead-chiplet` fault kills a *prefix* of one fixed permutation
    /// (dead sets nest as severity grows).
    pub fn effects(&self, n_chiplets: usize, n_groups: usize) -> FaultEffects {
        assert!(n_chiplets > 0 && n_groups > 0 && n_chiplets % n_groups == 0);
        let mut fx = FaultEffects::healthy(n_chiplets, n_groups);
        let mut base = Rng::new(self.seed ^ PLACEMENT_SALT);
        for (i, fault) in self.faults.iter().enumerate() {
            let mut rng = base.fork(i as u64);
            match *fault {
                Fault::DeadChiplets { count } => {
                    let live: Vec<usize> =
                        (0..n_chiplets).filter(|c| !fx.dead_set[*c]).collect();
                    // kill a prefix of one permutation of the live set, and
                    // always leave at least one survivor to absorb the spill
                    let kill = count.min(live.len().saturating_sub(1));
                    for &p in rng.permutation(live.len()).iter().take(kill) {
                        fx.dead_set[live[p]] = true;
                    }
                }
                Fault::NopDegrade { frac } => {
                    for h in &mut fx.trunk_health {
                        *h *= frac;
                    }
                    for h in &mut fx.leaf_health {
                        *h *= frac;
                    }
                }
                Fault::HbDegrade { frac } => {
                    let c = rng.below(n_chiplets);
                    fx.compute_health[c] *= frac;
                }
                Fault::DramThrottle { frac } => {
                    let g = rng.below(n_groups);
                    fx.dram_health[g] *= frac;
                }
            }
        }
        fx
    }
}

impl std::fmt::Display for FaultScenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// A [`FaultScenario`] lowered onto a concrete platform shape: per-resource
/// fractional healths (multipliers in `(0, 1]`) and the dead-chiplet set.
/// All vectors are `1.0` / `false` for the healthy platform.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEffects {
    /// `dead_set[c]` — whether MoE chiplet `c` is dead.
    pub dead_set: Vec<bool>,
    /// Per-group NoP trunk (root <-> switch) bandwidth health.
    pub trunk_health: Vec<f64>,
    /// Per-chiplet NoP leaf (switch <-> chiplet) bandwidth health.
    pub leaf_health: Vec<f64>,
    /// Per-chiplet sustained-compute health (hybrid-bonding degradation).
    pub compute_health: Vec<f64>,
    /// Per-group DRAM-stack bandwidth health (thermal throttling).
    pub dram_health: Vec<f64>,
}

impl FaultEffects {
    /// All-ones healths and no dead chiplets.
    pub fn healthy(n_chiplets: usize, n_groups: usize) -> FaultEffects {
        FaultEffects {
            dead_set: vec![false; n_chiplets],
            trunk_health: vec![1.0; n_groups],
            leaf_health: vec![1.0; n_chiplets],
            compute_health: vec![1.0; n_chiplets],
            dram_health: vec![1.0; n_groups],
        }
    }

    /// Whether every health is exactly `1.0` and no chiplet is dead.
    pub fn is_healthy(&self) -> bool {
        !self.dead_set.iter().any(|&d| d)
            && self.trunk_health.iter().all(|&h| h == 1.0)
            && self.leaf_health.iter().all(|&h| h == 1.0)
            && self.compute_health.iter().all(|&h| h == 1.0)
            && self.dram_health.iter().all(|&h| h == 1.0)
    }

    /// Dead MoE chiplet ids, ascending.
    pub fn dead(&self) -> Vec<usize> {
        (0..self.dead_set.len()).filter(|&c| self.dead_set[c]).collect()
    }

    /// Worst NoP leaf-link health among the *live* chiplets of group `g`
    /// (`1.0` if the whole group is dead): the conservative pacing factor
    /// for that group's shared weight-streaming channel.
    pub fn group_leaf_health(&self, g: usize, chiplets_per_group: usize) -> f64 {
        let lo = g * chiplets_per_group;
        (lo..lo + chiplets_per_group)
            .filter(|&c| !self.dead_set[c])
            .map(|c| self.leaf_health[c])
            .fold(1.0f64, f64::min)
    }

    /// Worst trunk health across groups: the serialized all-to-all root
    /// path is paced by its slowest trunk.
    pub fn min_trunk_health(&self) -> f64 {
        self.trunk_health.iter().cloned().fold(1.0f64, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_each_kind() {
        for spec in [
            "dead-chiplet:3",
            "nop-degrade:0.5",
            "hb-degrade:0.25",
            "dram-throttle:0.8",
            "dead-chiplet:2,nop-degrade:0.5,dram-throttle:0.75",
        ] {
            let s = FaultScenario::parse(spec, 7).expect(spec);
            assert_eq!(s.label(), spec, "canonical label");
            let again = FaultScenario::parse(&s.label(), 7).expect("re-parse");
            assert_eq!(s, again, "round-trip of `{spec}`");
        }
        // `+` is an accepted join character, normalized to `,`
        let s = FaultScenario::parse("dead-chiplet:1+hb-degrade:0.5", 0).unwrap();
        assert_eq!(s.label(), "dead-chiplet:1,hb-degrade:0.5");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "dead-chiplet",      // no value
            "dead-chiplet:0",    // zero count
            "dead-chiplet:x",    // not a count
            "nop-degrade:0",     // zero bandwidth is a dead link, not degrade
            "nop-degrade:1.5",   // above design bandwidth
            "hb-degrade:-0.5",   // negative
            "meltdown:0.5",      // unknown kind
            "",                  // empty
            ",,",                // only separators
        ] {
            assert!(FaultScenario::parse(bad, 0).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn healthy_scenario_lowers_to_identity_effects() {
        let fx = FaultScenario::none().effects(16, 4);
        assert!(fx.is_healthy());
        assert!(fx.dead().is_empty());
        assert_eq!(fx.group_leaf_health(2, 4), 1.0);
        assert_eq!(fx.min_trunk_health(), 1.0);
    }

    #[test]
    fn placement_is_seeded_and_reproducible() {
        let s = FaultScenario::parse("dead-chiplet:4,dram-throttle:0.5", 42).unwrap();
        let a = s.effects(16, 4);
        let b = s.effects(16, 4);
        assert_eq!(a, b, "same seed, same placement");
        let moved = (43..=47).any(|seed| {
            let other = FaultScenario { seed, ..s.clone() };
            other.effects(16, 4).dead() != a.dead()
        });
        assert!(moved, "placement never moved across five other seeds");
        assert_eq!(a.dead().len(), 4);
    }

    #[test]
    fn severity_scaling_keeps_placement_and_nests_dead_sets() {
        let s = FaultScenario::parse("dead-chiplet:6,nop-degrade:0.4", 9).unwrap();
        let mild = s.at_severity(0.34).effects(16, 4);
        let severe = s.at_severity(1.0).effects(16, 4);
        // dead sets nest: every mildly-dead chiplet is also severely dead
        let (md, sd) = (mild.dead(), severe.dead());
        assert!(md.len() < sd.len());
        assert!(md.iter().all(|c| sd.contains(c)), "mild {md:?} severe {sd:?}");
        // link health interpolates toward the full-severity fraction
        assert!(mild.trunk_health[0] > severe.trunk_health[0]);
        assert_eq!(severe.trunk_health[0], 0.4);
        // severity 0 is healthy bandwidth (counts clamp at >= 1 dead)
        let zero = s.at_severity(0.0);
        assert_eq!(zero.faults[1], Fault::NopDegrade { frac: 1.0 });
    }

    #[test]
    fn dead_chiplets_always_leave_a_survivor() {
        let s = FaultScenario::parse("dead-chiplet:99", 1).unwrap();
        let fx = s.effects(16, 4);
        assert_eq!(fx.dead().len(), 15, "one survivor absorbs the spill");
        // composition across two dead-chiplet faults still leaves one alive
        let s = FaultScenario::parse("dead-chiplet:10,dead-chiplet:10", 1).unwrap();
        assert_eq!(s.effects(16, 4).dead().len(), 15);
    }

    #[test]
    fn faults_compose_multiplicatively() {
        let s = FaultScenario::parse("nop-degrade:0.5,nop-degrade:0.5", 3).unwrap();
        let fx = s.effects(16, 4);
        assert_eq!(fx.trunk_health[0], 0.25);
        assert_eq!(fx.leaf_health[7], 0.25);
        assert!(!fx.is_healthy());
    }

    #[test]
    fn group_leaf_health_skips_dead_chiplets() {
        let mut fx = FaultEffects::healthy(16, 4);
        fx.leaf_health[0] = 0.2;
        fx.dead_set[0] = true; // the degraded leaf belongs to a dead chiplet
        fx.leaf_health[1] = 0.6;
        assert_eq!(fx.group_leaf_health(0, 4), 0.6);
        assert_eq!(fx.group_leaf_health(1, 4), 1.0);
    }
}
