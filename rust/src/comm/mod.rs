//! On-package communication models: the all-to-all dispatch/combine stages
//! of expert parallelism (paper §3.3 + Appendix D) and the 2.5D NoP-tree
//! interconnect (paper §4.4).

pub mod a2a;
pub mod nop;

pub use a2a::{A2aStats, A2aVolume};
pub use nop::NopTree;
