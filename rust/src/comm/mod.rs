//! On-package communication models: the all-to-all dispatch/combine stages
//! of expert parallelism (paper §3.3 + Appendix D), the 2.5D NoP-tree
//! interconnect (paper §4.4), and the fault-injection scenarios that
//! degrade both (ROADMAP item 4).

pub mod a2a;
pub mod fault;
pub mod nop;

pub use a2a::{A2aStats, A2aVolume};
pub use fault::{Fault, FaultEffects, FaultScenario};
pub use nop::NopTree;
