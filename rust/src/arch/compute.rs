//! Systolic-array compute-timing model.
//!
//! An `s x s` output-stationary systolic array computes a tile of C = A.B by
//! streaming K partial products: one `s x s` output tile over a reduction
//! depth K costs ~`K + 2s` cycles (fill + drain). A chiplet schedules output
//! tiles across its `n_sas` arrays; the per-matmul cycle count is the
//! critical path over that schedule. This is the same granularity the
//! paper's cycle-accurate simulator models for QKV projection / expert FFN
//! mapping onto SA tiles (§4.4 Algorithm-to-Hardware Mapping).

use crate::util::div_ceil;

/// Dense matmul shape: `[m x k] . [k x n]`.
#[derive(Clone, Copy, Debug)]
pub struct MatmulShape {
    /// Output rows.
    pub m: u64,
    /// Reduction depth.
    pub k: u64,
    /// Output columns.
    pub n: u64,
}

impl MatmulShape {
    /// Total FLOPs (one multiply + one add per MAC).
    pub fn flops(&self) -> u64 {
        2 * self.m * self.k * self.n
    }
}

/// Cycles for one matmul on `n_sas` systolic arrays of `sa_dim x sa_dim`
/// PEs, assuming perfect tile-level parallelism (the paper's local adder
/// trees aggregate partial sums within a tile).
pub fn matmul_cycles(shape: MatmulShape, n_sas: u64, sa_dim: u64) -> u64 {
    if shape.m == 0 || shape.k == 0 || shape.n == 0 {
        return 0;
    }
    let tiles_m = div_ceil(shape.m, sa_dim);
    let tiles_n = div_ceil(shape.n, sa_dim);
    let total_tiles = tiles_m * tiles_n;
    // each output tile costs K (stream) + 2*sa_dim (fill/drain)
    let cycles_per_tile = shape.k + 2 * sa_dim;
    let waves = div_ceil(total_tiles, n_sas);
    waves * cycles_per_tile
}

/// Wall-clock seconds for the matmul at `freq_ghz`, derated by `util`
/// (sustained utilization, a calibration knob).
pub fn matmul_time(shape: MatmulShape, n_sas: u64, sa_dim: u64, freq_ghz: f64, util: f64) -> f64 {
    assert!(util > 0.0 && util <= 1.0);
    matmul_cycles(shape, n_sas, sa_dim) as f64 / (freq_ghz * 1e9) / util
}

/// Effective FLOP/s achieved by the array on this shape (useful for
/// roofline reporting).
pub fn achieved_flops(shape: MatmulShape, n_sas: u64, sa_dim: u64, freq_ghz: f64, util: f64) -> f64 {
    let t = matmul_time(shape, n_sas, sa_dim, freq_ghz, util);
    if t == 0.0 {
        0.0
    } else {
        shape.flops() as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shapes_cost_nothing() {
        assert_eq!(
            matmul_cycles(MatmulShape { m: 0, k: 5, n: 5 }, 4, 16),
            0
        );
    }

    #[test]
    fn single_tile_cost() {
        // one 16x16 tile, K=64: 64 + 32 cycles
        let c = matmul_cycles(MatmulShape { m: 16, k: 64, n: 16 }, 16, 16);
        assert_eq!(c, 96);
    }

    #[test]
    fn tiles_parallelize_across_sas() {
        let shape = MatmulShape { m: 64, k: 128, n: 64 }; // 16 tiles of 16x16
        let c1 = matmul_cycles(shape, 1, 16);
        let c16 = matmul_cycles(shape, 16, 16);
        assert_eq!(c1, 16 * c16);
    }

    #[test]
    fn cycles_monotone_in_k() {
        let base = MatmulShape { m: 32, k: 100, n: 32 };
        let deeper = MatmulShape { m: 32, k: 200, n: 32 };
        assert!(matmul_cycles(deeper, 4, 16) > matmul_cycles(base, 4, 16));
    }

    #[test]
    fn time_and_flops_consistent() {
        let s = MatmulShape { m: 256, k: 256, n: 256 };
        let t = matmul_time(s, 16, 16, 1.0, 0.5);
        let f = achieved_flops(s, 16, 16, 1.0, 0.5);
        assert!(((f * t - s.flops() as f64).abs() / s.flops() as f64) < 1e-12);
    }

    #[test]
    fn achieved_below_peak() {
        // achieved FLOP/s can never exceed the array's peak
        let s = MatmulShape { m: 4096, k: 4096, n: 4096 };
        let n_sas = 16u64;
        let sa_dim = 24u64;
        let peak = (n_sas * sa_dim * sa_dim * 2) as f64 * 1e9;
        let f = achieved_flops(s, n_sas, sa_dim, 1.0, 1.0);
        assert!(f <= peak, "f={f} peak={peak}");
        // ...and large square matmuls should come close (>70%)
        assert!(f > 0.7 * peak, "f={f} peak={peak}");
    }
}
