//! 28nm area / typical-power analytic model (regenerates paper Table 2).
//!
//! The paper synthesizes logic dies, SRAM dies, inter-chiplet interconnects
//! and switches with Synopsys Design Compiler at 28nm and reports typical
//! power from PrimePower; neither tool nor the RTL is shippable, so we model
//! area and power from per-component constants representative of 28nm
//! planar CMOS, with the sustained compute activity per model taken from the
//! simulator's utilization (documented below). The fit lands within a few
//! percent of Table 2's totals and is checked by unit tests.

use crate::config::{DramKind, HwConfig, ModelConfig, ModelId};

/// 28nm component constants.
pub mod constants {
    /// Area of one bf16 MAC PE including local registers (mm^2).
    pub const PE_AREA_MM2: f64 = 0.00086;
    /// Tile-level overhead factor: local adder tree, control, NoC router.
    pub const TILE_OVERHEAD: f64 = 1.18;
    /// SRAM macro density (mm^2 per MiB) at 28nm (~0.25 mm^2/Mb).
    pub const SRAM_MM2_PER_MIB: f64 = 2.1;
    /// Interposer / packaging overhead applied to chiplet silicon.
    pub const PACKAGE_OVERHEAD: f64 = 1.08;
    /// Footprint of one DRAM stack on the wafer perimeter (mm^2).
    pub const DRAM_STACK_MM2: f64 = 110.0;
    /// Area of one NoP switch with in-network reduction (mm^2).
    pub const SWITCH_MM2: f64 = 30.0;
    /// Dynamic energy of one bf16 MAC (pJ).
    pub const MAC_ENERGY_PJ: f64 = 0.56;
    /// SRAM dynamic power as a fraction of PE dynamic power.
    pub const SRAM_DYN_FRACTION: f64 = 0.25;
    /// Leakage per PE (W).
    pub const PE_LEAKAGE_W: f64 = 20e-6;
    /// Typical power of one HBM2 stack under streaming (W).
    pub const HBM2_STACK_W: f64 = 25.0;
    /// Typical power of the SSD tier per channel (W).
    pub const SSD_CHANNEL_W: f64 = 9.0;
    /// Power of one switch (W).
    pub const SWITCH_W: f64 = 15.0;
    /// NoP signaling power budget (W), whole package.
    pub const NOP_W: f64 = 40.0;
}

/// Sustained compute activity (fraction of peak MACs busy, averaged over a
/// training step) per evaluation model. These come from the calibrated
/// simulator's utilization metric: OLMoE runs the highest utilization
/// (top-8 of 64 experts on the smallest platform), Qwen3 the lowest
/// (top-8 of 128 on the largest).
pub fn measured_activity(id: ModelId) -> f64 {
    match id {
        ModelId::Qwen3_30B_A3B => 0.329,
        ModelId::OlmoE_1B_7B => 0.516,
        ModelId::DeepSeekMoE_16B => 0.411,
        ModelId::TinyMoE => 0.25,
    }
}

/// Table 2 row: area + typical power + memory/link parameters.
#[derive(Clone, Debug)]
pub struct HwMetrics {
    /// Model whose platform sizing these metrics describe.
    pub model: ModelId,
    /// Total wafer area (chiplets + DRAM + switches + packaging), mm².
    pub total_area_mm2: f64,
    /// Typical power under training, kW.
    pub total_power_kw: f64,
    /// DRAM capacity per stack, MiB.
    pub dram_cap_mib: f64,
    /// SRAM capacity per tile, MiB.
    pub sram_per_tile_mib: f64,
    /// DRAM bandwidth per stack, GB/s.
    pub dram_bw_gbps: f64,
    /// SRAM bandwidth per tile, GB/s.
    pub sram_bw_gbps: f64,
    /// 2.5D NoP bandwidth per link, GB/s.
    pub nop_link_bw_gbps: f64,
    /// 2.5D NoP bump pitch, µm.
    pub nop_pitch_um: f64,
    /// 3D hybrid-bonding bandwidth per link, GB/s.
    pub hb_link_bw_gbps: f64,
    /// 3D hybrid-bonding bump pitch, µm.
    pub hb_pitch_um: f64,
    /// Typical-power decomposition.
    pub power: PowerBreakdown,
    /// Silicon area of all compute chiplets (pre-packaging), mm².
    pub area_chiplets_mm2: f64,
    /// Footprint of the DRAM stacks, mm².
    pub area_dram_mm2: f64,
    /// Area of the NoP switches, mm².
    pub area_switch_mm2: f64,
}

/// Power decomposition (W).
#[derive(Clone, Debug)]
pub struct PowerBreakdown {
    /// Dynamic power of the PE arrays.
    pub pe_dynamic: f64,
    /// Dynamic power of the SRAM dies.
    pub sram_dynamic: f64,
    /// Leakage of all PEs.
    pub leakage: f64,
    /// DRAM stack power.
    pub dram: f64,
    /// NoP switch power.
    pub switches: f64,
    /// NoP signaling power.
    pub nop: f64,
}

impl PowerBreakdown {
    /// Sum of all components (W).
    pub fn total(&self) -> f64 {
        self.pe_dynamic + self.sram_dynamic + self.leakage + self.dram + self.switches + self.nop
    }
}

/// Total PEs on the platform (MoE chiplets + attention chiplet).
fn total_pes(hw: &HwConfig) -> f64 {
    let moe = hw.n_moe_chiplets as f64
        * hw.moe_chiplet.tiles as f64
        * hw.moe_chiplet.sas_per_tile as f64
        * hw.moe_chiplet.pes_per_sa as f64;
    let attn = hw.attn_chiplet.tiles as f64
        * hw.attn_chiplet.sas_per_tile as f64
        * hw.attn_chiplet.pes_per_sa as f64;
    moe + attn
}

/// Compute the Table 2 metrics for one model's platform.
pub fn hw_metrics(model: &ModelConfig, hw: &HwConfig) -> HwMetrics {
    use constants::*;
    // --- area ---
    let tile_logic = |c: &crate::config::ChipletSpec| -> f64 {
        c.sas_per_tile as f64 * c.pes_per_sa as f64 * PE_AREA_MM2 * TILE_OVERHEAD
    };
    // 3D stack: the chiplet footprint is the larger of the logic die and the
    // SRAM die under it.
    let chiplet_area = |c: &crate::config::ChipletSpec| -> f64 {
        let logic = c.tiles as f64 * tile_logic(c);
        let sram = c.tiles as f64 * c.sram_per_tile_mib * SRAM_MM2_PER_MIB;
        logic.max(sram)
    };
    let area_chiplets = hw.n_moe_chiplets as f64 * chiplet_area(&hw.moe_chiplet)
        + chiplet_area(&hw.attn_chiplet);
    let area_dram =
        (hw.mem.group_dram_stacks + hw.mem.attn_dram_stacks) as f64 * DRAM_STACK_MM2;
    let area_switch = hw.n_groups as f64 * SWITCH_MM2;
    let total_area = area_chiplets * PACKAGE_OVERHEAD + area_dram + area_switch;

    // --- power ---
    let n_pes = total_pes(hw);
    let activity = measured_activity(model.id);
    let pe_dyn = n_pes * hw.freq_ghz * 1e9 * activity * MAC_ENERGY_PJ * 1e-12;
    let sram_dyn = pe_dyn * SRAM_DYN_FRACTION;
    let leakage = n_pes * PE_LEAKAGE_W;
    let n_stacks = (hw.mem.group_dram_stacks + hw.mem.attn_dram_stacks) as f64;
    let dram = match hw.mem.dram {
        DramKind::Hbm2 => n_stacks * HBM2_STACK_W,
        DramKind::Ssd => n_stacks * SSD_CHANNEL_W,
    };
    let power = PowerBreakdown {
        pe_dynamic: pe_dyn,
        sram_dynamic: sram_dyn,
        leakage,
        dram,
        switches: hw.n_groups as f64 * SWITCH_W,
        nop: NOP_W,
    };

    HwMetrics {
        model: model.id,
        total_area_mm2: total_area,
        total_power_kw: power.total() / 1e3,
        dram_cap_mib: hw.mem.dram_cap_mib,
        sram_per_tile_mib: hw.moe_chiplet.sram_per_tile_mib,
        dram_bw_gbps: hw.mem.dram_bw_gbps(),
        sram_bw_gbps: hw.moe_chiplet.sram_bw_gbps,
        nop_link_bw_gbps: hw.nop.link_bw_gbps,
        nop_pitch_um: hw.nop.pitch_um,
        hb_link_bw_gbps: hw.mem.hb_link_bw_gbps,
        hb_pitch_um: hw.nop.pitch_um,
        power,
        area_chiplets_mm2: area_chiplets,
        area_dram_mm2: area_dram,
        area_switch_mm2: area_switch,
    }
}

/// Paper Table 2 anchors (area mm^2, power kW) for validation.
pub fn paper_table2_anchor(id: ModelId) -> Option<(f64, f64)> {
    match id {
        ModelId::Qwen3_30B_A3B => Some((14175.0, 3.34)),
        ModelId::OlmoE_1B_7B => Some((10200.0, 3.55)),
        ModelId::DeepSeekMoE_16B => Some((11230.0, 3.19)),
        ModelId::TinyMoE => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramKind, HwConfig, ModelConfig};

    #[test]
    fn table2_area_within_5pct() {
        for id in ModelId::PAPER_MODELS {
            let m = ModelConfig::preset(id);
            let hw = HwConfig::paper_for_model(id, DramKind::Hbm2);
            let metrics = hw_metrics(&m, &hw);
            let (area, _) = paper_table2_anchor(id).unwrap();
            let rel = (metrics.total_area_mm2 - area).abs() / area;
            assert!(
                rel < 0.05,
                "{}: area {} vs paper {area} ({:.1}%)",
                id.name(),
                metrics.total_area_mm2,
                rel * 100.0
            );
        }
    }

    #[test]
    fn table2_power_within_5pct() {
        for id in ModelId::PAPER_MODELS {
            let m = ModelConfig::preset(id);
            let hw = HwConfig::paper_for_model(id, DramKind::Hbm2);
            let metrics = hw_metrics(&m, &hw);
            let (_, kw) = paper_table2_anchor(id).unwrap();
            let rel = (metrics.total_power_kw - kw).abs() / kw;
            assert!(
                rel < 0.05,
                "{}: power {} vs paper {kw} ({:.1}%)",
                id.name(),
                metrics.total_power_kw,
                rel * 100.0
            );
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        let hw = HwConfig::paper_for_model(m.id, DramKind::Hbm2);
        let metrics = hw_metrics(&m, &hw);
        assert!((metrics.power.total() / 1e3 - metrics.total_power_kw).abs() < 1e-12);
        assert!(metrics.power.pe_dynamic > metrics.power.leakage);
    }

    #[test]
    fn memory_columns_match_table2() {
        let m = ModelConfig::preset(ModelId::OlmoE_1B_7B);
        let hw = HwConfig::paper_for_model(m.id, DramKind::Hbm2);
        let metrics = hw_metrics(&m, &hw);
        assert_eq!(metrics.dram_cap_mib, 8192.0);
        assert_eq!(metrics.sram_per_tile_mib, 2.265);
        assert_eq!(metrics.dram_bw_gbps, 256.0);
        assert_eq!(metrics.sram_bw_gbps, 32.0);
        assert_eq!(metrics.nop_link_bw_gbps, 0.125);
        assert_eq!(metrics.nop_pitch_um, 50.0);
    }

    #[test]
    fn ssd_platform_draws_less_dram_power() {
        let m = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
        let hbm = hw_metrics(&m, &HwConfig::paper_for_model(m.id, DramKind::Hbm2));
        let ssd = hw_metrics(&m, &HwConfig::paper_for_model(m.id, DramKind::Ssd));
        assert!(ssd.power.dram < hbm.power.dram);
    }
}
