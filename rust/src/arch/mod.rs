//! Architecture models: systolic-array compute timing, memory tiers, and
//! the 28nm area/power analytic model that regenerates paper Table 2.

pub mod area;
pub mod compute;

pub use area::{HwMetrics, PowerBreakdown};
pub use compute::{matmul_cycles, matmul_time, MatmulShape};
