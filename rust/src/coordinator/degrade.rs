//! `mozart degrade` — fault-severity sweeps and graceful-degradation curves.
//!
//! For each (model × method) cell and each fault scenario, the sweep scales
//! the scenario's severity from 0 (healthy) to 1 (the scenario as written)
//! via [`FaultScenario::at_severity`], re-simulates the training step, and
//! reports the **retained throughput** fraction
//! `healthy latency / faulted latency` — exactly the resilience metric the
//! NSGA-II `--min-resilience` constraint gates on
//! (`coordinator::search`), so a degrade curve reads as "where along this
//! fault axis does a platform fall below its resilience floor".
//!
//! Severity 0 is simulated with [`FaultScenario::none`] (not
//! `at_severity(0.0)`): count-based faults such as `dead-chiplet:N` keep at
//! least one dead chiplet at any positive interpretation of the scenario,
//! so the healthy anchor must bypass the scenario entirely. Its retained
//! fraction is exactly `1.0` (the same experiment divided by itself).
//!
//! Everything is seeded and deterministic: the same `(config, seed)` pair
//! reproduces the same curves bit for bit, sequentially or on the parallel
//! executor.

use crate::comm::FaultScenario;
use crate::config::{DramKind, Method, ModelId, SchedPolicy};
use crate::coordinator::cache::{EvalOptions, EvalSession, EvalStats};
use crate::coordinator::sweep::{cell_config_sched, parallel_map_with, Cell};
use crate::util::json::Json;
use crate::util::table::{scatter_plot, Table};

/// Configuration of one degrade sweep.
#[derive(Clone, Debug)]
pub struct DegradeConfig {
    /// Models to sweep (one curve set per model).
    pub models: Vec<ModelId>,
    /// Methods to sweep (one curve set per method).
    pub methods: Vec<Method>,
    /// DRAM technology for every cell.
    pub dram: DramKind,
    /// Fault scenarios; each yields one severity curve per (model, method).
    pub scenarios: Vec<FaultScenario>,
    /// Number of positive severity steps; severities are `i / steps` for
    /// `i in 1..=steps`, plus the healthy severity-0 anchor.
    pub steps: usize,
    /// Sequence length per cell.
    pub seq_len: usize,
    /// Simulated training iterations averaged per point.
    pub iters: usize,
    /// Master seed (simulation, routing, and fault placement).
    pub seed: u64,
    /// Worker threads for the parallel executor (0 = auto).
    pub threads: usize,
    /// Cap on the number of *faulted* points simulated (0 = no cap). The
    /// healthy anchors always run — retained throughput needs them — and
    /// any truncation is reported, never silent.
    pub budget: usize,
    /// DAG scheduling policy every cell (healthy and faulted) is simulated
    /// under (`--sched`); both sides of each retained-throughput ratio use
    /// the same policy, so the curves compare like with like.
    pub sched: SchedPolicy,
    /// Evaluation-throughput toggles (memoization cache, delta re-timing).
    /// Bit-transparent: severity points of the bandwidth-fault curves share
    /// the healthy topology and re-time it instead of rebuilding.
    pub eval: EvalOptions,
}

impl DegradeConfig {
    /// Paper-flavoured default: the fastest model, the full Mozart method,
    /// one curve per fault kind, four severity steps.
    pub fn paper_default() -> DegradeConfig {
        let seed = 7;
        DegradeConfig {
            models: vec![ModelId::OlmoE_1B_7B],
            methods: vec![Method::MozartC],
            dram: DramKind::Hbm2,
            scenarios: default_scenarios(seed),
            steps: 4,
            seq_len: 128,
            iters: 2,
            seed,
            threads: 0,
            budget: 0,
            sched: SchedPolicy::Streaming,
            eval: EvalOptions::default(),
        }
    }
}

/// The default scenario set: one curve per fault kind, at the reference
/// severities used throughout the docs (4 dead chiplets, 4× link/compute/
/// DRAM degradation at full severity).
pub fn default_scenarios(seed: u64) -> Vec<FaultScenario> {
    [
        "dead-chiplet:4",
        "nop-degrade:0.25",
        "hb-degrade:0.25",
        "dram-throttle:0.25",
    ]
    .iter()
    .map(|s| {
        FaultScenario::parse(s, seed).expect("default degrade scenarios parse")
    })
    .collect()
}

/// One simulated point on a degrade curve.
#[derive(Clone, Debug)]
pub struct DegradePoint {
    /// Model of the cell.
    pub model: ModelId,
    /// Method of the cell.
    pub method: Method,
    /// Scenario label (`FaultScenario::label`); `"healthy"` only ever
    /// appears via the severity-0 anchors, which carry their curve's label
    /// instead so each curve is self-contained.
    pub scenario: String,
    /// Severity in `[0, 1]`; 0 is the healthy anchor.
    pub severity: f64,
    /// Mean step latency at this severity (seconds).
    pub latency_s: f64,
    /// Retained throughput: healthy latency / this latency. Exactly 1.0 at
    /// severity 0.
    pub retained: f64,
}

/// Outcome of a degrade sweep: every curve point plus truncation
/// accounting.
#[derive(Clone, Debug)]
pub struct DegradeOutcome {
    /// Sweep configuration echo.
    pub cfg: DegradeConfig,
    /// All points, ordered by (model, method, scenario, severity).
    pub points: Vec<DegradePoint>,
    /// Faulted points dropped by `cfg.budget` (0 when the budget was off
    /// or large enough).
    pub dropped: usize,
    /// Evaluation-throughput accounting (cache hits, plan builds/re-times).
    /// Wall-clock only — never influences a curve point.
    pub eval: EvalStats,
}

/// Run the sweep: healthy anchors first (they define retained throughput),
/// then every (cell × scenario × severity) point over the work-stealing
/// pool. Point order in the output is deterministic and independent of the
/// thread count.
pub fn run(cfg: &DegradeConfig) -> DegradeOutcome {
    let mut cells: Vec<Cell> = Vec::new();
    for &model in &cfg.models {
        for &method in &cfg.methods {
            cells.push(Cell {
                model,
                method,
                seq_len: cfg.seq_len,
                dram: cfg.dram,
            });
        }
    }

    let session = EvalSession::new(cfg.eval.clone());

    // healthy anchors: one per cell
    let healthy: Vec<f64> = parallel_map_with(
        &cells,
        cfg.threads,
        session.pools(),
        || session.new_pool(),
        |pool, &cell| {
            let mut ctx = session.ctx(pool);
            ctx.run(&cell_config_sched(cell, cfg.iters, cfg.seed, cfg.sched))
                .latency
        },
    );

    // faulted jobs: (cell index, scenario index, severity step 1..=steps)
    let mut jobs: Vec<(usize, usize, usize)> = Vec::new();
    for ci in 0..cells.len() {
        for si in 0..cfg.scenarios.len() {
            for ti in 1..=cfg.steps {
                jobs.push((ci, si, ti));
            }
        }
    }
    let total = jobs.len();
    if cfg.budget > 0 && jobs.len() > cfg.budget {
        jobs.truncate(cfg.budget);
    }
    let dropped = total - jobs.len();

    let faulted: Vec<f64> = parallel_map_with(
        &jobs,
        cfg.threads,
        session.pools(),
        || session.new_pool(),
        |pool, &(ci, si, ti)| {
            let severity = ti as f64 / cfg.steps as f64;
            let mut ec = cell_config_sched(cells[ci], cfg.iters, cfg.seed, cfg.sched);
            ec.fault = cfg.scenarios[si].at_severity(severity);
            let mut ctx = session.ctx(pool);
            ctx.run(&ec).latency
        },
    );

    // assemble curves in deterministic (cell, scenario, severity) order
    let mut points = Vec::with_capacity(cells.len() * cfg.scenarios.len() + faulted.len());
    let mut by_job: std::collections::BTreeMap<(usize, usize, usize), f64> =
        std::collections::BTreeMap::new();
    for (j, &(ci, si, ti)) in jobs.iter().enumerate() {
        by_job.insert((ci, si, ti), faulted[j]);
    }
    for (ci, cell) in cells.iter().enumerate() {
        for (si, scenario) in cfg.scenarios.iter().enumerate() {
            points.push(DegradePoint {
                model: cell.model,
                method: cell.method,
                scenario: scenario.label(),
                severity: 0.0,
                latency_s: healthy[ci],
                retained: healthy[ci] / healthy[ci], // exactly 1.0
            });
            for ti in 1..=cfg.steps {
                if let Some(&lat) = by_job.get(&(ci, si, ti)) {
                    points.push(DegradePoint {
                        model: cell.model,
                        method: cell.method,
                        scenario: scenario.label(),
                        severity: ti as f64 / cfg.steps as f64,
                        latency_s: lat,
                        retained: healthy[ci] / lat,
                    });
                }
            }
        }
    }

    DegradeOutcome {
        cfg: cfg.clone(),
        points,
        dropped,
        eval: session.finish(),
    }
}

impl DegradeOutcome {
    /// Human-readable report: one table per (model, method) cell plus an
    /// ASCII retained-throughput-vs-severity plot overlaying every
    /// scenario's curve (one marker letter per scenario).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# Graceful degradation under injected faults\n\n");
        if self.dropped > 0 {
            out.push_str(&format!(
                "> budget truncation: {} faulted point(s) NOT simulated \
                 (--budget {}); curves below are partial\n\n",
                self.dropped, self.cfg.budget
            ));
        }
        for &model in &self.models() {
            for &method in &self.methods_of(model) {
                let mut t = Table::new(
                    &format!(
                        "{} / {} — retained throughput vs fault severity",
                        model.name(),
                        method.name()
                    ),
                    &["scenario", "severity", "latency s/step", "retained"],
                );
                let mut plot: Vec<(f64, f64, char)> = Vec::new();
                let mut legend: Vec<(char, String)> = Vec::new();
                for p in &self.points {
                    if p.model != model || p.method != method {
                        continue;
                    }
                    t.row(&[
                        p.scenario.clone(),
                        format!("{:.2}", p.severity),
                        format!("{:.4}", p.latency_s),
                        format!("{:.3}", p.retained),
                    ]);
                    let mark = Self::marker(&p.scenario, &mut legend);
                    plot.push((p.severity, p.retained, mark));
                }
                out.push_str(&t.render());
                out.push('\n');
                out.push_str(&scatter_plot(
                    &format!("{} / {}: retained vs severity", model.name(), method.name()),
                    "severity",
                    "retained",
                    &plot,
                ));
                out.push('\n');
                for (mark, label) in &legend {
                    out.push_str(&format!("  {mark} = {label}\n"));
                }
                out.push('\n');
            }
        }
        out
    }

    /// Stable per-scenario plot marker: first unused letter of the
    /// scenario label, falling back through a fixed alphabet.
    fn marker(scenario: &str, legend: &mut Vec<(char, String)>) -> char {
        if let Some((m, _)) = legend.iter().find(|(_, l)| l == scenario) {
            return *m;
        }
        let preferred = scenario.chars().find(|c| c.is_ascii_alphabetic());
        let mut candidates: Vec<char> = preferred.into_iter().collect();
        candidates.extend("abcdefghijklmnopqrstuvwxyz".chars());
        let mark = candidates
            .into_iter()
            .find(|c| legend.iter().all(|(m, _)| m != c))
            .unwrap_or('*');
        legend.push((mark, scenario.to_string()));
        mark
    }

    fn models(&self) -> Vec<ModelId> {
        let mut v = Vec::new();
        for p in &self.points {
            if !v.contains(&p.model) {
                v.push(p.model);
            }
        }
        v
    }

    fn methods_of(&self, model: ModelId) -> Vec<Method> {
        let mut v = Vec::new();
        for p in &self.points {
            if p.model == model && !v.contains(&p.method) {
                v.push(p.method);
            }
        }
        v
    }

    /// Machine-readable artifact (`DEGRADE_*.json`).
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                Json::obj([
                    ("model", Json::str(p.model.name())),
                    ("method", Json::str(p.method.name())),
                    ("scenario", Json::str(p.scenario.as_str())),
                    ("severity", Json::num(p.severity)),
                    ("latency_s", Json::num(p.latency_s)),
                    ("retained", Json::num(p.retained)),
                ])
            })
            .collect();
        Json::obj([
            ("artifact", Json::str("degrade")),
            (
                "scenarios",
                Json::Arr(
                    self.cfg
                        .scenarios
                        .iter()
                        .map(|s| Json::str(s.label()))
                        .collect(),
                ),
            ),
            ("steps", Json::int(self.cfg.steps)),
            ("seq_len", Json::int(self.cfg.seq_len)),
            ("iters", Json::int(self.cfg.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53, breaking reproduction from the artifact
            ("seed", Json::str(self.cfg.seed.to_string())),
            ("dram", Json::str(self.cfg.dram.name())),
            ("sched", Json::str(self.cfg.sched.name())),
            ("dropped_by_budget", Json::int(self.dropped)),
            ("cache", self.eval.to_json()),
            ("points", Json::Arr(points)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(threads: usize) -> DegradeConfig {
        DegradeConfig {
            models: vec![ModelId::OlmoE_1B_7B],
            methods: vec![Method::MozartC],
            dram: DramKind::Hbm2,
            scenarios: default_scenarios(11),
            steps: 2,
            seq_len: 64,
            iters: 1,
            seed: 11,
            threads,
            budget: 0,
            sched: SchedPolicy::Streaming,
            eval: EvalOptions::default(),
        }
    }

    #[test]
    fn default_scenarios_cover_at_least_three_fault_kinds() {
        let s = default_scenarios(7);
        assert!(s.len() >= 3, "need >= 3 degrade curves, got {}", s.len());
        let mut kinds: Vec<&str> = s
            .iter()
            .flat_map(|sc| sc.faults.iter().map(|f| f.kind()))
            .collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert!(kinds.len() >= 3, "kinds not distinct: {kinds:?}");
    }

    #[test]
    fn sweep_produces_full_curves_with_exact_healthy_anchor() {
        let out = run(&tiny(1));
        let cfg = tiny(1);
        let expected = cfg.scenarios.len() * (cfg.steps + 1);
        assert_eq!(out.points.len(), expected);
        assert_eq!(out.dropped, 0);
        for p in &out.points {
            assert!(p.latency_s.is_finite() && p.latency_s > 0.0);
            assert!(p.retained.is_finite() && p.retained > 0.0);
            // faults never meaningfully speed the step up. Bandwidth/compute
            // throttles only stretch durations; dead-chiplet spill also
            // re-samples the workload over the survivor layout, so it gets a
            // small sampling-noise allowance instead of an exact bound.
            let tol = if p.scenario.contains("dead-chiplet") {
                0.05
            } else {
                1e-6
            };
            assert!(
                p.retained <= 1.0 + tol,
                "{} severity {}: retained {} > 1",
                p.scenario,
                p.severity,
                p.retained
            );
            if p.severity == 0.0 {
                assert_eq!(p.retained.to_bits(), 1.0f64.to_bits());
            }
        }
    }

    #[test]
    fn severity_one_matches_the_scenario_as_written() {
        // the curve's endpoint must equal a direct simulation of the
        // un-scaled scenario — at_severity(1.0) is the identity
        let cfg = tiny(1);
        let out = run(&cfg);
        let p = out
            .points
            .iter()
            .find(|p| p.scenario == cfg.scenarios[0].label() && p.severity == 1.0)
            .expect("endpoint present");
        let mut ec = cell_config_sched(
            Cell {
                model: cfg.models[0],
                method: cfg.methods[0],
                seq_len: cfg.seq_len,
                dram: cfg.dram,
            },
            cfg.iters,
            cfg.seed,
            cfg.sched,
        );
        ec.fault = cfg.scenarios[0].clone();
        let direct = crate::coordinator::run_experiment(&ec).latency;
        assert_eq!(p.latency_s.to_bits(), direct.to_bits());
    }

    /// The throughput layers must not change a single curve point, and the
    /// bandwidth-severity sweeps must actually exercise the re-timing path
    /// (they share the healthy topology).
    #[test]
    fn pooled_sweep_is_bit_identical_to_plain_runs() {
        let fast = tiny(1);
        let mut slow = tiny(1);
        slow.eval = EvalOptions {
            cache: false,
            retime: false,
            ..Default::default()
        };
        let a = run(&fast);
        let b = run(&slow);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
            assert_eq!(x.retained.to_bits(), y.retained.to_bits());
        }
        assert!(a.eval.retimes > 0, "bandwidth severities should re-time");
        assert_eq!(b.eval.retimes, 0);
        // disabled layers: every cell is a plain full build, nothing cached
        assert_eq!(b.eval.builds, a.eval.builds + a.eval.retimes);
        assert_eq!(b.eval.cache.misses, 0);
    }

    #[test]
    fn sweep_is_reproducible_and_thread_invariant() {
        let a = run(&tiny(1));
        let b = run(&tiny(2));
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.scenario, y.scenario);
            assert_eq!(x.severity.to_bits(), y.severity.to_bits());
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
            assert_eq!(x.retained.to_bits(), y.retained.to_bits());
        }
    }

    #[test]
    fn budget_truncates_and_reports() {
        let mut cfg = tiny(1);
        cfg.budget = 3;
        let out = run(&cfg);
        // all healthy anchors present, only `budget` faulted points
        let anchors = out.points.iter().filter(|p| p.severity == 0.0).count();
        assert_eq!(anchors, cfg.scenarios.len());
        let faulted = out.points.len() - anchors;
        assert_eq!(faulted, 3);
        assert_eq!(out.dropped, cfg.scenarios.len() * cfg.steps - 3);
        assert!(out.render_markdown().contains("budget truncation"));
    }

    #[test]
    fn report_and_json_are_well_formed() {
        let out = run(&tiny(0));
        let md = out.render_markdown();
        assert!(md.contains("retained throughput vs fault severity"));
        assert!(md.contains("retained vs severity"));
        assert!(md.contains("dead-chiplet:4"));
        let js = out.to_json().render_pretty();
        for key in [
            "\"artifact\"",
            "\"scenarios\"",
            "\"seed\"",
            "\"points\"",
            "\"retained\"",
            "\"severity\"",
            "\"dropped_by_budget\"",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
        // seed serialized as a string
        assert!(js.contains("\"seed\": \"11\""));
    }
}
