//! L3 coordinator: orchestrates one experiment — profile the routing prior,
//! derive the expert layout for the configured method, sample per-step
//! routing workloads, build and simulate the training-step plans, and
//! aggregate latency / C_T / breakdown / energy across iterations.
//!
//! This is the module that composes the paper's three algorithm
//! contributions (§4.2 clustering+allocation, §3.3/§4.2 efficient
//! all-to-all, §4.3 fine-grained scheduling) over the architecture model
//! (§4.4) into end-to-end numbers.

pub mod cache;
pub mod degrade;
pub mod explore;
pub mod search;
pub mod serve;
pub mod sweep;
pub mod tenants;

use crate::allocation::ExpertLayout;
use crate::config::ExperimentConfig;
use crate::metrics::energy::{step_energy, EnergyBreakdown};
use crate::pipeline::{PlanCache, StepWorkload};
use crate::sim::{SimScratch, Simulator, Tag, TagBreakdown};
use crate::trace::{Priors, TraceGen};
use crate::util::rng::Rng;
use crate::util::stats;

/// Aggregated outcome of one experiment cell.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    /// Mean end-to-end latency per training step (seconds).
    pub latency: f64,
    /// Standard deviation of the per-step latency across iterations.
    pub latency_std: f64,
    /// Mean all-to-all replication factor C_T (Table 4 metric).
    pub c_t: f64,
    /// Mean busy seconds per tag per step.
    pub tag_busy: TagBreakdown,
    /// Mean critical-path seconds per tag per step.
    pub critical: TagBreakdown,
    /// Mean per-step energy.
    pub energy: EnergyBreakdown,
    /// Workload imbalance across groups (max/mean of token-slots).
    pub group_imbalance: f64,
    /// Mean MoE-compute utilization (busy / makespan, averaged chiplets).
    pub moe_utilization: f64,
    /// Iterations averaged over.
    pub iters: usize,
}

impl ExperimentResult {
    /// Mean busy seconds per step of `tag`.
    pub fn tag_time(&self, tag: Tag) -> f64 {
        self.tag_busy.get(tag)
    }

    /// Mean critical-path seconds per step attributed to `tag`.
    pub fn critical_time(&self, tag: Tag) -> f64 {
        self.critical.get(tag)
    }
}

/// Derive the per-layer expert layouts for a method: Mozart-C profiles the
/// prior of every MoE layer (the paper's §3.2 pre-deployment profiling) and
/// runs Algorithm 1 clustering + Eq. 5 allocation per layer; everything
/// else keeps the default contiguous layout (paper Table 3).
pub fn layouts_for(cfg: &ExperimentConfig, gen: &TraceGen) -> Vec<ExpertLayout> {
    let hw = &cfg.hw;
    let n_layers = cfg.model.n_moe_layers();
    let mut layouts = if cfg.method.expert_layout {
        let profile_tokens = 4096;
        let traces = gen.profile(profile_tokens, cfg.seed ^ 0x50F1_1E);
        traces
            .iter()
            .map(|tr| {
                let priors = Priors::from_trace(tr);
                ExpertLayout::mozart(&priors, hw.n_moe_chiplets, hw.n_groups)
            })
            .collect()
    } else {
        vec![
            ExpertLayout::contiguous(cfg.model.n_experts, hw.n_moe_chiplets, hw.n_groups);
            n_layers
        ]
    };
    // Graceful degradation: experts homed on dead chiplets spill onto the
    // least-loaded survivors (same objective as Eq. 5). The healthy scenario
    // has no dead set and leaves the layouts untouched.
    if !cfg.fault.is_healthy() {
        let fx = cfg.fault.effects(hw.n_moe_chiplets, hw.n_groups);
        let dead = fx.dead();
        if !dead.is_empty() {
            for layout in &mut layouts {
                layout.spill_dead(&dead);
            }
        }
    }
    layouts
}

/// Run one experiment cell: `cfg.iters` simulated training steps with fresh
/// routing each step, averaged.
///
/// Hot path: the plan topology (resources, placements, byte/FLOP model) is
/// built once in a [`PlanCache`]; each iteration re-emits only the sampled
/// durations/bytes over the cache's reusable arena and runs the simulator
/// over reusable [`SimScratch`] buffers.
pub fn run_experiment(cfg: &ExperimentConfig) -> ExperimentResult {
    let gen = TraceGen::for_model(&cfg.model, cfg.seed);
    let layouts = layouts_for(cfg, &gen);
    for layout in &layouts {
        layout.validate().expect("layout invariants");
    }
    let mut plan_cache = PlanCache::new(cfg, &layouts);
    let mut scratch = SimScratch::new();
    run_prepared(cfg, &gen, &layouts, &mut plan_cache, &mut scratch)
}

/// The iteration loop shared by [`run_experiment`] and the pooled delta
/// re-timing path ([`cache::EvalPool`]): simulate `cfg.iters` training
/// steps over an already-prepared topology and aggregate.
///
/// Contract: `gen`/`layouts` were derived from a config with the same
/// topology fingerprint as `cfg` (same model, seed, workload shape, and
/// fault dead-set), and `plan_cache` has been built or
/// [`PlanCache::retime`]d for `cfg`. Under that contract the result is
/// bit-identical to `run_experiment(cfg)` — every quantity in the loop is
/// a deterministic function of `cfg` and the prepared state.
pub fn run_prepared(
    cfg: &ExperimentConfig,
    gen: &TraceGen,
    layouts: &[ExpertLayout],
    cache: &mut PlanCache,
    scratch: &mut SimScratch,
) -> ExperimentResult {
    let coalesce = cfg.method.efficient_a2a;
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut latencies = Vec::with_capacity(cfg.iters);
    let mut cts = Vec::with_capacity(cfg.iters);
    let mut tag_busy = TagBreakdown::zero();
    let mut critical = TagBreakdown::zero();
    let mut energy_acc: Option<EnergyBreakdown> = None;
    let mut imbalance_acc = 0.0;
    let mut util_acc = 0.0;

    for it in 0..cfg.iters {
        let mut step_rng = rng.fork(it as u64);
        let workload = StepWorkload::sample(cfg, gen, layouts, coalesce, &mut step_rng);
        let plan = cache.rebuild(&workload);
        if it == 0 {
            // Guard the engine's contract once per experiment: durations/
            // bytes/flops are finite and the DAG is acyclic. NaN can only
            // enter through the workload-independent calibration constants,
            // so the first iteration's plan is representative; validating
            // every iteration would spend an extra O(tasks+deps) pass per
            // step on the hot path for no additional coverage.
            plan.validate().expect("step plan invariants");
        }
        // `cfg.sched` picks the dispatch policy; `streaming` routes through
        // the exact historical path, so default configs stay bit-identical.
        let res = Simulator::run_policy(plan, cfg.sched, cfg.seed, scratch);
        latencies.push(res.makespan);
        cts.push(workload.mean_c_t);
        tag_busy.accumulate_div(&res.tag_busy, cfg.iters as f64);
        critical.accumulate_div(&res.critical_path, cfg.iters as f64);
        let e = step_energy(cfg, &res);
        energy_acc = Some(match energy_acc {
            None => e.scale(1.0 / cfg.iters as f64),
            Some(acc) => acc.add(&e.scale(1.0 / cfg.iters as f64)),
        });

        // group imbalance over the step's token-slots
        let per = cfg.hw.chiplets_per_group();
        let mut group_slots = vec![0.0f64; cfg.hw.n_groups];
        for row in &workload.cells {
            for cell in row {
                for g in 0..cfg.hw.n_groups {
                    group_slots[g] += cell.chiplet_slots[g * per..(g + 1) * per]
                        .iter()
                        .sum::<u64>() as f64;
                }
            }
        }
        imbalance_acc += stats::imbalance(&group_slots) / cfg.iters as f64;

        // MoE compute utilization: moe resources are indexed after
        // attn-compute, attn-dram and the group streams
        let first_moe = 2 + cfg.hw.n_groups;
        let mut u = 0.0;
        for c in 0..cfg.hw.n_moe_chiplets {
            u += res.utilization(first_moe + c);
        }
        util_acc += u / cfg.hw.n_moe_chiplets as f64 / cfg.iters as f64;
    }

    ExperimentResult {
        latency: stats::mean(&latencies),
        latency_std: stats::std(&latencies),
        c_t: stats::mean(&cts),
        tag_busy,
        critical,
        energy: energy_acc.expect("at least one iteration"),
        group_imbalance: imbalance_acc,
        moe_utilization: util_acc,
        iters: cfg.iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, Method, ModelConfig, ModelId};

    fn cfg(method: Method) -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::OlmoE_1B_7B),
            method.config(),
        );
        c.seq_len = 64;
        c.iters = 2;
        c
    }

    #[test]
    fn sched_policy_is_a_pure_retiming() {
        // the policy reorders work, it never changes the work: total busy
        // time per tag is bit-identical across all four policies, and every
        // policy yields a positive latency
        use crate::config::SchedPolicy;
        let mut c = cfg(Method::MozartC);
        let mut results = Vec::new();
        for p in SchedPolicy::ALL {
            c.sched = p;
            let r = run_experiment(&c);
            assert!(r.latency > 0.0, "{} produced no schedule", p.name());
            results.push(r);
        }
        for r in &results[1..] {
            assert_eq!(r.tag_busy, results[0].tag_busy);
        }
    }

    #[test]
    fn experiment_runs_and_aggregates() {
        let r = run_experiment(&cfg(Method::MozartC));
        assert!(r.latency > 0.0);
        assert!(r.c_t > 1.0 && r.c_t <= 8.0);
        assert!(r.energy.total_j() > 0.0);
        assert_eq!(r.iters, 2);
        assert!(r.moe_utilization > 0.0 && r.moe_utilization <= 1.0);
    }

    #[test]
    fn method_ablation_ordering() {
        let base = run_experiment(&cfg(Method::Baseline)).latency;
        let a = run_experiment(&cfg(Method::MozartA)).latency;
        let c = run_experiment(&cfg(Method::MozartC)).latency;
        assert!(a < base);
        assert!(c < a * 1.02);
    }

    #[test]
    fn mozart_c_reduces_ct() {
        let b = run_experiment(&cfg(Method::MozartB));
        let c = run_experiment(&cfg(Method::MozartC));
        assert!(c.c_t < b.c_t, "C {} !< B {}", c.c_t, b.c_t);
        // balance stays within a sane envelope (Eq. 5 balances the expected
        // workload; per-step sampling noise remains)
        assert!(c.group_imbalance < 1.3, "imbalance {}", c.group_imbalance);
    }

    #[test]
    fn baseline_ct_is_k() {
        let r = run_experiment(&cfg(Method::MozartA));
        assert!((r.c_t - 8.0).abs() < 1e-9); // no elision -> C_T == k
    }

    #[test]
    fn faulted_experiment_degrades_gracefully() {
        let h = run_experiment(&cfg(Method::MozartC));
        let mut fc = cfg(Method::MozartC);
        fc.fault =
            crate::comm::FaultScenario::parse("dead-chiplet:2,dram-throttle:0.25", fc.seed)
                .unwrap();
        let f = run_experiment(&fc);
        assert!(
            f.latency > h.latency,
            "faulted {} !> healthy {}",
            f.latency,
            h.latency
        );
        assert!(f.latency.is_finite());
    }

    /// The all-ones scenario takes the faulted code path (spill check, health
    /// vectors, contention model) yet must reproduce the healthy experiment
    /// bit for bit — the zero-fault regression contract.
    #[test]
    fn all_ones_scenario_is_bit_identical_at_experiment_level() {
        let h = run_experiment(&cfg(Method::MozartC));
        let mut fc = cfg(Method::MozartC);
        fc.fault = crate::comm::FaultScenario::parse(
            "nop-degrade:1,hb-degrade:1,dram-throttle:1",
            fc.seed,
        )
        .unwrap();
        let f = run_experiment(&fc);
        assert_eq!(h.latency.to_bits(), f.latency.to_bits());
        assert_eq!(h.c_t.to_bits(), f.c_t.to_bits());
        assert_eq!(h.energy.total_j().to_bits(), f.energy.total_j().to_bits());
    }

    #[test]
    fn memory_bound_q1() {
        // paper §5.4 Q1: weight streaming dominates the critical path
        let r = run_experiment(&cfg(Method::MozartC));
        let stream = r.critical_time(Tag::WeightStream);
        let compute: f64 = r
            .critical
            .iter()
            .filter(|(t, _)| matches!(t, Tag::MoeCompute | Tag::AttnCompute))
            .map(|(_, v)| v)
            .sum();
        assert!(
            stream > compute,
            "stream {stream} !> compute {compute} (should be memory-bound)"
        );
    }
}
