//! Parameter sweeps over the experiment grid (models x methods x sequence
//! lengths x DRAM kinds), the workhorse behind the Table 3 / Table 4 /
//! Figure 6-9 reports and benches.

use crate::config::{
    DramKind, ExperimentConfig, Method, ModelConfig, ModelId,
};
use crate::coordinator::{run_experiment, ExperimentResult};

/// One grid cell specification.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub model: ModelId,
    pub method: Method,
    pub seq_len: usize,
    pub dram: DramKind,
}

/// A cell's outcome along with its spec.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: Cell,
    pub result: ExperimentResult,
}

/// Build the `ExperimentConfig` for a cell with the paper's workload
/// defaults and this run's iteration budget.
pub fn cell_config(cell: Cell, iters: usize, seed: u64) -> ExperimentConfig {
    let model = ModelConfig::preset(cell.model);
    let mut cfg = ExperimentConfig::paper_default(model, cell.method.config());
    cfg.hw = crate::config::HwConfig::paper_for_model(cell.model, cell.dram);
    cfg.seq_len = cell.seq_len;
    cfg.iters = iters;
    cfg.seed = seed;
    cfg
}

/// Run a list of cells sequentially (deterministic order and seeds).
pub fn run_cells(cells: &[Cell], iters: usize, seed: u64) -> Vec<CellResult> {
    cells
        .iter()
        .map(|&cell| CellResult {
            cell,
            result: run_experiment(&cell_config(cell, iters, seed)),
        })
        .collect()
}

/// The Table 3 / Figure 6(a) grid: 3 models x 4 methods at seq 256, HBM2.
pub fn table3_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for model in ModelId::PAPER_MODELS {
        for method in Method::ALL {
            v.push(Cell {
                model,
                method,
                seq_len: 256,
                dram: DramKind::Hbm2,
            });
        }
    }
    v
}

/// Figure 6(b): sequence-length sweep on Qwen3 / HBM2.
pub fn fig6b_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for seq_len in [128, 256, 512] {
        for method in Method::ALL {
            v.push(Cell {
                model: ModelId::Qwen3_30B_A3B,
                method,
                seq_len,
                dram: DramKind::Hbm2,
            });
        }
    }
    v
}

/// Figure 6(c): DRAM sweep on Qwen3 / seq 256.
pub fn fig6c_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for dram in [DramKind::Hbm2, DramKind::Ssd] {
        for method in Method::ALL {
            v.push(Cell {
                model: ModelId::Qwen3_30B_A3B,
                method,
                seq_len: 256,
                dram,
            });
        }
    }
    v
}

/// Appendix Figures 7/8/9: the full grid at one sequence length.
pub fn appendix_cells(seq_len: usize) -> Vec<Cell> {
    let mut v = Vec::new();
    for model in ModelId::PAPER_MODELS {
        for dram in [DramKind::Hbm2, DramKind::Ssd] {
            for method in Method::ALL {
                v.push(Cell {
                    model,
                    method,
                    seq_len,
                    dram,
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_expected_sizes() {
        assert_eq!(table3_cells().len(), 12);
        assert_eq!(fig6b_cells().len(), 12);
        assert_eq!(fig6c_cells().len(), 8);
        assert_eq!(appendix_cells(128).len(), 24);
    }

    #[test]
    fn cell_config_applies_spec() {
        let cell = Cell {
            model: ModelId::DeepSeekMoE_16B,
            method: Method::MozartB,
            seq_len: 512,
            dram: DramKind::Ssd,
        };
        let cfg = cell_config(cell, 3, 42);
        assert_eq!(cfg.seq_len, 512);
        assert_eq!(cfg.iters, 3);
        assert_eq!(cfg.model.id, ModelId::DeepSeekMoE_16B);
        assert_eq!(cfg.hw.mem.dram, DramKind::Ssd);
        assert!(cfg.method.efficient_a2a && !cfg.method.expert_layout);
    }

    #[test]
    fn run_small_grid() {
        // a 2-cell smoke of the sweep machinery at tiny workload
        let cells = vec![
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::Baseline,
                seq_len: 128,
                dram: DramKind::Hbm2,
            },
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::MozartC,
                seq_len: 128,
                dram: DramKind::Hbm2,
            },
        ];
        let res = run_cells(&cells, 1, 7);
        assert_eq!(res.len(), 2);
        assert!(res[1].result.latency < res[0].result.latency);
    }
}
