//! Parameter sweeps over the experiment grid (models x methods x sequence
//! lengths x DRAM kinds), the workhorse behind the Table 3 / Table 4 /
//! Figure 6-9 reports and benches.
//!
//! # Parallel execution
//!
//! [`run_cells`] fans the grid out across a work-stealing pool of OS
//! threads (the offline crate set has no `rayon`; the pool is a shared
//! atomic cursor over the cell list, which is the same scheduling
//! discipline as `par_iter` for coarse-grained items). Every cell's
//! experiment derives all of its randomness from its own
//! `ExperimentConfig` — the per-cell seed is fixed up front and no state is
//! shared between cells — so results are **bit-identical** to the
//! sequential path ([`run_cells_seq`]) regardless of thread count or
//! completion order. An integration test asserts this on the Table 3 grid.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{
    DramKind, ExperimentConfig, Method, ModelConfig, ModelId, SchedPolicy,
};
use crate::coordinator::{run_experiment, ExperimentResult};

/// One grid cell specification.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// Model preset to simulate.
    pub model: ModelId,
    /// Optimization method (paper Table 3 column).
    pub method: Method,
    /// Sequence length per sample.
    pub seq_len: usize,
    /// Off-chip memory technology.
    pub dram: DramKind,
}

/// A cell's outcome along with its spec.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// The grid cell that was run.
    pub cell: Cell,
    /// Aggregated experiment outcome for the cell.
    pub result: ExperimentResult,
}

/// Build the `ExperimentConfig` for a cell with the paper's workload
/// defaults and this run's iteration budget (streaming scheduler — the
/// paper's schedule; see [`cell_config_sched`] to override).
pub fn cell_config(cell: Cell, iters: usize, seed: u64) -> ExperimentConfig {
    let model = ModelConfig::preset(cell.model);
    let mut cfg = ExperimentConfig::paper_default(model, cell.method.config());
    cfg.hw = crate::config::HwConfig::paper_for_model(cell.model, cell.dram);
    cfg.seq_len = cell.seq_len;
    cfg.iters = iters;
    cfg.seed = seed;
    cfg
}

/// [`cell_config`] with an explicit scheduling policy (`--sched`). With
/// [`SchedPolicy::Streaming`] this is exactly `cell_config` — the default
/// sweep path stays bit-identical.
pub fn cell_config_sched(
    cell: Cell,
    iters: usize,
    seed: u64,
    sched: SchedPolicy,
) -> ExperimentConfig {
    let mut cfg = cell_config(cell, iters, seed);
    cfg.sched = sched;
    cfg
}

/// Execution options for the sweep executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepOptions {
    /// Worker threads; 0 = one per available core (capped at the cell
    /// count). 1 forces the sequential path.
    pub threads: usize,
}

impl SweepOptions {
    /// Resolve the effective worker count for `n_cells` cells.
    pub fn effective_threads(&self, n_cells: usize) -> usize {
        let auto = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let t = if self.threads == 0 { auto } else { self.threads };
        t.min(n_cells).max(1)
    }
}

/// Run a list of cells in parallel (deterministic order and seeds; results
/// are bit-identical to [`run_cells_seq`]).
pub fn run_cells(cells: &[Cell], iters: usize, seed: u64) -> Vec<CellResult> {
    run_cells_with(cells, iters, seed, SweepOptions::default())
}

/// Run a list of cells sequentially (the pre-parallel reference path, kept
/// for determinism checks and speedup baselines).
pub fn run_cells_seq(cells: &[Cell], iters: usize, seed: u64) -> Vec<CellResult> {
    cells
        .iter()
        .map(|&cell| CellResult {
            cell,
            result: run_experiment(&cell_config(cell, iters, seed)),
        })
        .collect()
}

/// Run a list of cells across a work-stealing thread pool. Each worker
/// repeatedly claims the next unclaimed cell index from a shared atomic
/// cursor, so long cells (e.g. Qwen3's 48-layer plans) never convoy short
/// ones. Output order matches the input cell order.
pub fn run_cells_with(
    cells: &[Cell],
    iters: usize,
    seed: u64,
    opts: SweepOptions,
) -> Vec<CellResult> {
    let threads = opts.effective_threads(cells.len());
    parallel_map(cells, threads, |&cell| CellResult {
        cell,
        result: run_experiment(&cell_config(cell, iters, seed)),
    })
}

/// [`run_cells_with`] under an explicit scheduling policy: every cell of
/// the grid simulates with `sched` instead of the streaming default. Used
/// by `--sched` on the report grids and by `bench --grid sched`'s
/// per-policy throughput rows. Bit-identical to [`run_cells_with`] when
/// `sched` is [`SchedPolicy::Streaming`].
pub fn run_cells_sched(
    cells: &[Cell],
    iters: usize,
    seed: u64,
    sched: SchedPolicy,
    opts: SweepOptions,
) -> Vec<CellResult> {
    let threads = opts.effective_threads(cells.len());
    parallel_map(cells, threads, |&cell| CellResult {
        cell,
        result: run_experiment(&cell_config_sched(cell, iters, seed, sched)),
    })
}

/// Apply `f` to every item across a work-stealing pool of `threads` scoped
/// OS threads, preserving input order in the output. This is the pool behind
/// [`run_cells_with`] and the design-space explorer
/// (`coordinator::explore`): workers claim the next unclaimed index from a
/// shared atomic cursor, so long items never convoy short ones, and because
/// `f` sees only its own item the output is bit-identical to a sequential
/// `items.iter().map(f)` regardless of thread count or completion order.
///
/// With `threads <= 1` (or fewer than two items) the map runs inline on the
/// calling thread — the sequential reference path used by determinism checks.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter().map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(&items[i])));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("parallel_map worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("every index claimed exactly once"))
        .collect()
}

/// A pool of per-worker mutable states for [`parallel_map_with`], kept
/// alive by the caller so state (plan pools, scratch buffers) persists
/// across consecutive map calls — e.g. across search generations. States
/// are checked out by whichever worker asks first and returned afterwards;
/// since evaluation results never depend on which pooled state served them
/// (re-timed and fresh builds are bit-identical), this reassignment is
/// invisible in every reported number.
#[derive(Debug, Default)]
pub struct StatePool<S> {
    states: std::sync::Mutex<Vec<S>>,
}

impl<S> StatePool<S> {
    /// An empty pool; states are created lazily by `init` inside
    /// [`parallel_map_with`].
    pub fn new() -> StatePool<S> {
        StatePool {
            states: std::sync::Mutex::new(Vec::new()),
        }
    }

    fn checkout(&self, init: impl FnOnce() -> S) -> S {
        self.states
            .lock()
            .expect("state pool poisoned")
            .pop()
            .unwrap_or_else(init)
    }

    fn restore(&self, state: S) {
        self.states.lock().expect("state pool poisoned").push(state);
    }

    /// Drain the pooled states (e.g. to aggregate per-worker counters).
    pub fn drain(&self) -> Vec<S> {
        std::mem::take(&mut *self.states.lock().expect("state pool poisoned"))
    }
}

/// [`parallel_map`] with a per-worker mutable state threaded through `f`.
/// Each worker checks one state out of `pool` (creating it with `init` on
/// first use) and returns it when the map finishes, so a pool owned by the
/// caller carries worker state across calls. Scheduling, ordering, and the
/// `threads <= 1` inline path match [`parallel_map`] exactly.
pub fn parallel_map_with<T, R, S, F, I>(
    items: &[T],
    threads: usize,
    pool: &StatePool<S>,
    init: I,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    F: Fn(&mut S, &T) -> R + Sync,
    I: Fn() -> S + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        let mut state = pool.checkout(&init);
        let out = items.iter().map(|it| f(&mut state, it)).collect();
        pool.restore(state);
        return out;
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut state = pool.checkout(&init);
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(&mut state, &items[i])));
                    }
                    pool.restore(state);
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("parallel_map_with worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("every index claimed exactly once"))
        .collect()
}

/// The Table 3 / Figure 6(a) grid: 3 models x 4 methods at seq 256, HBM2.
pub fn table3_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for model in ModelId::PAPER_MODELS {
        for method in Method::ALL {
            v.push(Cell {
                model,
                method,
                seq_len: 256,
                dram: DramKind::Hbm2,
            });
        }
    }
    v
}

/// Figure 6(b): sequence-length sweep on Qwen3 / HBM2.
pub fn fig6b_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for seq_len in [128, 256, 512] {
        for method in Method::ALL {
            v.push(Cell {
                model: ModelId::Qwen3_30B_A3B,
                method,
                seq_len,
                dram: DramKind::Hbm2,
            });
        }
    }
    v
}

/// Figure 6(c): DRAM sweep on Qwen3 / seq 256.
pub fn fig6c_cells() -> Vec<Cell> {
    let mut v = Vec::new();
    for dram in [DramKind::Hbm2, DramKind::Ssd] {
        for method in Method::ALL {
            v.push(Cell {
                model: ModelId::Qwen3_30B_A3B,
                method,
                seq_len: 256,
                dram,
            });
        }
    }
    v
}

/// Appendix Figures 7/8/9: the full grid at one sequence length.
pub fn appendix_cells(seq_len: usize) -> Vec<Cell> {
    let mut v = Vec::new();
    for model in ModelId::PAPER_MODELS {
        for dram in [DramKind::Hbm2, DramKind::Ssd] {
            for method in Method::ALL {
                v.push(Cell {
                    model,
                    method,
                    seq_len,
                    dram,
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_expected_sizes() {
        assert_eq!(table3_cells().len(), 12);
        assert_eq!(fig6b_cells().len(), 12);
        assert_eq!(fig6c_cells().len(), 8);
        assert_eq!(appendix_cells(128).len(), 24);
    }

    #[test]
    fn cell_config_applies_spec() {
        let cell = Cell {
            model: ModelId::DeepSeekMoE_16B,
            method: Method::MozartB,
            seq_len: 512,
            dram: DramKind::Ssd,
        };
        let cfg = cell_config(cell, 3, 42);
        assert_eq!(cfg.seq_len, 512);
        assert_eq!(cfg.iters, 3);
        assert_eq!(cfg.model.id, ModelId::DeepSeekMoE_16B);
        assert_eq!(cfg.hw.mem.dram, DramKind::Ssd);
        assert!(cfg.method.efficient_a2a && !cfg.method.expert_layout);
    }

    #[test]
    fn run_small_grid() {
        // a 2-cell smoke of the sweep machinery at tiny workload
        let cells = vec![
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::Baseline,
                seq_len: 128,
                dram: DramKind::Hbm2,
            },
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::MozartC,
                seq_len: 128,
                dram: DramKind::Hbm2,
            },
        ];
        let res = run_cells(&cells, 1, 7);
        assert_eq!(res.len(), 2);
        assert!(res[1].result.latency < res[0].result.latency);
    }

    #[test]
    fn parallel_map_matches_sequential_and_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let seq = parallel_map(&items, 1, |&x| x * x);
        let par = parallel_map(&items, 7, |&x| x * x);
        assert_eq!(seq, par);
        assert_eq!(par[10], 100);
        // degenerate shapes
        assert_eq!(parallel_map::<u64, u64, _>(&[], 4, |&x| x), Vec::<u64>::new());
        assert_eq!(parallel_map(&[3u64], 4, |&x| x + 1), vec![4]);
    }

    #[test]
    fn parallel_map_with_threads_state_and_reuses_it_across_calls() {
        let items: Vec<u64> = (0..50).collect();
        let pool: StatePool<u64> = StatePool::new();
        // state is a per-worker counter; results must not depend on it
        let par = parallel_map_with(&items, 4, &pool, || 0u64, |s, &x| {
            *s += 1;
            x * 3
        });
        assert_eq!(par, items.iter().map(|&x| x * 3).collect::<Vec<_>>());
        let states = pool.drain();
        assert!(!states.is_empty() && states.len() <= 4);
        assert_eq!(states.iter().sum::<u64>(), 50, "every item counted once");

        // sequential path checks a state out of the same pool and restores it
        let pool: StatePool<u64> = StatePool::new();
        let a = parallel_map_with(&items[..3], 1, &pool, || 100u64, |s, &x| {
            *s += 1;
            x
        });
        assert_eq!(a, vec![0, 1, 2]);
        let b = parallel_map_with(&items[..2], 1, &pool, || 0u64, |s, &x| {
            *s += 1;
            x
        });
        assert_eq!(b, vec![0, 1]);
        // the second call reused the first call's state (init never re-ran)
        assert_eq!(pool.drain(), vec![105]);
    }

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(SweepOptions { threads: 1 }.effective_threads(24), 1);
        assert_eq!(SweepOptions { threads: 8 }.effective_threads(3), 3);
        assert!(SweepOptions { threads: 0 }.effective_threads(24) >= 1);
        assert_eq!(SweepOptions { threads: 0 }.effective_threads(0), 1);
    }

    #[test]
    fn parallel_matches_sequential_small_grid() {
        // bit-identical results regardless of worker count / claim order
        // (the full Table 3 grid is covered in tests/integration_sweep.rs)
        let cells = vec![
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::Baseline,
                seq_len: 64,
                dram: DramKind::Hbm2,
            },
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::MozartB,
                seq_len: 64,
                dram: DramKind::Ssd,
            },
            Cell {
                model: ModelId::OlmoE_1B_7B,
                method: Method::MozartC,
                seq_len: 64,
                dram: DramKind::Hbm2,
            },
        ];
        let seq = run_cells_seq(&cells, 1, 11);
        let par = run_cells_with(&cells, 1, 11, SweepOptions { threads: 3 });
        assert_eq!(seq.len(), par.len());
        for (s, p) in seq.iter().zip(par.iter()) {
            assert_eq!(s.cell.model, p.cell.model);
            assert_eq!(s.cell.method, p.cell.method);
            assert_eq!(s.result.latency, p.result.latency);
            assert_eq!(s.result.c_t, p.result.c_t);
            assert_eq!(s.result.tag_busy, p.result.tag_busy);
            assert_eq!(s.result.critical, p.result.critical);
        }
    }
}
