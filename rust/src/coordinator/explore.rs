//! Hardware design-space exploration (`mozart explore`) — the co-design
//! loop the paper motivates but fixes at one platform point.
//!
//! `HwConfig` is fully parameterized (tiles per chiplet, NoP link bandwidth,
//! DRAM technology and stack counts, hybrid-bonding links, clock), yet the
//! report generators only evaluate the paper's Table 2 configurations. The
//! explorer turns the simulator into a search tool: a declarative [`Axis`]
//! grid is expanded into hardware variants (each validated by
//! `HwConfig::validate`), every (variant × model × method) cell runs through
//! the same work-stealing pool as the paper sweeps ([`parallel_map`]), and
//! the results are reduced to the Pareto frontier over three minimized
//! objectives:
//!
//! - **iteration time** (s/step, from the discrete-event simulator),
//! - **energy per iteration** (J/step, from `metrics::energy`),
//! - **die area** (mm², from the `arch::area` 28nm analytic model).
//!
//! The paper's own configuration is always evaluated as variant 0 ("paper
//! (Table 2)"), so every report states where Table 2 lands relative to the
//! discovered frontier. Determinism mirrors the sweep executor: each cell
//! derives all randomness from its own config, so results are bit-identical
//! between sequential and parallel execution (asserted in
//! `tests/integration_explore.rs`).

use crate::arch::area::hw_metrics;
use crate::config::{
    DramKind, ExperimentConfig, HwConfig, HwOverride, KnobId, Method, ModelConfig, ModelId,
    SchedPolicy,
};
use crate::coordinator::cache::{EvalCtx, EvalOptions, EvalSession, EvalStats};
use crate::coordinator::sweep::{parallel_map_with, SweepOptions};
use crate::metrics::pareto;
use crate::util::json::Json;
use crate::util::table::{scatter_plot, Table};

/// One exploration axis: a named design dimension and its candidate values.
#[derive(Clone, Debug)]
pub struct Axis {
    /// Stable axis name (one of [`Axis::KNOWN`]).
    pub name: String,
    /// Candidate overrides along this axis, in evaluation order.
    pub values: Vec<HwOverride>,
}

impl Axis {
    /// Hardware axis names `parse_axes` accepts. Calibration-knob
    /// sensitivity axes are declared separately as `knob=name:lo:hi`
    /// (see [`parse_axes`]) and are named after the knob itself.
    pub const KNOWN: [&str; 6] =
        ["tiles", "nop_bw", "dram", "group_stacks", "hb_links", "freq"];

    /// A known axis with its default candidate values, spanning the design
    /// ranges the paper discusses (tiles 36-100, Table 2's NoP/HB points
    /// bracketed by a half and a 2-4x step, HBM2 vs SSD, 0.8-1.2 GHz).
    pub fn by_name(name: &str) -> Option<Axis> {
        let values: Vec<HwOverride> = match name {
            "tiles" => [36usize, 49, 64, 81, 100]
                .iter()
                .map(|&t| HwOverride::MoeTiles(t))
                .collect(),
            "nop_bw" => [0.0625f64, 0.125, 0.25, 0.5]
                .iter()
                .map(|&b| HwOverride::NopLinkBw(b))
                .collect(),
            "dram" => vec![
                HwOverride::Dram(DramKind::Hbm2),
                HwOverride::Dram(DramKind::Ssd),
            ],
            "group_stacks" => [2usize, 4, 8]
                .iter()
                .map(|&s| HwOverride::GroupDramStacks(s))
                .collect(),
            "hb_links" => [51_200usize, 102_400, 204_800]
                .iter()
                .map(|&h| HwOverride::HbLinks(h))
                .collect(),
            "freq" => [0.8f64, 1.0, 1.2]
                .iter()
                .map(|&f| HwOverride::FreqGhz(f))
                .collect(),
            _ => return None,
        };
        Some(Axis {
            name: name.to_string(),
            values,
        })
    }
}

/// Parse one axis value (`tiles` -> integer, `dram` -> `hbm2|ssd`, ...).
/// Values are range-checked here so a bad `--axes` spec is a parse error,
/// not a `HwConfig::validate` panic inside a worker thread.
fn parse_value(axis: &str, s: &str) -> Result<HwOverride, String> {
    let bad = |what: &str| format!("axis `{axis}`: invalid {what} value `{s}`");
    let uint = |what: &'static str| -> Result<usize, String> {
        match s.parse::<usize>() {
            Ok(v) if v > 0 => Ok(v),
            _ => Err(bad(what)),
        }
    };
    let rate = |what: &'static str| -> Result<f64, String> {
        match s.parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => Ok(v),
            _ => Err(bad(what)),
        }
    };
    match axis {
        "tiles" => uint("positive integer").map(HwOverride::MoeTiles),
        "nop_bw" => rate("positive number").map(HwOverride::NopLinkBw),
        "dram" => DramKind::from_name(s)
            .map(HwOverride::Dram)
            .ok_or_else(|| bad("dram kind (hbm2|ssd)")),
        "group_stacks" => uint("positive integer").map(HwOverride::GroupDramStacks),
        "hb_links" => uint("positive integer").map(HwOverride::HbLinks),
        "freq" => rate("positive number").map(HwOverride::FreqGhz),
        _ => Err(format!("unknown axis `{axis}`")),
    }
}

/// Number of evenly spaced values a `knob=name:lo:hi` range expands into.
const KNOB_LINSPACE_STEPS: usize = 5;

/// Parse a calibration-knob sensitivity axis: `name:lo:hi` (a
/// [`KNOB_LINSPACE_STEPS`]-point linear sweep from `lo` to `hi` inclusive)
/// or `name:v1:v2:...:vk` with `k != 2` explicit values. Values are checked
/// against the knob's physical range ([`KnobId::in_range`]) so a bad spec
/// fails at parse time, not as a `HwConfig::validate` panic in a worker.
fn parse_knob_axis(spec: &str) -> Result<Axis, String> {
    let mut parts = spec.split(':');
    let name = parts.next().unwrap_or("").trim();
    let id = KnobId::from_name(name).ok_or_else(|| {
        format!(
            "unknown knob `{name}` (known: {})",
            KnobId::ALL.map(|k| k.name()).join(", ")
        )
    })?;
    let nums: Vec<f64> = parts
        .map(|s| {
            let s = s.trim();
            match s.parse::<f64>() {
                Ok(v) if v.is_finite() => Ok(v),
                _ => Err(format!("knob `{name}`: invalid value `{s}`")),
            }
        })
        .collect::<Result<Vec<_>, String>>()?;
    if nums.is_empty() {
        return Err(format!(
            "knob `{name}` needs a range (`knob={name}:lo:hi`) or explicit values"
        ));
    }
    let values: Vec<f64> = if nums.len() == 2 {
        let (lo, hi) = (nums[0], nums[1]);
        if hi < lo {
            return Err(format!("knob `{name}`: range {lo}:{hi} has hi < lo"));
        }
        if hi == lo {
            vec![lo]
        } else {
            (0..KNOB_LINSPACE_STEPS)
                .map(|i| lo + (hi - lo) * i as f64 / (KNOB_LINSPACE_STEPS - 1) as f64)
                .collect()
        }
    } else {
        nums
    };
    for &v in &values {
        if !id.in_range(v) {
            return Err(format!(
                "knob `{name}`: value {v} is outside the knob's valid range"
            ));
        }
    }
    Ok(Axis {
        name: id.name().to_string(),
        values: values.into_iter().map(|v| HwOverride::Knob(id, v)).collect(),
    })
}

/// Parse a `--axes` specification: a comma-separated list of axis names,
/// each optionally carrying explicit values after `=`, colon-separated
/// (e.g. `tiles,nop_bw,dram` or `tiles=36:64:100,dram=ssd`). A part of the
/// form `knob=name:lo:hi` declares a calibration-knob sensitivity axis (a
/// 5-point linear sweep of that knob; pass more than two numbers for
/// explicit values). Unlisted axes stay at the base platform's value.
pub fn parse_axes(spec: &str) -> Result<Vec<Axis>, String> {
    let mut out: Vec<Axis> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, values) = match part.split_once('=') {
            None => (part, None),
            Some((n, v)) => (n.trim(), Some(v)),
        };
        if name == "knob" {
            let vals = values.ok_or_else(|| {
                "axis `knob` needs a spec: `knob=name:lo:hi`".to_string()
            })?;
            let axis = parse_knob_axis(vals)?;
            if out.iter().any(|a| a.name == axis.name) {
                return Err(format!("duplicate axis `{}`", axis.name));
            }
            out.push(axis);
            continue;
        }
        let mut axis = Axis::by_name(name).ok_or_else(|| {
            format!("unknown axis `{name}` (known: {})", Axis::KNOWN.join(", "))
        })?;
        if let Some(vals) = values {
            axis.values = vals
                .split(':')
                .map(|s| parse_value(name, s.trim()))
                .collect::<Result<Vec<_>, String>>()?;
            if axis.values.is_empty() {
                return Err(format!("axis `{name}` has no values"));
            }
        }
        if out.iter().any(|a| a.name == axis.name) {
            return Err(format!("duplicate axis `{}`", axis.name));
        }
        out.push(axis);
    }
    if out.is_empty() {
        return Err("no axes given".to_string());
    }
    Ok(out)
}

/// All grid genomes — one value index per axis, first axis fastest-varying
/// (least-significant mixed-radix digit) — with the deterministic
/// even-stride `budget` subsample. The single source of the grid order and
/// stride, shared by [`expand_grid`] and the guided search's exhaustive
/// strategy (`coordinator::search`) so the two can never diverge.
pub(crate) fn grid_genomes(axes: &[Axis], budget: usize) -> Vec<Vec<usize>> {
    let total: usize = axes.iter().map(|a| a.values.len()).product();
    // mixed-radix decode of one combination index, so the budgeted case
    // never materializes the full product
    let genome_at = |mut idx: usize| -> Vec<usize> {
        axes.iter()
            .map(|a| {
                let v = idx % a.values.len();
                idx /= a.values.len();
                v
            })
            .collect()
    };
    if budget > 0 && total > budget {
        (0..budget).map(|i| genome_at(i * total / budget)).collect()
    } else {
        (0..total).map(genome_at).collect()
    }
}

/// Expand the axis grid into the cartesian product of override combinations
/// (first axis fastest-varying). When `budget > 0` caps the grid below its
/// full size, an even-stride deterministic subsample keeps coverage spread
/// across the whole product instead of truncating to a corner.
pub fn expand_grid(axes: &[Axis], budget: usize) -> Vec<Vec<HwOverride>> {
    grid_genomes(axes, budget)
        .into_iter()
        .map(|g| axes.iter().zip(g).map(|(a, i)| a.values[i]).collect())
        .collect()
}

/// Full specification of one exploration run.
#[derive(Clone, Debug)]
pub struct ExploreConfig {
    /// The design axes to sweep.
    pub axes: Vec<Axis>,
    /// Maximum number of grid variants to evaluate (0 = the full product);
    /// the paper anchor is always evaluated on top of the budget.
    pub budget: usize,
    /// Models to evaluate each variant on.
    pub models: Vec<ModelId>,
    /// Optimization methods to evaluate each variant with.
    pub methods: Vec<Method>,
    /// DAG scheduling policies to evaluate each variant under. The first
    /// entry is the reference policy: the paper-anchor verdicts and the
    /// schedule-frontier comparisons are relative to it. With more than one
    /// entry the report gains a per-(model, method) schedule frontier.
    pub scheds: Vec<SchedPolicy>,
    /// Sequence length per sample.
    pub seq_len: usize,
    /// Base DRAM technology (overridden by a `dram` axis value, if present).
    pub dram: DramKind,
    /// Simulated training iterations to average per cell.
    pub iters: usize,
    /// RNG seed shared by all cells (each cell forks from its own config).
    pub seed: u64,
    /// Worker threads; 0 = one per available core, 1 = sequential.
    pub threads: usize,
    /// Evaluation-reuse toggles (cell memoization, delta re-timing, cache
    /// persistence). Both reuse layers are bit-transparent, so these only
    /// affect throughput, never a reported number.
    pub eval: EvalOptions,
}

impl ExploreConfig {
    /// The default exploration: tiles × NoP bandwidth × DRAM kind around the
    /// paper's Qwen3 / Mozart-C operating point, full grid within a
    /// 64-variant budget.
    pub fn paper_default() -> ExploreConfig {
        let axes = parse_axes("tiles,nop_bw,dram").expect("default axes parse");
        ExploreConfig {
            axes,
            budget: 64,
            models: vec![ModelId::Qwen3_30B_A3B],
            methods: vec![Method::MozartC],
            scheds: vec![SchedPolicy::Streaming],
            seq_len: 256,
            dram: DramKind::Hbm2,
            iters: 2,
            seed: 7,
            threads: 0,
            eval: EvalOptions::default(),
        }
    }
}

/// One hardware variant of the exploration grid.
#[derive(Clone, Debug)]
pub struct Variant {
    /// Overrides applied on top of the per-model paper platform; empty for
    /// the paper anchor (variant 0).
    pub overrides: Vec<HwOverride>,
    /// Display label (`"paper (Table 2)"` or `"tiles=36 dram=SSD"` style).
    pub label: String,
}

/// One evaluated (variant × model × method) cell with its objectives.
#[derive(Clone, Debug)]
pub struct ExplorePoint {
    /// Index into [`ExploreOutcome::variants`].
    pub variant: usize,
    /// Model this cell simulated.
    pub model: ModelId,
    /// Method this cell simulated.
    pub method: Method,
    /// DAG scheduling policy the simulator dispatched this cell with.
    pub sched: SchedPolicy,
    /// Mean end-to-end latency per training step (seconds) — minimized.
    pub latency_s: f64,
    /// Mean energy per training step (Joules) — minimized.
    pub energy_j: f64,
    /// Total platform die area (mm², `arch::area` model) — minimized.
    pub area_mm2: f64,
    /// Typical platform power (kW, `arch::area` model) — reported only.
    pub power_kw: f64,
    /// Simulated mean power over the step (W, total step energy over the
    /// makespan; `metrics::energy::EnergyBreakdown::mean_power_w`) — the
    /// per-configuration draw the search's `--max-power` budget caps.
    pub mean_power_w: f64,
    /// Mean all-to-all replication factor — reported only.
    pub c_t: f64,
    /// Retained throughput fraction (healthy latency / faulted latency)
    /// under the search's `--min-resilience` fault scenario; `None` when no
    /// resilience evaluation ran (the plain grid explorer never sets it).
    pub retained: Option<f64>,
    /// Serving scores (p99 latency, SLO-goodput) when a serving workload
    /// was evaluated — set by searches with `--objective p99|goodput`;
    /// `None` otherwise (the plain grid explorer never sets it).
    pub serve: Option<crate::coordinator::serve::ServeMetrics>,
}

impl ExplorePoint {
    /// The minimized objective vector (latency, energy, area) fed to the
    /// Pareto analysis.
    pub fn objectives(&self) -> Vec<f64> {
        vec![self.latency_s, self.energy_j, self.area_mm2]
    }
}

/// Pareto analysis of one (model, method) slice of the evaluated points.
#[derive(Clone, Debug)]
pub struct Frontier {
    /// Model of this slice.
    pub model: ModelId,
    /// Method of this slice.
    pub method: Method,
    /// All point indices (into [`ExploreOutcome::points`]) of the slice.
    pub points: Vec<usize>,
    /// Non-dominated point indices (subset of `points`).
    pub members: Vec<usize>,
    /// Index of the paper-anchor point (variant 0) in this slice.
    pub paper_point: usize,
    /// Point indices dominating the paper anchor; empty iff the paper's
    /// Table 2 configuration is itself on the frontier.
    pub paper_dominators: Vec<usize>,
}

/// One (model, method) slice of the schedule frontier: for every hardware
/// variant of the slice, the step latency under each evaluated scheduling
/// policy, and the winning (lowest-latency) policy. This is the per-platform
/// "which schedule should this design point run?" view the multi-`--scheds`
/// explorer reports.
#[derive(Clone, Debug)]
pub struct SchedFrontier {
    /// Model of this slice.
    pub model: ModelId,
    /// Method of this slice.
    pub method: Method,
    /// One row per evaluated variant, ascending variant index.
    pub rows: Vec<SchedRow>,
}

/// One hardware variant's row of a [`SchedFrontier`].
#[derive(Clone, Debug)]
pub struct SchedRow {
    /// Index into [`ExploreOutcome::variants`].
    pub variant: usize,
    /// Step latency (seconds) under each policy, parallel to
    /// [`ExploreConfig::scheds`].
    pub latency_by_sched: Vec<f64>,
    /// Index (into [`ExploreConfig::scheds`]) of the lowest-latency policy;
    /// exact ties break to the earlier list position ([`pareto::argmin`]).
    pub best: usize,
}

/// Everything one exploration run produced.
#[derive(Clone, Debug)]
pub struct ExploreOutcome {
    /// The configuration the run used.
    pub cfg: ExploreConfig,
    /// Evaluated hardware variants (variant 0 is the paper anchor).
    pub variants: Vec<Variant>,
    /// Every evaluated (variant × model × method) cell.
    pub points: Vec<ExplorePoint>,
    /// One Pareto analysis per (model, method) pair.
    pub frontiers: Vec<Frontier>,
    /// Cache / re-timing accounting of the run (the artifact's `cache`
    /// section). Never affects a reported number.
    pub eval: EvalStats,
}

/// True iff every override in `combo` is a no-op against `base` — i.e. the
/// combo re-describes the paper anchor. Such grid points are skipped so the
/// anchor is never simulated (and reported) twice. Shared with the guided
/// search strategies (`coordinator::search`), which apply the same skip.
pub(crate) fn is_anchor_combo(combo: &[HwOverride], base: &HwConfig) -> bool {
    combo.iter().all(|ov| match *ov {
        HwOverride::MoeTiles(v) => v == base.moe_chiplet.tiles,
        HwOverride::NopLinkBw(v) => v == base.nop.link_bw_gbps,
        HwOverride::Dram(d) => d == base.mem.dram,
        HwOverride::GroupDramStacks(v) => v == base.mem.group_dram_stacks,
        HwOverride::HbLinks(v) => v == base.mem.hb_links,
        HwOverride::FreqGhz(v) => v == base.freq_ghz,
        HwOverride::Knob(id, v) => v == id.get(&base.knobs),
    })
}

/// Evaluate one cell: simulate the overridden platform and attach the area
/// model's objectives. This is the single cell-evaluation path shared by
/// [`explore`] and the guided search strategies (`coordinator::search`);
/// `vi` is recorded as the point's variant/candidate index. With a `fault`
/// scenario (the search's `--min-resilience`), the cell is simulated a
/// second time under the injected faults and the retained-throughput
/// fraction (healthy latency / faulted latency) is attached.
///
/// Both runs flow through `ctx` (cell cache + worker plan pool): the
/// healthy result is memoized independently of the fault evaluation, so a
/// `--min-resilience` search never re-simulates a healthy cell it already
/// knows, and — because a bandwidth-degrading fault shares the healthy
/// topology — the faulted run re-times the healthy plan instead of
/// rebuilding it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_point(
    cfg: &ExploreConfig,
    overrides: &[HwOverride],
    vi: usize,
    model: ModelId,
    method: Method,
    sched: SchedPolicy,
    fault: Option<&crate::comm::FaultScenario>,
    serve: Option<&crate::coordinator::serve::ServeEvalSpec>,
    ctx: &mut EvalCtx<'_>,
) -> ExplorePoint {
    let model_cfg = ModelConfig::preset(model);
    let mut ec = ExperimentConfig::paper_default(model_cfg, method.config());
    ec.hw = HwConfig::paper_for_model(model, cfg.dram).with_overrides(overrides);
    ec.seq_len = cfg.seq_len;
    ec.iters = cfg.iters;
    ec.seed = cfg.seed;
    ec.sched = sched;
    let r = ctx.run(&ec);
    let retained = fault.map(|scenario| {
        let mut fc = ec.clone();
        fc.fault = scenario.clone();
        r.latency / ctx.run(&fc).latency
    });
    let serve = serve.map(|spec| {
        crate::coordinator::serve::serve_cell_eval(|c| ctx.run(c).latency, &ec, spec)
    });
    let m = hw_metrics(&ec.model, &ec.hw);
    ExplorePoint {
        variant: vi,
        model,
        method,
        sched,
        latency_s: r.latency,
        energy_j: r.energy.total_j(),
        area_mm2: m.total_area_mm2,
        power_kw: m.total_power_kw,
        mean_power_w: r.energy.mean_power_w(r.latency),
        c_t: r.c_t,
        retained,
        serve,
    }
}

/// Run the exploration: expand the grid, evaluate every cell across the
/// work-stealing pool, and compute the Pareto frontiers. Deterministic for a
/// fixed config regardless of `threads`.
///
/// # Examples
///
/// ```
/// use mozart::config::{DramKind, HwOverride, Method, ModelId, SchedPolicy};
/// use mozart::coordinator::explore::{explore, Axis, ExploreConfig};
///
/// // one tiny axis at a reduced workload, sequentially
/// let cfg = ExploreConfig {
///     axes: vec![Axis {
///         name: "tiles".to_string(),
///         values: vec![HwOverride::MoeTiles(36)],
///     }],
///     budget: 0,
///     models: vec![ModelId::OlmoE_1B_7B],
///     methods: vec![Method::MozartC],
///     scheds: vec![SchedPolicy::Streaming],
///     seq_len: 64,
///     dram: DramKind::Hbm2,
///     iters: 1,
///     seed: 7,
///     threads: 1,
///     eval: mozart::coordinator::cache::EvalOptions::default(),
/// };
/// let out = explore(&cfg);
/// assert_eq!(out.points.len(), 2); // the paper anchor + the tiles=36 variant
/// assert!(!out.frontiers[0].members.is_empty());
/// ```
pub fn explore(cfg: &ExploreConfig) -> ExploreOutcome {
    let mut variants = vec![Variant {
        overrides: Vec::new(),
        label: "paper (Table 2)".to_string(),
    }];
    // per-model base platforms, for anchor-duplicate elimination (a combo
    // that is a no-op for EVERY evaluated model re-describes variant 0)
    let bases: Vec<HwConfig> = cfg
        .models
        .iter()
        .map(|&m| HwConfig::paper_for_model(m, cfg.dram))
        .collect();
    for combo in expand_grid(&cfg.axes, cfg.budget) {
        if bases.iter().all(|b| is_anchor_combo(&combo, b)) {
            continue;
        }
        let label = combo
            .iter()
            .map(|o| o.label())
            .collect::<Vec<_>>()
            .join(" ");
        variants.push(Variant {
            overrides: combo,
            label,
        });
    }

    let mut specs: Vec<(usize, ModelId, Method, SchedPolicy)> = Vec::new();
    for vi in 0..variants.len() {
        for (mi, &model) in cfg.models.iter().enumerate() {
            // in a multi-model explore a combo may survive the global skip
            // above yet still equal THIS model's anchor — drop that cell
            // rather than simulate variant 0 twice in one slice
            if vi != 0 && is_anchor_combo(&variants[vi].overrides, &bases[mi]) {
                continue;
            }
            for &method in &cfg.methods {
                for &sched in &cfg.scheds {
                    specs.push((vi, model, method, sched));
                }
            }
        }
    }
    let threads = SweepOptions {
        threads: cfg.threads,
    }
    .effective_threads(specs.len());
    let session = EvalSession::new(cfg.eval.clone());
    let points = parallel_map_with(
        &specs,
        threads,
        session.pools(),
        || session.new_pool(),
        |pool, &(vi, model, method, sched)| {
            let mut ctx = session.ctx(pool);
            eval_point(
                cfg,
                &variants[vi].overrides,
                vi,
                model,
                method,
                sched,
                None,
                None,
                &mut ctx,
            )
        },
    );

    let mut frontiers = Vec::new();
    for &model in &cfg.models {
        for &method in &cfg.methods {
            let idxs: Vec<usize> = points
                .iter()
                .enumerate()
                .filter(|(_, p)| p.model == model && p.method == method)
                .map(|(i, _)| i)
                .collect();
            let objs: Vec<Vec<f64>> = idxs.iter().map(|&i| points[i].objectives()).collect();
            let members: Vec<usize> = pareto::pareto_frontier(&objs)
                .into_iter()
                .map(|k| idxs[k])
                .collect();
            // the anchor is variant 0 under the reference (first) policy —
            // with several scheds, variant 0 appears once per policy
            let paper_point = idxs
                .iter()
                .copied()
                .find(|&i| points[i].variant == 0 && points[i].sched == cfg.scheds[0])
                .expect("paper anchor is always evaluated");
            let paper_obj = points[paper_point].objectives();
            let paper_dominators: Vec<usize> = pareto::dominators(&paper_obj, &objs)
                .into_iter()
                .map(|k| idxs[k])
                .collect();
            frontiers.push(Frontier {
                model,
                method,
                points: idxs,
                members,
                paper_point,
                paper_dominators,
            });
        }
    }

    ExploreOutcome {
        cfg: cfg.clone(),
        variants,
        points,
        frontiers,
        eval: session.finish(),
    }
}

impl ExploreOutcome {
    /// The per-(model, method) schedule frontier: for each variant of the
    /// slice, its latency under every evaluated policy and the argmin
    /// winner. Rows are ascending by variant index; one frontier per entry
    /// of [`ExploreOutcome::frontiers`], in the same order. With a single
    /// `--sched` the rows are trivial (one column, winner 0) but still
    /// well-formed, so artifact consumers need no special case.
    pub fn sched_frontiers(&self) -> Vec<SchedFrontier> {
        let ns = self.cfg.scheds.len();
        self.frontiers
            .iter()
            .map(|f| {
                let mut rows: Vec<SchedRow> = Vec::new();
                for &i in &f.points {
                    let p = &self.points[i];
                    let si = self
                        .cfg
                        .scheds
                        .iter()
                        .position(|&s| s == p.sched)
                        .expect("every point's policy is one of cfg.scheds");
                    let row = match rows.iter_mut().find(|r| r.variant == p.variant) {
                        Some(r) => r,
                        None => {
                            rows.push(SchedRow {
                                variant: p.variant,
                                latency_by_sched: vec![f64::NAN; ns],
                                best: 0,
                            });
                            rows.last_mut().expect("just pushed")
                        }
                    };
                    row.latency_by_sched[si] = p.latency_s;
                }
                rows.sort_by_key(|r| r.variant);
                for r in &mut rows {
                    r.best = pareto::argmin(&r.latency_by_sched)
                        .expect("cfg.scheds is never empty");
                }
                SchedFrontier {
                    model: f.model,
                    method: f.method,
                    rows,
                }
            })
            .collect()
    }

    fn render_sched_frontier(&self, sf: &SchedFrontier) -> String {
        let title = format!(
            "Schedule frontier — {} / {}",
            sf.model.name(),
            sf.method.name()
        );
        let mut cols: Vec<String> = vec!["Variant".to_string()];
        cols.extend(self.cfg.scheds.iter().map(|s| format!("{} (s)", s.name())));
        cols.push("Best".to_string());
        let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&title, &col_refs);
        let mut wins = vec![0usize; self.cfg.scheds.len()];
        for r in &sf.rows {
            wins[r.best] += 1;
            let mut cells = vec![self.variants[r.variant].label.clone()];
            cells.extend(r.latency_by_sched.iter().map(|l| format!("{l:.4}")));
            cells.push(self.cfg.scheds[r.best].name().to_string());
            t.row(&cells);
        }
        let mut s = t.render();
        let tally = self
            .cfg
            .scheds
            .iter()
            .zip(&wins)
            .map(|(p, w)| format!("{} x{}", p.name(), w))
            .collect::<Vec<_>>()
            .join(", ");
        s.push_str(&format!(
            "=> winning policy per variant (exact latency ties break to the \
             earlier --scheds entry): {tally}.\n"
        ));
        s
    }

    /// Rendered markdown report: axis summary, one frontier table + ASCII
    /// latency/energy scatter per (model, method), and the Q3-style verdict
    /// on where the paper's Table 2 configuration lands. With more than one
    /// scheduling policy, a per-(model, method) schedule-frontier table
    /// follows the Pareto sections.
    pub fn render_markdown(&self) -> String {
        let mut t = Table::new("Design-space axes", &["Axis", "Values"]);
        for a in &self.cfg.axes {
            t.row(&[
                a.name.clone(),
                a.values
                    .iter()
                    .map(|v| v.value_label())
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "({} variants incl. the paper anchor; {} cells; budget {})\n\n",
            self.variants.len(),
            self.points.len(),
            self.cfg.budget
        ));
        if self.cfg.scheds.len() > 1 {
            out.push_str(&format!(
                "(schedulers: {})\n\n",
                self.cfg
                    .scheds
                    .iter()
                    .map(|s| s.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        for f in &self.frontiers {
            out.push_str(&self.render_frontier(f));
            out.push('\n');
        }
        if self.cfg.scheds.len() > 1 {
            for sf in &self.sched_frontiers() {
                out.push_str(&self.render_sched_frontier(sf));
                out.push('\n');
            }
        }
        out
    }

    fn render_frontier(&self, f: &Frontier) -> String {
        let title = format!(
            "Pareto frontier — {} / {} ({} of {} points non-dominated)",
            f.model.name(),
            f.method.name(),
            f.members.len(),
            f.points.len()
        );
        let mut t = Table::new(
            &title,
            &["Variant", "Latency (s)", "Energy (J/step)", "Area (mm^2)", "C_T"],
        );
        let mut members = f.members.clone();
        members.sort_by(|&a, &b| self.points[a].latency_s.total_cmp(&self.points[b].latency_s));
        for &i in &members {
            let p = &self.points[i];
            t.row(&[
                self.variants[p.variant].label.clone(),
                format!("{:.4}", p.latency_s),
                format!("{:.1}", p.energy_j),
                format!("{:.0}", p.area_mm2),
                format!("{:.2}", p.c_t),
            ]);
        }
        let mut s = t.render();

        // scatter: all points '.', frontier '*', paper anchor 'P' (drawn
        // last so it wins overlaps)
        let mut pts: Vec<(f64, f64, char)> = Vec::new();
        for &i in &f.points {
            if !f.members.contains(&i) {
                pts.push((self.points[i].latency_s, self.points[i].energy_j, '.'));
            }
        }
        for &i in &f.members {
            pts.push((self.points[i].latency_s, self.points[i].energy_j, '*'));
        }
        let anchor = &self.points[f.paper_point];
        pts.push((anchor.latency_s, anchor.energy_j, 'P'));
        s.push('\n');
        s.push_str(&scatter_plot(
            "latency vs energy ('*' frontier, '.' dominated, 'P' paper)",
            "latency (s)",
            "energy (J/step)",
            &pts,
        ));

        if f.paper_dominators.is_empty() {
            s.push_str(
                "=> the paper's Table 2 configuration is ON the discovered frontier \
                 (no explored variant dominates it).\n",
            );
        } else {
            let best = f
                .paper_dominators
                .iter()
                .copied()
                .min_by(|&a, &b| self.points[a].latency_s.total_cmp(&self.points[b].latency_s))
                .expect("non-empty dominator set");
            let p = &self.points[best];
            s.push_str(&format!(
                "=> the paper's Table 2 configuration is dominated by {} explored \
                 variant(s); e.g. `{}`: {:+.1}% latency, {:+.1}% energy, {:+.1}% area \
                 relative to paper.\n",
                f.paper_dominators.len(),
                self.variants[p.variant].label,
                (p.latency_s / anchor.latency_s - 1.0) * 100.0,
                (p.energy_j / anchor.energy_j - 1.0) * 100.0,
                (p.area_mm2 / anchor.area_mm2 - 1.0) * 100.0,
            ));
        }
        s
    }

    /// Machine-readable artifact (`EXPLORE_*.json`).
    pub fn to_json(&self) -> Json {
        let axes = Json::Arr(
            self.cfg
                .axes
                .iter()
                .map(|a| {
                    Json::obj([
                        ("name", Json::str(a.name.clone())),
                        (
                            "values",
                            Json::Arr(
                                a.values.iter().map(|v| Json::str(v.value_label())).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let variants = Json::Arr(
            self.variants
                .iter()
                .map(|v| {
                    Json::obj([
                        ("label", Json::str(v.label.clone())),
                        (
                            "overrides",
                            Json::Obj(
                                v.overrides
                                    .iter()
                                    .map(|o| {
                                        (o.axis_name().to_string(), Json::str(o.value_label()))
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let mut on_frontier = vec![false; self.points.len()];
        for f in &self.frontiers {
            for &m in &f.members {
                on_frontier[m] = true;
            }
        }
        let points = Json::Arr(
            self.points
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    Json::obj([
                        ("variant", Json::int(p.variant)),
                        ("model", Json::str(p.model.name())),
                        ("method", Json::str(p.method.name())),
                        ("sched", Json::str(p.sched.name())),
                        ("latency_s", Json::num(p.latency_s)),
                        ("energy_j_per_step", Json::num(p.energy_j)),
                        ("area_mm2", Json::num(p.area_mm2)),
                        ("power_kw", Json::num(p.power_kw)),
                        ("mean_power_w", Json::num(p.mean_power_w)),
                        ("c_t", Json::num(p.c_t)),
                        ("on_frontier", Json::Bool(on_frontier[i])),
                    ])
                })
                .collect(),
        );
        let frontiers = Json::Arr(
            self.frontiers
                .iter()
                .map(|f| {
                    Json::obj([
                        ("model", Json::str(f.model.name())),
                        ("method", Json::str(f.method.name())),
                        (
                            "members",
                            Json::Arr(f.members.iter().map(|&m| Json::int(m)).collect()),
                        ),
                        ("paper_point", Json::int(f.paper_point)),
                        ("paper_on_frontier", Json::Bool(f.paper_dominators.is_empty())),
                        (
                            "paper_dominators",
                            Json::Arr(
                                f.paper_dominators.iter().map(|&m| Json::int(m)).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let sched_frontier = Json::Arr(
            self.sched_frontiers()
                .iter()
                .map(|sf| {
                    Json::obj([
                        ("model", Json::str(sf.model.name())),
                        ("method", Json::str(sf.method.name())),
                        (
                            "rows",
                            Json::Arr(
                                sf.rows
                                    .iter()
                                    .map(|r| {
                                        Json::obj([
                                            ("variant", Json::int(r.variant)),
                                            (
                                                "latency_by_sched",
                                                Json::Arr(
                                                    r.latency_by_sched
                                                        .iter()
                                                        .map(|&l| Json::num(l))
                                                        .collect(),
                                                ),
                                            ),
                                            (
                                                "best_sched",
                                                Json::str(
                                                    self.cfg.scheds[r.best].name(),
                                                ),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj([
            ("explore", Json::str("design_space")),
            ("axes", axes),
            ("budget", Json::int(self.cfg.budget)),
            ("seq_len", Json::int(self.cfg.seq_len)),
            ("iters", Json::int(self.cfg.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53 (same policy as BENCH_sweep.json)
            ("seed", Json::str(self.cfg.seed.to_string())),
            ("base_dram", Json::str(self.cfg.dram.name())),
            (
                "scheds",
                Json::Arr(
                    self.cfg
                        .scheds
                        .iter()
                        .map(|s| Json::str(s.name()))
                        .collect(),
                ),
            ),
            ("objectives", Json::Arr(vec![
                Json::str("latency_s"),
                Json::str("energy_j_per_step"),
                Json::str("area_mm2"),
            ])),
            ("variants", variants),
            ("points", points),
            ("frontiers", frontiers),
            ("sched_frontier", sched_frontier),
            ("cache", self.eval.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_axes_resolve_with_defaults() {
        for name in Axis::KNOWN {
            let a = Axis::by_name(name).unwrap();
            assert_eq!(a.name, name);
            assert!(!a.values.is_empty());
            for v in &a.values {
                assert_eq!(v.axis_name(), name);
            }
        }
        assert!(Axis::by_name("bogus").is_none());
    }

    #[test]
    fn parse_axes_defaults_and_explicit_values() {
        let axes = parse_axes("tiles,nop_bw,dram").unwrap();
        assert_eq!(axes.len(), 3);
        assert_eq!(axes[0].values.len(), 5);

        let axes = parse_axes("tiles=36:100, dram=ssd").unwrap();
        assert_eq!(
            axes[0].values,
            vec![HwOverride::MoeTiles(36), HwOverride::MoeTiles(100)]
        );
        assert_eq!(axes[1].values, vec![HwOverride::Dram(DramKind::Ssd)]);

        assert!(parse_axes("bogus").is_err());
        assert!(parse_axes("tiles,tiles").is_err());
        assert!(parse_axes("tiles=abc").is_err());
        assert!(parse_axes("").is_err());
        // range checks happen at parse time, not as worker-thread panics
        assert!(parse_axes("tiles=0").is_err());
        assert!(parse_axes("freq=0").is_err());
        assert!(parse_axes("nop_bw=-1").is_err());
        assert!(parse_axes("nop_bw=nan").is_err());
        assert!(parse_axes("group_stacks=0").is_err());
    }

    #[test]
    fn knob_axes_parse_ranges_and_explicit_values() {
        // `name:lo:hi` expands to a 5-point linspace
        let axes = parse_axes("tiles=36:64,knob=dram_eff:0.6:1.0").unwrap();
        assert_eq!(axes.len(), 2);
        assert_eq!(axes[1].name, "dram_eff");
        assert_eq!(
            axes[1].values,
            vec![
                HwOverride::Knob(KnobId::DramEff, 0.6),
                HwOverride::Knob(KnobId::DramEff, 0.7),
                HwOverride::Knob(KnobId::DramEff, 0.8),
                HwOverride::Knob(KnobId::DramEff, 0.9),
                HwOverride::Knob(KnobId::DramEff, 1.0),
            ]
        );
        // more than two numbers are explicit values; one number pins it
        let axes = parse_axes("knob=mxu_util:0.4:0.6:0.8").unwrap();
        assert_eq!(axes[0].values.len(), 3);
        let axes = parse_axes("knob=switch_agg_factor:2.5").unwrap();
        assert_eq!(
            axes[0].values,
            vec![HwOverride::Knob(KnobId::SwitchAggFactor, 2.5)]
        );
        // a degenerate lo == hi range collapses to one value
        let axes = parse_axes("knob=nop_eff:0.5:0.5").unwrap();
        assert_eq!(axes[0].values.len(), 1);
        // two different knobs coexist; the same knob twice is a duplicate
        assert_eq!(
            parse_axes("knob=dram_eff:0.6:0.9,knob=nop_eff:0.3:0.5")
                .unwrap()
                .len(),
            2
        );
        assert!(parse_axes("knob=dram_eff:0.6:0.9,knob=dram_eff:0.7:0.8").is_err());
        // parse-time rejection: unknown knob, missing spec, bad numbers,
        // inverted ranges, out-of-range values
        assert!(parse_axes("knob").is_err());
        assert!(parse_axes("knob=bogus:0.1:0.2").is_err());
        assert!(parse_axes("knob=dram_eff").is_err());
        assert!(parse_axes("knob=dram_eff:abc:0.9").is_err());
        assert!(parse_axes("knob=dram_eff:0.9:0.6").is_err());
        assert!(parse_axes("knob=dram_eff:0.5:1.5").is_err());
        assert!(parse_axes("knob=a2a_link_occupancy:-0.2:0.5").is_err());
    }

    #[test]
    fn knob_overrides_participate_in_anchor_detection() {
        let base = HwConfig::paper_for_model(ModelId::Qwen3_30B_A3B, DramKind::Hbm2);
        let fitted = base.knobs.dram_eff;
        assert!(is_anchor_combo(
            &[HwOverride::Knob(KnobId::DramEff, fitted)],
            &base
        ));
        assert!(!is_anchor_combo(
            &[HwOverride::Knob(KnobId::DramEff, fitted * 0.5)],
            &base
        ));
    }

    #[test]
    fn grid_expansion_is_the_cartesian_product() {
        let axes = parse_axes("tiles=36:64,dram").unwrap();
        let grid = expand_grid(&axes, 0);
        assert_eq!(grid.len(), 4);
        // first axis fastest-varying
        assert_eq!(grid[0], vec![
            HwOverride::MoeTiles(36),
            HwOverride::Dram(DramKind::Hbm2)
        ]);
        assert_eq!(grid[1][0], HwOverride::MoeTiles(64));
        assert_eq!(grid[3], vec![
            HwOverride::MoeTiles(64),
            HwOverride::Dram(DramKind::Ssd)
        ]);
    }

    #[test]
    fn anchor_duplicate_combos_are_detected() {
        let base = HwConfig::paper_for_model(ModelId::Qwen3_30B_A3B, DramKind::Hbm2);
        // the default qwen3 grid contains the exact Table 2 point
        assert!(is_anchor_combo(
            &[
                HwOverride::MoeTiles(81),
                HwOverride::NopLinkBw(0.125),
                HwOverride::Dram(DramKind::Hbm2),
            ],
            &base
        ));
        assert!(!is_anchor_combo(&[HwOverride::MoeTiles(36)], &base));
        assert!(!is_anchor_combo(
            &[HwOverride::MoeTiles(81), HwOverride::Dram(DramKind::Ssd)],
            &base
        ));
        // the empty combo is definitionally the anchor
        assert!(is_anchor_combo(&[], &base));
    }

    #[test]
    fn grid_genomes_are_the_index_form_of_expand_grid() {
        let axes = parse_axes("tiles=36:64,dram").unwrap();
        let genomes = grid_genomes(&axes, 0);
        let combos = expand_grid(&axes, 0);
        assert_eq!(genomes.len(), combos.len());
        // first axis = least-significant digit, in lockstep with the combos
        assert_eq!(genomes[0], vec![0, 0]);
        assert_eq!(genomes[1], vec![1, 0]);
        assert_eq!(genomes[3], vec![1, 1]);
        for (g, combo) in genomes.iter().zip(combos.iter()) {
            let derived: Vec<HwOverride> = axes
                .iter()
                .zip(g.iter())
                .map(|(a, &i)| a.values[i])
                .collect();
            assert_eq!(&derived, combo);
        }
        // the budget stride is shared, so subsamples stay in lockstep too
        assert_eq!(grid_genomes(&axes, 3).len(), 3);
        for (g, combo) in grid_genomes(&axes, 3).iter().zip(expand_grid(&axes, 3).iter()) {
            assert_eq!(axes[0].values[g[0]], combo[0]);
            assert_eq!(axes[1].values[g[1]], combo[1]);
        }
    }

    #[test]
    fn budget_subsamples_evenly_and_deterministically() {
        let axes = parse_axes("tiles,nop_bw,dram").unwrap(); // 5*4*2 = 40
        let full = expand_grid(&axes, 0);
        assert_eq!(full.len(), 40);
        let capped = expand_grid(&axes, 12);
        assert_eq!(capped.len(), 12);
        // strictly increasing stride picks -> no duplicates, stable order
        let again = expand_grid(&axes, 12);
        for (a, b) in capped.iter().zip(again.iter()) {
            assert_eq!(a, b);
        }
        // every pick is a member of the full grid
        for combo in &capped {
            assert!(full.contains(combo));
        }
        // budget >= grid size leaves the grid untouched
        assert_eq!(expand_grid(&axes, 100).len(), 40);
    }
}
