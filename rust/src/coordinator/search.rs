//! Guided design-space search (`mozart explore --strategy ...`).
//!
//! PR 3's explorer enumerates a declarative axis grid exhaustively. This
//! module turns the same cell-evaluation path into a *search*: a
//! [`SearchStrategy`] proposes candidates over the gene space (the axis
//! value sets, plus — in co-design mode — the Mozart method itself), each
//! candidate is evaluated through the explorer's shared cell path on the
//! work-stealing pool ([`parallel_map_with`], threading a per-worker
//! [`crate::coordinator::cache::EvalPool`] of re-timeable plan topologies
//! plus the run's shared memoization cache), and an incremental Pareto
//! archive
//! ([`pareto::Frontier`]) tracks the non-dominated set in `O(n)` per point
//! instead of re-reducing the whole cloud per generation.
//!
//! **Surrogate preselection.** With `--surrogate-frac F` (F < 1), each
//! generation's fresh offspring are first ranked by a closed-form roofline
//! estimate ([`roofline::surrogate_step_latency`], worst case across the
//! candidate's cells) and only the best `ceil(F * batch)` are fully
//! simulated; the rest are returned to the proposal pool (their genomes are
//! un-registered so later generations may resurface them). The Spearman rank
//! correlation between the surrogate and the true joint latencies of the
//! simulated candidates is recorded per generation, so the artifact shows
//! how trustworthy the preselection was. `F = 1` (the default) disables the
//! path entirely and reproduces the unfiltered search bit for bit.
//!
//! **NSGA-II evolutionary strategy.** [`SearchStrategy::Evolutionary`] is a
//! full NSGA-II-style loop: binary-tournament parent selection under the
//! constrained-crowded-comparison operator, uniform crossover over the
//! discrete genomes, per-gene mutation, and environmental selection by
//! non-dominated-sort rank + crowding distance
//! ([`pareto::constrained_selection_order`]). All of it is seeded and
//! bit-reproducible.
//!
//! **Hard constraints.** [`Constraints`] caps the worst-case die area
//! (`--max-area`, mm²) and the worst-case simulated mean power
//! (`--max-power`, W), and can set a resilience floor (`--min-resilience
//! X:scenario`): each candidate is additionally simulated under the named
//! [`FaultScenario`] and must retain at least `X` of its healthy throughput
//! in the worst case across its cells. Infeasible candidates are evaluated
//! and recorded but never enter the frontier archive, and the selection
//! ranks every feasible candidate ahead of every infeasible one (infeasible
//! by ascending violation), so the budgets are hard caps rather than soft
//! penalties. Feasibility counts land in the artifact's
//! `search.feasibility` section.
//!
//! **The method gene.** With `method_gene` set (`--methods
//! baseline,a,b,c|all`), each candidate carries one Mozart ablation as a
//! trailing gene, so the frontier answers the paper's co-design question
//! directly: *which ablation on which platform*. The anchor (candidate 0)
//! is then the paper platform running its deployed method (Mozart-C when
//! configured, otherwise the last listed method). Without the gene, every
//! candidate is evaluated on all configured methods and the objectives take
//! the worst case across them, as in PR 4.
//!
//! **Joint frontiers.** The paper tunes the platform per model; the search
//! answers the harder co-design question "which hardware is good for *every*
//! model". A candidate's objectives are the **worst case** (maximum, since
//! all objectives are minimized) of latency / energy / area across every
//! configured cell, with all per-cell values recorded. With one model the
//! joint frontier degenerates to that model's frontier.
//!
//! **Serving objectives.** With `--objective p99|goodput` the first
//! minimized objective is no longer the training-step latency but an
//! online-serving score: every candidate's cells additionally build a
//! token-bucketed service model (through the same memoization cache) and
//! replay one fixed seeded arrival stream through the
//! [`crate::sim::serve`] queueing engine; the candidate is scored on the
//! worst-case p99 sojourn latency (minimized) or SLO-goodput (maximized,
//! entering the objective vector as its inverse) across its cells. The
//! surrogate preselection still ranks by the roofline *step-latency*
//! estimate — a proxy for the serving scores, which the recorded Spearman
//! correlation makes auditable.
//!
//! **Determinism.** All strategy randomness comes from one seeded
//! [`Rng`] driven on the coordinating thread; candidate evaluation derives
//! its randomness from each cell's own config (same discipline as the sweep
//! executor). Two runs with the same [`SearchConfig`] are therefore
//! bit-identical regardless of thread count — asserted in
//! `tests/integration_search.rs` and checked by `mozart bench --grid search`.
//!
//! **Convergence.** After every generation the archive's exact dominated
//! hypervolume ([`pareto::Frontier::hypervolume`], vs a fixed reference of
//! 2× the paper anchor's objectives) is recorded; the curve lands in the
//! `EXPLORE_*.json` artifact's `search` section.

use std::collections::BTreeSet;

use crate::comm::FaultScenario;
use crate::config::{
    ExperimentConfig, HwConfig, HwOverride, Method, ModelConfig, SchedPolicy,
};
use crate::coordinator::cache::{EvalSession, EvalStats};
use crate::coordinator::explore::{self, Axis, ExploreConfig, ExplorePoint};
use crate::coordinator::serve::ServeEvalSpec;
use crate::coordinator::sweep::{parallel_map_with, SweepOptions};
use crate::metrics::{pareto, roofline};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{scatter_plot, Table};

/// How the search proposes candidates over the gene space.
///
/// # Examples
///
/// A tiny seeded random search over one axis; the same seed reproduces the
/// same archive bit for bit:
///
/// ```
/// use mozart::config::{DramKind, HwOverride, Method, ModelId, SchedPolicy};
/// use mozart::coordinator::explore::{Axis, ExploreConfig};
/// use mozart::coordinator::search::{search, SearchConfig, SearchStrategy};
///
/// let explore = ExploreConfig {
///     axes: vec![Axis {
///         name: "tiles".to_string(),
///         values: vec![HwOverride::MoeTiles(36), HwOverride::MoeTiles(64)],
///     }],
///     budget: 0,
///     models: vec![ModelId::OlmoE_1B_7B],
///     methods: vec![Method::MozartC],
///     scheds: vec![SchedPolicy::Streaming],
///     seq_len: 64,
///     dram: DramKind::Hbm2,
///     iters: 1,
///     seed: 7,
///     threads: 1,
///     eval: mozart::coordinator::cache::EvalOptions::default(),
/// };
/// let cfg = SearchConfig::new(explore, SearchStrategy::Random { samples: 2, seed: 7 });
/// let a = search(&cfg);
/// let b = search(&cfg);
/// assert_eq!(a.archive, b.archive); // deterministic for a fixed seed
/// assert!(!a.convergence.is_empty());
/// assert!(a.archive.iter().all(|&c| c < a.candidates.len()));
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SearchStrategy {
    /// Enumerate the full gene product — the hardware grid (subject to the
    /// explore config's `budget` even-stride subsample), crossed with every
    /// configured method when the method gene is active — fed through the
    /// streaming archive in PR-3 grid order.
    Exhaustive,
    /// Uniform seeded sampling of the gene product: `samples` proposals,
    /// de-duplicated, evaluated in one generation.
    Random {
        /// Number of candidate proposals (duplicates are evaluated once).
        samples: usize,
        /// Strategy RNG seed (independent of the simulation seed).
        seed: u64,
    },
    /// NSGA-II-style evolutionary search: a seeded random initial
    /// population, then per generation binary-tournament parent selection
    /// under the constrained-crowded-comparison operator
    /// ([`pareto::constrained_selection_order`]), uniform crossover with
    /// probability `crossover_rate` (otherwise the first parent is cloned),
    /// per-gene mutation (each gene resamples with probability
    /// `mutation_rate`, forcing at least one gene to move), and
    /// environmental selection of the next population by non-dominated-sort
    /// rank + crowding distance, feasible candidates always ahead of
    /// infeasible ones. Already-evaluated genomes are never re-simulated.
    Evolutionary {
        /// Offspring proposals per generation (and the population size kept
        /// by environmental selection).
        population: usize,
        /// Number of generations (the initial population is generation 1).
        generations: usize,
        /// Probability in `[0, 1]` that an offspring is produced by uniform
        /// crossover of two tournament-selected parents (0 disables
        /// crossover: offspring are mutated copies of one parent; selection
        /// is still NSGA-II, so this does not reproduce the old (μ+λ)
        /// archive-parent trajectories).
        crossover_rate: f64,
        /// Per-gene mutation probability in `[0, 1]`.
        mutation_rate: f64,
        /// Strategy RNG seed (independent of the simulation seed).
        seed: u64,
    },
}

impl SearchStrategy {
    /// Stable CLI / JSON name of the strategy kind.
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Exhaustive => "exhaustive",
            SearchStrategy::Random { .. } => "random",
            SearchStrategy::Evolutionary { .. } => "evolutionary",
        }
    }

    /// Human-readable one-line description including the parameters.
    pub fn describe(&self) -> String {
        match *self {
            SearchStrategy::Exhaustive => "exhaustive".to_string(),
            SearchStrategy::Random { samples, seed } => {
                format!("random (samples={samples}, seed={seed})")
            }
            SearchStrategy::Evolutionary {
                population,
                generations,
                crossover_rate,
                mutation_rate,
                seed,
            } => format!(
                "evolutionary/NSGA-II (population={population}, \
                 generations={generations}, crossover_rate={crossover_rate}, \
                 mutation_rate={mutation_rate}, seed={seed})"
            ),
        }
    }
}

/// The first minimized objective of the search (`--objective`); energy and
/// area are always the second and third. The default scores candidates on
/// training-step latency exactly as before; the serving objectives replay
/// the configured [`ServeEvalSpec`] traffic against every candidate's
/// service model (see [`crate::coordinator::serve::serve_cell_eval`]) and
/// score the worst case across its cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Worst-case mean training-step latency (seconds) — the default.
    Latency,
    /// Worst-case online-serving p99 sojourn latency (ms).
    P99,
    /// Worst-case SLO-goodput (requests/s). Goodput is maximized; it
    /// enters the minimized objective vector as its inverse.
    Goodput,
}

impl Objective {
    /// Stable CLI / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::P99 => "p99",
            Objective::Goodput => "goodput",
        }
    }

    /// Parse a `--objective` value.
    pub fn parse(s: &str) -> Result<Objective, String> {
        match s {
            "latency" => Ok(Objective::Latency),
            "p99" => Ok(Objective::P99),
            "goodput" => Ok(Objective::Goodput),
            other => Err(format!(
                "unknown objective `{other}` (expected latency, p99, or goodput)"
            )),
        }
    }

    /// Whether candidates must additionally be scored on the serving
    /// workload.
    pub fn needs_serve(&self) -> bool {
        !matches!(self, Objective::Latency)
    }
}

/// A resilience floor (`--min-resilience X:scenario`): every candidate must
/// retain at least `frac` of its healthy throughput when the named
/// [`FaultScenario`] is injected (retained = healthy latency / faulted
/// latency, per cell; the candidate's joint resilience is the worst case —
/// the minimum — across its cells).
#[derive(Clone, Debug, PartialEq)]
pub struct MinResilience {
    /// Required retained-throughput fraction in `(0, 1]`.
    pub frac: f64,
    /// The fault scenario the requirement is evaluated under.
    pub scenario: FaultScenario,
}

/// Hard design-envelope constraints on the joint (worst-case) objectives.
/// A candidate is *feasible* iff it violates none of the set caps;
/// infeasible candidates never enter the frontier archive and are ranked
/// behind every feasible candidate by the NSGA-II selection.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Constraints {
    /// Cap on the worst-case total die area (mm², `--max-area`).
    pub max_area_mm2: Option<f64>,
    /// Cap on the worst-case simulated mean power draw (W, `--max-power`;
    /// `metrics::energy::EnergyBreakdown::mean_power_w`).
    pub max_power_w: Option<f64>,
    /// Floor on the worst-case retained throughput under a fault scenario
    /// (`--min-resilience`). When set, every candidate is additionally
    /// simulated under [`MinResilience::scenario`].
    pub min_resilience: Option<MinResilience>,
}

impl Constraints {
    /// No caps: every candidate is feasible.
    pub fn none() -> Constraints {
        Constraints::default()
    }

    /// Whether any cap is set.
    pub fn any(&self) -> bool {
        self.max_area_mm2.is_some()
            || self.max_power_w.is_some()
            || self.min_resilience.is_some()
    }

    /// The fault scenario candidates must additionally be evaluated under,
    /// when a resilience floor is set.
    pub fn fault_scenario(&self) -> Option<&FaultScenario> {
        self.min_resilience.as_ref().map(|mr| &mr.scenario)
    }

    /// Total normalized violation of the caps: the sum over set caps of the
    /// relative excess `max(0, value/cap - 1)` (for the resilience floor,
    /// `max(0, floor/retained - 1)`). Exactly `0.0` iff feasible; larger is
    /// worse (the NSGA-II selection orders infeasible candidates by this
    /// value). `resilience` is the candidate's worst-case retained
    /// throughput, `None` when no resilience evaluation ran — which counts
    /// as a full violation whenever a floor is set.
    pub fn violation(&self, area_mm2: f64, power_w: f64, resilience: Option<f64>) -> f64 {
        let mut v = 0.0;
        if let Some(cap) = self.max_area_mm2 {
            v += (area_mm2 / cap - 1.0).max(0.0);
        }
        if let Some(cap) = self.max_power_w {
            v += (power_w / cap - 1.0).max(0.0);
        }
        if let Some(mr) = &self.min_resilience {
            match resilience {
                Some(r) if r > 0.0 => v += (mr.frac / r - 1.0).max(0.0),
                _ => v += 1.0,
            }
        }
        v
    }

    /// Whether a (area, power, resilience) point satisfies every set cap.
    pub fn feasible(&self, area_mm2: f64, power_w: f64, resilience: Option<f64>) -> bool {
        self.violation(area_mm2, power_w, resilience) == 0.0
    }

    /// Human-readable cap list, e.g. `area <= 900 mm^2, power <= 12000 W,
    /// resilience >= 0.8 under dead-chiplet:2`; empty when no cap is set.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(cap) = self.max_area_mm2 {
            parts.push(format!("area <= {cap} mm^2"));
        }
        if let Some(cap) = self.max_power_w {
            parts.push(format!("power <= {cap} W"));
        }
        if let Some(mr) = &self.min_resilience {
            parts.push(format!(
                "resilience >= {} under {}",
                mr.frac,
                mr.scenario.label()
            ));
        }
        parts.join(", ")
    }
}

/// Full specification of one guided search run: the design space and
/// workload (reusing [`ExploreConfig`]), the proposal strategy, the hard
/// [`Constraints`], and whether the Mozart method is a searchable gene.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Axes, models, methods, workload, simulation seed, and thread count.
    /// `budget` caps the hardware grid only under
    /// [`SearchStrategy::Exhaustive`].
    pub explore: ExploreConfig,
    /// Candidate-proposal strategy.
    pub strategy: SearchStrategy,
    /// Hard area/power caps on the joint objectives (default: none).
    pub constraints: Constraints,
    /// When set, each candidate carries one of `explore.methods` as a
    /// trailing gene (`--methods ...`) instead of being evaluated on all of
    /// them, so the frontier answers "which ablation on which platform".
    pub method_gene: bool,
    /// When set, each candidate carries one of `explore.scheds` as a
    /// trailing gene (`--scheds ...`, after the method gene when both are
    /// active) instead of being evaluated on all of them, so the frontier
    /// answers "which schedule on which platform". Without the gene, every
    /// candidate is evaluated under all configured policies and the
    /// objectives take the worst case across them — the same semantics the
    /// method list has without its gene.
    pub sched_gene: bool,
    /// Fraction in `(0, 1]` of each generation's fresh offspring that gets
    /// fully simulated (`--surrogate-frac`); the batch is ranked by the
    /// roofline surrogate first and the tail is skipped. `1.0` (the
    /// default) disables preselection and is bit-identical to not having
    /// the feature at all.
    pub surrogate_frac: f64,
    /// First minimized objective (`--objective`, default step latency).
    /// The serving objectives score every candidate on the serving
    /// workload ([`SearchConfig::serve_spec`]).
    pub objective: Objective,
    /// Serving workload candidates are scored on. `None` with a serving
    /// objective falls back to [`ServeEvalSpec::paper_default`]; `Some`
    /// with `--objective latency` still records the serving metrics per
    /// candidate without changing the optimized objectives.
    pub serve: Option<ServeEvalSpec>,
}

impl SearchConfig {
    /// An unconstrained search without the method gene — the PR-4 semantics.
    pub fn new(explore: ExploreConfig, strategy: SearchStrategy) -> SearchConfig {
        SearchConfig {
            explore,
            strategy,
            constraints: Constraints::none(),
            method_gene: false,
            sched_gene: false,
            surrogate_frac: 1.0,
            objective: Objective::Latency,
            serve: None,
        }
    }

    /// The serving workload candidates are actually scored on, if any:
    /// the configured spec, or the paper default when a serving objective
    /// is selected without one.
    pub fn serve_spec(&self) -> Option<ServeEvalSpec> {
        match (&self.serve, self.objective) {
            (Some(s), _) => Some(s.clone()),
            (None, Objective::Latency) => None,
            (None, _) => Some(ServeEvalSpec::paper_default()),
        }
    }
}

/// One proposed candidate (candidate 0 is always the paper anchor).
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Overrides applied on top of the per-model paper platform; empty for
    /// the anchor.
    pub overrides: Vec<HwOverride>,
    /// The method gene: `Some(m)` when this candidate is evaluated on one
    /// specific method (co-design mode); `None` when it is evaluated on
    /// every configured method (worst-case mode).
    pub method: Option<Method>,
    /// The scheduling-policy gene: `Some(s)` when this candidate is
    /// evaluated under one specific policy (`--scheds` co-design mode);
    /// `None` when it is evaluated under every configured policy
    /// (worst-case mode).
    pub sched: Option<SchedPolicy>,
    /// Display label (`"paper (Table 2)"` or `"tiles=36 dram=SSD
    /// method=Mozart-B sched=heft"` style).
    pub label: String,
    /// Per-gene value indices the strategy proposed; `None` for the anchor,
    /// which is not a grid point.
    pub genome: Option<Vec<usize>>,
}

/// A candidate's joint (worst-case across its cells) objectives.
#[derive(Clone, Debug)]
pub struct JointPoint {
    /// Index into [`SearchOutcome::candidates`].
    pub candidate: usize,
    /// Worst mean step latency across all evaluated cells (s) — minimized.
    pub latency_s: f64,
    /// Worst energy per step across all evaluated cells (J) — minimized.
    pub energy_j: f64,
    /// Worst die area across all evaluated cells (mm²) — minimized.
    pub area_mm2: f64,
    /// Worst simulated mean power across all evaluated cells (W) —
    /// constrained by `--max-power`, not an objective.
    pub power_w: f64,
    /// Worst-case (minimum) retained throughput across all evaluated cells
    /// under the constraint's fault scenario — constrained by
    /// `--min-resilience`, not an objective. `None` when no resilience
    /// floor is set (no faulted evaluation ran).
    pub resilience: Option<f64>,
    /// Worst (maximum) serving p99 sojourn latency across all evaluated
    /// cells (ms); `None` when no serving workload was evaluated.
    pub p99_ms: Option<f64>,
    /// Worst (minimum) SLO-goodput across all evaluated cells (req/s);
    /// `None` when no serving workload was evaluated.
    pub goodput_rps: Option<f64>,
    /// Indices of this candidate's per-(model × method) cells in
    /// [`SearchOutcome::cells`].
    pub cells: Vec<usize>,
}

impl JointPoint {
    /// The minimized joint objective vector (latency, energy, area) —
    /// shorthand for [`JointPoint::objectives_for`] with
    /// [`Objective::Latency`].
    pub fn objectives(&self) -> Vec<f64> {
        self.objectives_for(Objective::Latency)
    }

    /// The minimized joint objective vector under the given first
    /// objective: `[latency | p99 | 1/goodput, energy, area]`. Goodput is
    /// maximized, so it enters as its inverse (guarded so a zero-goodput
    /// candidate maps to a large finite value rather than infinity, which
    /// would break the exact hypervolume).
    pub fn objectives_for(&self, obj: Objective) -> Vec<f64> {
        let first = match obj {
            Objective::Latency => self.latency_s,
            Objective::P99 => self
                .p99_ms
                .expect("p99 objective requires serving metrics on every candidate"),
            Objective::Goodput => {
                let g = self
                    .goodput_rps
                    .expect("goodput objective requires serving metrics on every candidate");
                1.0 / (g + 1e-9)
            }
        };
        vec![first, self.energy_j, self.area_mm2]
    }
}

/// Surrogate-preselection accounting for one generation (only present when
/// `--surrogate-frac < 1` actually filtered the generation's offspring).
#[derive(Clone, Debug)]
pub struct SurrogateStat {
    /// Fresh offspring the strategy proposed this generation.
    pub proposed: usize,
    /// Offspring that survived the surrogate cut and were fully simulated.
    pub simulated: usize,
    /// Spearman rank correlation between the surrogate estimates and the
    /// true joint latencies of the *simulated* offspring; `None` when fewer
    /// than two offspring were simulated or the ranks are degenerate.
    pub spearman: Option<f64>,
}

/// Archive/convergence snapshot after one generation.
#[derive(Clone, Debug)]
pub struct GenStat {
    /// 1-based generation number.
    pub generation: usize,
    /// Cumulative unique candidates evaluated so far (incl. the anchor).
    pub evaluations: usize,
    /// Cumulative candidates satisfying the constraints (== `evaluations`
    /// for an unconstrained search).
    pub feasible: usize,
    /// Archive size after this generation (feasible non-dominated set).
    pub archive_size: usize,
    /// Exact dominated hypervolume of the archive vs the fixed reference
    /// point ([`pareto::Frontier::hypervolume`]).
    pub hypervolume: f64,
    /// Surrogate-preselection accounting; `None` when the generation was
    /// not filtered (`--surrogate-frac 1` or nothing fresh to filter).
    pub surrogate: Option<SurrogateStat>,
}

impl GenStat {
    /// One-line rendering, shared by the CLI's live per-generation progress
    /// and the report's convergence section so the two never drift.
    pub fn render(&self) -> String {
        let mut line = format!(
            "gen {:>2}: {:>4} candidates evaluated ({} feasible), archive {:>3}, \
             hypervolume {:.4}",
            self.generation, self.evaluations, self.feasible, self.archive_size,
            self.hypervolume
        );
        if let Some(s) = &self.surrogate {
            line.push_str(&format!(
                ", surrogate {}/{} simulated (rho {})",
                s.simulated,
                s.proposed,
                s.spearman
                    .map_or("n/a".to_string(), |r| format!("{r:.2}"))
            ));
        }
        line
    }
}

/// Everything one guided search run produced.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// The configuration the run used.
    pub cfg: SearchConfig,
    /// Every evaluated candidate (candidate 0 is the paper anchor).
    pub candidates: Vec<Candidate>,
    /// Every evaluated (candidate × model × method) cell; the point's
    /// `variant` field holds the candidate index.
    pub cells: Vec<ExplorePoint>,
    /// Joint worst-case objectives, aligned with `candidates`.
    pub joint: Vec<JointPoint>,
    /// Candidate indices on the joint Pareto frontier of the *feasible*
    /// candidates, sorted ascending (with no constraints set, of all
    /// candidates).
    pub archive: Vec<usize>,
    /// Candidate indices that jointly dominate the paper anchor (feasible
    /// or not); empty iff the anchor is non-dominated.
    pub paper_dominators: Vec<usize>,
    /// Per-generation convergence curve.
    pub convergence: Vec<GenStat>,
    /// Reference point of the hypervolume (2× the anchor objectives).
    pub hypervolume_ref: Vec<f64>,
    /// Evaluation-throughput accounting: memoization-cache hit rates and
    /// plan-pool build/retime counts. Affects wall-clock only, never the
    /// reported numbers.
    pub eval: EvalStats,
}

/// The discrete gene space of one search: one gene per hardware axis, plus
/// a trailing method gene and/or scheduling-policy gene in co-design mode
/// (axes first, then method, then sched).
struct GenomeSpace<'a> {
    axes: &'a [Axis],
    /// `Some(methods)` when the method is a searchable gene.
    methods: Option<&'a [Method]>,
    /// `Some(scheds)` when the scheduling policy is a searchable gene.
    scheds: Option<&'a [SchedPolicy]>,
    /// Cardinality of each gene position.
    card: Vec<usize>,
}

impl<'a> GenomeSpace<'a> {
    fn new(
        axes: &'a [Axis],
        methods: Option<&'a [Method]>,
        scheds: Option<&'a [SchedPolicy]>,
    ) -> GenomeSpace<'a> {
        let mut card: Vec<usize> = axes.iter().map(|a| a.values.len()).collect();
        if let Some(ms) = methods {
            card.push(ms.len());
        }
        if let Some(ss) = scheds {
            card.push(ss.len());
        }
        GenomeSpace { axes, methods, scheds, card }
    }

    /// Decode a genome into hardware overrides and (in co-design mode) the
    /// candidate's method and scheduling policy.
    fn decode(&self, g: &[usize]) -> (Vec<HwOverride>, Option<Method>, Option<SchedPolicy>) {
        let overrides: Vec<HwOverride> = self
            .axes
            .iter()
            .zip(g.iter())
            .map(|(a, &i)| a.values[i])
            .collect();
        let method = self.methods.map(|ms| ms[g[self.axes.len()]]);
        let sched_pos = self.axes.len() + usize::from(self.methods.is_some());
        let sched = self.scheds.map(|ss| ss[g[sched_pos]]);
        (overrides, method, sched)
    }
}

/// The anchor's method in co-design mode: the paper deploys the full system
/// (Mozart-C) on its Table 2 platform, so that is the reference whenever it
/// is configured; otherwise the last (most-featured) listed method.
fn preferred_method(methods: &[Method]) -> Method {
    if methods.contains(&Method::MozartC) {
        Method::MozartC
    } else {
        *methods.last().expect("at least one method configured")
    }
}

/// The anchor's scheduling policy in co-design mode: the paper's schedule
/// is the streaming dispatcher, so that is the reference whenever it is
/// configured; otherwise the first listed policy (the `--scheds` reference
/// position, matching the explorer's convention).
fn preferred_sched(scheds: &[SchedPolicy]) -> SchedPolicy {
    if scheds.contains(&SchedPolicy::Streaming) {
        SchedPolicy::Streaming
    } else {
        *scheds.first().expect("at least one scheduler configured")
    }
}

/// Evaluate a batch of fresh candidates over the work-stealing pool and fold
/// them into the outcome state. Cells are appended candidate-major (models
/// outer, methods next, scheds innermost), so a candidate's cells are
/// contiguous. Only feasible candidates enter the frontier archive.
///
/// A candidate whose overrides are a no-op for one model — and whose method
/// and sched genes match the anchor's — would simulate a cell bit-identical
/// to the anchor's (identical `ExperimentConfig`), so that cell reuses
/// candidate 0's result instead of re-running the discrete-event simulation
/// — the search-side mirror of the per-model anchor-duplicate skip in
/// [`explore::explore`].
#[allow(clippy::too_many_arguments)]
fn eval_batch(
    ex: &ExploreConfig,
    constraints: &Constraints,
    objective: Objective,
    serve_spec: Option<&ServeEvalSpec>,
    bases: &[HwConfig],
    batch: Vec<Candidate>,
    session: &EvalSession,
    candidates: &mut Vec<Candidate>,
    cells: &mut Vec<ExplorePoint>,
    joint: &mut Vec<JointPoint>,
    archive: &mut pareto::Frontier,
) {
    if batch.is_empty() {
        return;
    }
    let first = candidates.len();
    let n_models = ex.models.len();
    let methods_of = |c: &Candidate| -> Vec<Method> {
        match c.method {
            Some(m) => vec![m],
            None => ex.methods.clone(),
        }
    };
    let scheds_of = |c: &Candidate| -> Vec<SchedPolicy> {
        match c.sched {
            Some(s) => vec![s],
            None => ex.scheds.clone(),
        }
    };
    // which (candidate, model) pairs can reuse the anchor's cells: same
    // method and sched sets as the anchor and hardware that is a no-op for
    // that model (none while evaluating the anchor batch itself)
    let anchor_genes = candidates.first().map(|c| (c.method, c.sched));
    let mut reuse = vec![false; batch.len() * n_models];
    if let Some((am, asched)) = anchor_genes {
        for (off, cand) in batch.iter().enumerate() {
            if cand.method != am || cand.sched != asched {
                continue;
            }
            for mi in 0..n_models {
                reuse[off * n_models + mi] =
                    explore::is_anchor_combo(&cand.overrides, &bases[mi]);
            }
        }
    }
    let mut specs: Vec<(usize, usize, Method, SchedPolicy)> = Vec::new();
    for (off, cand) in batch.iter().enumerate() {
        for mi in 0..n_models {
            if reuse[off * n_models + mi] {
                continue;
            }
            for m in methods_of(cand) {
                for s in scheds_of(cand) {
                    specs.push((off, mi, m, s));
                }
            }
        }
    }
    let fault = constraints.fault_scenario();
    let threads = SweepOptions { threads: ex.threads }.effective_threads(specs.len());
    let pts = parallel_map_with(
        &specs,
        threads,
        session.pools(),
        || session.new_pool(),
        |pool, &(off, mi, m, s)| {
            let mut ctx = session.ctx(pool);
            explore::eval_point(
                ex,
                &batch[off].overrides,
                first + off,
                ex.models[mi],
                m,
                s,
                fault,
                serve_spec,
                &mut ctx,
            )
        },
    );

    let mut fresh = pts.into_iter();
    for (off, cand) in batch.into_iter().enumerate() {
        let ci = first + off;
        let methods = methods_of(&cand);
        let scheds = scheds_of(&cand);
        let width = methods.len() * scheds.len();
        let mut cand_pts: Vec<ExplorePoint> = Vec::with_capacity(n_models * width);
        for mi in 0..n_models {
            if reuse[off * n_models + mi] {
                for w in 0..width {
                    // the anchor's cells sit at the head of `cells` in the
                    // same (model-major, method-then-sched-minor) order and
                    // — because the gene sets match — the same width
                    let mut anchor_cell = cells[mi * width + w].clone();
                    anchor_cell.variant = ci;
                    cand_pts.push(anchor_cell);
                }
            } else {
                for _ in 0..width {
                    cand_pts.push(fresh.next().expect("one simulated point per spec"));
                }
            }
        }
        let mut latency_s = 0.0f64;
        let mut energy_j = 0.0f64;
        let mut area_mm2 = 0.0f64;
        let mut power_w = 0.0f64;
        // joint resilience is the WORST retained fraction across cells;
        // likewise serving: worst p99 is the maximum, worst goodput the
        // minimum
        let mut resilience: Option<f64> = None;
        let mut p99_ms: Option<f64> = None;
        let mut goodput_rps: Option<f64> = None;
        let mut cell_idx = Vec::with_capacity(cand_pts.len());
        for p in cand_pts {
            latency_s = latency_s.max(p.latency_s);
            energy_j = energy_j.max(p.energy_j);
            area_mm2 = area_mm2.max(p.area_mm2);
            power_w = power_w.max(p.mean_power_w);
            if let Some(r) = p.retained {
                resilience = Some(resilience.map_or(r, |acc: f64| acc.min(r)));
            }
            if let Some(sv) = p.serve {
                p99_ms = Some(p99_ms.map_or(sv.p99_ms, |acc: f64| acc.max(sv.p99_ms)));
                goodput_rps =
                    Some(goodput_rps.map_or(sv.goodput_rps, |acc: f64| acc.min(sv.goodput_rps)));
            }
            cell_idx.push(cells.len());
            cells.push(p);
        }
        let jp = JointPoint {
            candidate: ci,
            latency_s,
            energy_j,
            area_mm2,
            power_w,
            resilience,
            p99_ms,
            goodput_rps,
            cells: cell_idx,
        };
        // hard caps: infeasible candidates are recorded but never pollute
        // the frontier archive
        if constraints.feasible(jp.area_mm2, jp.power_w, jp.resilience) {
            archive.insert(ci, &jp.objectives_for(objective));
        }
        joint.push(jp);
        candidates.push(cand);
    }
}

/// Joint (worst-case across the candidate's cells) roofline surrogate of a
/// candidate's step latency: the same `(model, method)` cell enumeration and
/// config construction as the simulated path, but each cell costs a handful
/// of closed-form arithmetic ops instead of a discrete-event simulation.
/// Comparable across candidates of one search only — the values are ranks'
/// raw material, never reported as latencies.
fn surrogate_score(ex: &ExploreConfig, bases: &[HwConfig], cand: &Candidate) -> f64 {
    let methods: Vec<Method> = match cand.method {
        Some(m) => vec![m],
        None => ex.methods.clone(),
    };
    let mut worst = 0.0f64;
    for (mi, &model) in ex.models.iter().enumerate() {
        let hw = bases[mi].with_overrides(&cand.overrides);
        for &m in &methods {
            let mut ec = ExperimentConfig::paper_default(
                ModelConfig::preset(model),
                m.config(),
            );
            ec.hw = hw.clone();
            ec.seq_len = ex.seq_len;
            ec.iters = ex.iters;
            ec.seed = ex.seed;
            worst = worst.max(roofline::surrogate_step_latency(&ec));
        }
    }
    worst
}

/// Turn proposed genomes into fresh [`Candidate`]s: drops genomes already
/// seen and combos that re-describe the paper anchor (same method and sched
/// genes, and hardware that is a no-op for every configured model — the
/// anchor is candidate 0 already). Every inspected genome — including
/// dropped ones — is registered in `seen`, so a re-proposal skips the
/// rebuild and anchor check next time.
fn fresh_candidates(
    space: &GenomeSpace,
    genomes: Vec<Vec<usize>>,
    bases: &[HwConfig],
    anchor_method: Option<Method>,
    anchor_sched: Option<SchedPolicy>,
    seen: &mut BTreeSet<Vec<usize>>,
) -> Vec<Candidate> {
    let mut out: Vec<Candidate> = Vec::new();
    for g in genomes {
        if seen.contains(&g) {
            continue;
        }
        seen.insert(g.clone());
        let (overrides, method, sched) = space.decode(&g);
        if method == anchor_method
            && sched == anchor_sched
            && bases.iter().all(|b| explore::is_anchor_combo(&overrides, b))
        {
            continue;
        }
        let mut label = overrides
            .iter()
            .map(|o| o.label())
            .collect::<Vec<_>>()
            .join(" ");
        let push_part = |part: String, label: &mut String| {
            if !label.is_empty() {
                label.push(' ');
            }
            label.push_str(&part);
        };
        if let Some(m) = method {
            push_part(format!("method={}", m.name()), &mut label);
        }
        if let Some(s) = sched {
            push_part(format!("sched={}", s.name()), &mut label);
        }
        out.push(Candidate {
            overrides,
            method,
            sched,
            label,
            genome: Some(g),
        });
    }
    out
}

/// One uniformly random genome over the gene cardinalities.
fn random_genome(card: &[usize], rng: &mut Rng) -> Vec<usize> {
    card.iter().map(|&n| rng.below(n)).collect()
}

/// Resample an index in `[0, n)` different from `cur` (requires `n > 1`).
fn resample_different(n: usize, cur: usize, rng: &mut Rng) -> usize {
    let j = rng.below(n - 1);
    if j >= cur {
        j + 1
    } else {
        j
    }
}

/// Mutate a genome: each gene moves to a different value of its position
/// with probability `rate`; if nothing moved, one mutable gene is forced to
/// move so offspring always explore (when any position has more than one
/// value).
fn mutate(card: &[usize], genome: &[usize], rate: f64, rng: &mut Rng) -> Vec<usize> {
    let mut g = genome.to_vec();
    let mut changed = false;
    for (i, &n) in card.iter().enumerate() {
        if n > 1 && rng.f64() < rate {
            g[i] = resample_different(n, g[i], rng);
            changed = true;
        }
    }
    if !changed {
        let mutable: Vec<usize> = card
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 1)
            .map(|(i, _)| i)
            .collect();
        if !mutable.is_empty() {
            let i = mutable[rng.below(mutable.len())];
            g[i] = resample_different(card[i], g[i], rng);
        }
    }
    g
}

/// Uniform crossover: each gene is taken from either parent with equal
/// probability.
fn uniform_crossover(a: &[usize], b: &[usize], rng: &mut Rng) -> Vec<usize> {
    debug_assert_eq!(a.len(), b.len(), "parent genome arity mismatch");
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| if rng.f64() < 0.5 { x } else { y })
        .collect()
}

/// Best-first NSGA-II selection order over the candidate indices in `pool`
/// (returned as positions into `pool`): feasible candidates by
/// non-dominated-sort rank then crowding distance, infeasible candidates
/// behind them by ascending violation.
fn selection_order(
    pool: &[usize],
    joint: &[JointPoint],
    constraints: &Constraints,
    objective: Objective,
) -> Vec<usize> {
    let objs: Vec<Vec<f64>> =
        pool.iter().map(|&ci| joint[ci].objectives_for(objective)).collect();
    let viol: Vec<f64> = pool
        .iter()
        .map(|&ci| {
            constraints.violation(
                joint[ci].area_mm2,
                joint[ci].power_w,
                joint[ci].resilience,
            )
        })
        .collect();
    pareto::constrained_selection_order(&objs, &viol)
}

/// NSGA-II environmental selection: the best `n` of `pool` under the
/// constrained-crowded-comparison order, best-first.
fn environmental_select(
    pool: &[usize],
    n: usize,
    joint: &[JointPoint],
    constraints: &Constraints,
    objective: Objective,
) -> Vec<usize> {
    selection_order(pool, joint, constraints, objective)
        .into_iter()
        .take(n)
        .map(|pos| pool[pos])
        .collect()
}

/// Run a guided search (see [`search_with`] for the progress-callback form).
pub fn search(cfg: &SearchConfig) -> SearchOutcome {
    search_with(cfg, |_| {})
}

/// Run a guided search, invoking `on_generation` with each [`GenStat`] as it
/// is recorded (the CLI prints these as per-generation progress).
/// Deterministic for a fixed config regardless of `threads`.
pub fn search_with(
    cfg: &SearchConfig,
    mut on_generation: impl FnMut(&GenStat),
) -> SearchOutcome {
    let ex = &cfg.explore;
    let space = GenomeSpace::new(
        &ex.axes,
        if cfg.method_gene {
            Some(ex.methods.as_slice())
        } else {
            None
        },
        if cfg.sched_gene {
            Some(ex.scheds.as_slice())
        } else {
            None
        },
    );
    let bases: Vec<HwConfig> = ex
        .models
        .iter()
        .map(|&m| HwConfig::paper_for_model(m, ex.dram))
        .collect();
    let anchor_method = if cfg.method_gene {
        Some(preferred_method(&ex.methods))
    } else {
        None
    };
    let anchor_sched = if cfg.sched_gene {
        Some(preferred_sched(&ex.scheds))
    } else {
        None
    };
    let constraints = &cfg.constraints;
    let objective = cfg.objective;
    // the serving workload, when any: every candidate replays the same
    // arrival stream against its own service model (built through the
    // memoization cache, so candidates sharing a topology share the cost)
    let serve_spec = cfg.serve_spec();
    let serve_ref = serve_spec.as_ref();
    let session = EvalSession::new(ex.eval.clone());

    let mut candidates: Vec<Candidate> = Vec::new();
    let mut cells: Vec<ExplorePoint> = Vec::new();
    let mut joint: Vec<JointPoint> = Vec::new();
    let mut archive = pareto::Frontier::new();
    let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
    let mut convergence: Vec<GenStat> = Vec::new();

    // the paper anchor is always candidate 0 and seeds the hypervolume
    // reference point (and, when feasible, the archive)
    eval_batch(
        ex,
        constraints,
        objective,
        serve_ref,
        &bases,
        vec![Candidate {
            overrides: Vec::new(),
            method: anchor_method,
            sched: anchor_sched,
            label: {
                let mut l = "paper (Table 2)".to_string();
                if let Some(m) = anchor_method {
                    l.push_str(&format!(" method={}", m.name()));
                }
                if let Some(s) = anchor_sched {
                    l.push_str(&format!(" sched={}", s.name()));
                }
                l
            },
            genome: None,
        }],
        &session,
        &mut candidates,
        &mut cells,
        &mut joint,
        &mut archive,
    );
    let hypervolume_ref: Vec<f64> =
        joint[0].objectives_for(objective).iter().map(|v| v * 2.0).collect();

    // one macro per generation: evaluate a batch of genomes, then record
    let surrogate_frac = cfg.surrogate_frac;
    let mut run_generation = |generation: usize,
                              genomes: Vec<Vec<usize>>,
                              candidates: &mut Vec<Candidate>,
                              cells: &mut Vec<ExplorePoint>,
                              joint: &mut Vec<JointPoint>,
                              archive: &mut pareto::Frontier,
                              seen: &mut BTreeSet<Vec<usize>>,
                              convergence: &mut Vec<GenStat>| {
        let mut batch =
            fresh_candidates(&space, genomes, &bases, anchor_method, anchor_sched, seen);
        // surrogate preselection: rank the fresh offspring by the roofline
        // estimate and simulate only the most promising fraction; the rest
        // give their genomes back to the proposal pool
        let mut preselect: Option<(usize, Vec<f64>)> = None;
        if surrogate_frac < 1.0 && batch.len() > 1 {
            let proposed = batch.len();
            let scores: Vec<f64> =
                batch.iter().map(|c| surrogate_score(ex, &bases, c)).collect();
            let keep = ((surrogate_frac * proposed as f64).ceil() as usize)
                .clamp(1, proposed);
            let mut order: Vec<usize> = (0..proposed).collect();
            order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
            let kept_set: BTreeSet<usize> = order[..keep].iter().copied().collect();
            let mut kept: Vec<Candidate> = Vec::with_capacity(keep);
            let mut kept_scores: Vec<f64> = Vec::with_capacity(keep);
            for (i, c) in batch.into_iter().enumerate() {
                if kept_set.contains(&i) {
                    kept_scores.push(scores[i]);
                    kept.push(c);
                } else if let Some(g) = &c.genome {
                    // un-register so a later generation may re-propose it
                    seen.remove(g);
                }
            }
            batch = kept;
            preselect = Some((proposed, kept_scores));
        }
        let first_joint = joint.len();
        eval_batch(
            ex, constraints, objective, serve_ref, &bases, batch, &session, candidates,
            cells, joint, archive,
        );
        let surrogate = preselect.map(|(proposed, scores)| {
            let truth: Vec<f64> =
                joint[first_joint..].iter().map(|j| j.latency_s).collect();
            SurrogateStat {
                proposed,
                simulated: truth.len(),
                spearman: stats::spearman(&scores, &truth),
            }
        });
        let feasible = joint
            .iter()
            .filter(|j| constraints.feasible(j.area_mm2, j.power_w, j.resilience))
            .count();
        let stat = GenStat {
            generation,
            evaluations: candidates.len(),
            feasible,
            archive_size: archive.len(),
            hypervolume: archive.hypervolume(&hypervolume_ref),
            surrogate,
        };
        on_generation(&stat);
        convergence.push(stat);
    };

    match cfg.strategy {
        SearchStrategy::Exhaustive => {
            let mut genomes = explore::grid_genomes(&ex.axes, ex.budget);
            if cfg.method_gene {
                // the hardware grid (budget-capped) crossed with every
                // configured method
                let hw = std::mem::take(&mut genomes);
                for g in &hw {
                    for ki in 0..ex.methods.len() {
                        let mut w = g.clone();
                        w.push(ki);
                        genomes.push(w);
                    }
                }
            }
            if cfg.sched_gene {
                // ... and with every configured scheduling policy
                let prev = std::mem::take(&mut genomes);
                for g in &prev {
                    for si in 0..ex.scheds.len() {
                        let mut w = g.clone();
                        w.push(si);
                        genomes.push(w);
                    }
                }
            }
            run_generation(
                1,
                genomes,
                &mut candidates,
                &mut cells,
                &mut joint,
                &mut archive,
                &mut seen,
                &mut convergence,
            );
        }
        SearchStrategy::Random { samples, seed } => {
            let mut rng = Rng::new(seed ^ 0x5EA2_C417);
            let genomes: Vec<Vec<usize>> = (0..samples)
                .map(|_| random_genome(&space.card, &mut rng))
                .collect();
            run_generation(
                1,
                genomes,
                &mut candidates,
                &mut cells,
                &mut joint,
                &mut archive,
                &mut seen,
                &mut convergence,
            );
        }
        SearchStrategy::Evolutionary {
            population,
            generations,
            crossover_rate,
            mutation_rate,
            seed,
        } => {
            let population = population.max(1);
            let mut rng = Rng::new(seed ^ 0xE501_7104);
            // the NSGA-II population: evaluated, genome-bearing candidate
            // indices (the anchor is tracked by the archive, not bred from)
            let mut pop: Vec<usize> = Vec::new();
            for g in 0..generations.max(1) {
                let genomes: Vec<Vec<usize>> = if g == 0 || pop.is_empty() {
                    (0..population)
                        .map(|_| random_genome(&space.card, &mut rng))
                        .collect()
                } else {
                    // binary tournaments under the constrained-crowded
                    // order, then uniform crossover + mutation
                    let order = selection_order(&pop, &joint, constraints, objective);
                    let mut rank = vec![0usize; pop.len()];
                    for (pos, &member) in order.iter().enumerate() {
                        rank[member] = pos;
                    }
                    let tournament = |rng: &mut Rng| -> usize {
                        let a = rng.below(pop.len());
                        let b = rng.below(pop.len());
                        pop[if rank[a] <= rank[b] { a } else { b }]
                    };
                    (0..population)
                        .map(|_| {
                            let p1 = tournament(&mut rng);
                            let p2 = tournament(&mut rng);
                            let ga = candidates[p1]
                                .genome
                                .as_ref()
                                .expect("population members carry genomes");
                            let gb = candidates[p2]
                                .genome
                                .as_ref()
                                .expect("population members carry genomes");
                            let child = if rng.f64() < crossover_rate {
                                uniform_crossover(ga, gb, &mut rng)
                            } else {
                                ga.clone()
                            };
                            mutate(&space.card, &child, mutation_rate, &mut rng)
                        })
                        .collect()
                };
                let before = candidates.len();
                run_generation(
                    g + 1,
                    genomes,
                    &mut candidates,
                    &mut cells,
                    &mut joint,
                    &mut archive,
                    &mut seen,
                    &mut convergence,
                );
                pop.extend(before..candidates.len());
                pop = environmental_select(&pop, population, &joint, constraints, objective);
            }
        }
    }

    let joint_objs: Vec<Vec<f64>> =
        joint.iter().map(|j| j.objectives_for(objective)).collect();
    let paper_dominators = pareto::dominators(&joint_objs[0], &joint_objs);
    SearchOutcome {
        cfg: cfg.clone(),
        candidates,
        cells,
        joint,
        archive: archive.keys(),
        paper_dominators,
        convergence,
        hypervolume_ref,
        eval: session.finish(),
    }
}

impl SearchOutcome {
    /// Whether a candidate satisfies the run's constraints (always true for
    /// an unconstrained run).
    pub fn is_feasible(&self, candidate: usize) -> bool {
        let j = &self.joint[candidate];
        self.cfg.constraints.feasible(j.area_mm2, j.power_w, j.resilience)
    }

    /// Number of evaluated candidates satisfying the constraints.
    pub fn n_feasible(&self) -> usize {
        (0..self.candidates.len()).filter(|&c| self.is_feasible(c)).count()
    }

    /// Rendered markdown report: axis summary, constraints + feasibility,
    /// the joint frontier table, an ASCII latency/energy scatter, the
    /// per-generation convergence curve, and the verdict on the paper's
    /// Table 2 configuration.
    pub fn render_markdown(&self) -> String {
        let ex = &self.cfg.explore;
        let mut t = Table::new("Design-space axes", &["Axis", "Values"]);
        for a in &ex.axes {
            t.row(&[
                a.name.clone(),
                a.values
                    .iter()
                    .map(|v| v.value_label())
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        if self.cfg.method_gene {
            t.row(&[
                "method".to_string(),
                ex.methods
                    .iter()
                    .map(|m| m.name().to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        if self.cfg.sched_gene {
            t.row(&[
                "sched".to_string(),
                ex.scheds
                    .iter()
                    .map(|s| s.name().to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "({} candidates incl. the paper anchor; {} cells; strategy {})\n",
            self.candidates.len(),
            self.cells.len(),
            self.cfg.strategy.describe()
        ));
        if self.cfg.constraints.any() {
            out.push_str(&format!(
                "constraints: {}; {} of {} candidates feasible\n",
                self.cfg.constraints.describe(),
                self.n_feasible(),
                self.candidates.len()
            ));
        }
        if let Some(spec) = self.cfg.serve_spec() {
            out.push_str(&format!(
                "objective: {} — serving workload {} for {} s, SLO {} ms, \
                 batch close {}\n",
                self.cfg.objective.name(),
                spec.arrivals.label(),
                spec.duration_s,
                spec.slo_ms,
                spec.params.close.label(),
            ));
        }
        out.push('\n');

        let models = ex
            .models
            .iter()
            .map(|m| m.name())
            .collect::<Vec<_>>()
            .join(", ");
        let title = format!(
            "Joint Pareto frontier — worst case across [{models}] \
             ({} of {} candidates non-dominated{})",
            self.archive.len(),
            self.candidates.len(),
            if self.cfg.constraints.any() {
                " and feasible"
            } else {
                ""
            }
        );
        let first_hdr = match self.cfg.objective {
            Objective::Latency => "Latency (s)",
            Objective::P99 => "Serve p99 (ms)",
            Objective::Goodput => "Goodput (req/s)",
        };
        let mut t = Table::new(
            &title,
            &["Candidate", first_hdr, "Energy (J/step)", "Area (mm^2)"],
        );
        let mut members = self.archive.clone();
        // best-first under the selected objective (for goodput that is
        // the smallest inverse, i.e. the highest goodput)
        members.sort_by(|&a, &b| {
            self.joint[a].objectives_for(self.cfg.objective)[0]
                .total_cmp(&self.joint[b].objectives_for(self.cfg.objective)[0])
        });
        for &ci in &members {
            let j = &self.joint[ci];
            let first = match self.cfg.objective {
                Objective::Latency => format!("{:.4}", j.latency_s),
                Objective::P99 => format!("{:.2}", j.p99_ms.unwrap_or(f64::NAN)),
                Objective::Goodput => {
                    format!("{:.1}", j.goodput_rps.unwrap_or(f64::NAN))
                }
            };
            t.row(&[
                self.candidates[ci].label.clone(),
                first,
                format!("{:.1}", j.energy_j),
                format!("{:.0}", j.area_mm2),
            ]);
        }
        out.push_str(&t.render());
        if self.archive.is_empty() {
            out.push_str(
                "(no feasible candidate satisfies the constraints — the frontier \
                 is empty; relax --max-area/--max-power or widen the axes)\n",
            );
        }

        // scatter: dominated feasible '.', infeasible 'x', frontier '*',
        // paper anchor 'P' (drawn last so it wins overlaps)
        let mut pts: Vec<(f64, f64, char)> = Vec::new();
        for j in &self.joint {
            if !self.archive.contains(&j.candidate) {
                let mark = if self.is_feasible(j.candidate) { '.' } else { 'x' };
                pts.push((j.latency_s, j.energy_j, mark));
            }
        }
        for &ci in &self.archive {
            pts.push((self.joint[ci].latency_s, self.joint[ci].energy_j, '*'));
        }
        let anchor = &self.joint[0];
        pts.push((anchor.latency_s, anchor.energy_j, 'P'));
        out.push('\n');
        out.push_str(&scatter_plot(
            "joint latency vs energy ('*' frontier, '.' dominated, 'x' infeasible, \
             'P' paper)",
            "latency (s)",
            "energy (J/step)",
            &pts,
        ));

        out.push_str(
            "convergence (exact dominated hypervolume vs ref = 2x the paper \
             anchor's objectives):\n",
        );
        for s in &self.convergence {
            out.push_str(&format!("  {}\n", s.render()));
        }

        if self.cfg.constraints.any() && !self.is_feasible(0) {
            out.push_str(&format!(
                "=> the paper's Table 2 configuration VIOLATES the constraints \
                 ({}; worst case {:.0} mm^2, {:.0} W) and cannot sit on the \
                 feasible frontier.\n",
                self.cfg.constraints.describe(),
                anchor.area_mm2,
                anchor.power_w,
            ));
        }
        // dominance verdict against feasible competitors only: an infeasible
        // candidate "beating" the anchor is not a deployable alternative
        let feasible_dominators: Vec<usize> = self
            .paper_dominators
            .iter()
            .copied()
            .filter(|&c| self.is_feasible(c))
            .collect();
        if feasible_dominators.is_empty() {
            out.push_str(
                "=> no feasible candidate jointly dominates the paper's Table 2 \
                 configuration.\n",
            );
        } else {
            let best = feasible_dominators
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    self.joint[a].latency_s.total_cmp(&self.joint[b].latency_s)
                })
                .expect("non-empty dominator set");
            let j = &self.joint[best];
            out.push_str(&format!(
                "=> the paper's Table 2 configuration is jointly dominated by {} \
                 feasible candidate(s); e.g. `{}`: {:+.1}% latency, {:+.1}% energy, \
                 {:+.1}% area (worst case across models) relative to paper.\n",
                feasible_dominators.len(),
                self.candidates[best].label,
                (j.latency_s / anchor.latency_s - 1.0) * 100.0,
                (j.energy_j / anchor.energy_j - 1.0) * 100.0,
                (j.area_mm2 / anchor.area_mm2 - 1.0) * 100.0,
            ));
        }
        out
    }

    /// Machine-readable artifact (`EXPLORE_*.json` with a `search` section).
    pub fn to_json(&self) -> Json {
        let ex = &self.cfg.explore;
        let axes = Json::Arr(
            ex.axes
                .iter()
                .map(|a| {
                    Json::obj([
                        ("name", Json::str(a.name.clone())),
                        (
                            "values",
                            Json::Arr(
                                a.values.iter().map(|v| Json::str(v.value_label())).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let candidates = Json::Arr(
            self.candidates
                .iter()
                .map(|c| {
                    Json::obj([
                        ("label", Json::str(c.label.clone())),
                        (
                            "method",
                            match c.method {
                                Some(m) => Json::str(m.name()),
                                None => Json::Null,
                            },
                        ),
                        (
                            "sched",
                            match c.sched {
                                Some(s) => Json::str(s.name()),
                                None => Json::Null,
                            },
                        ),
                        (
                            "overrides",
                            Json::Obj(
                                c.overrides
                                    .iter()
                                    .map(|o| {
                                        (o.axis_name().to_string(), Json::str(o.value_label()))
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let points = Json::Arr(
            self.cells
                .iter()
                .map(|p| {
                    Json::obj([
                        ("candidate", Json::int(p.variant)),
                        ("model", Json::str(p.model.name())),
                        ("method", Json::str(p.method.name())),
                        ("sched", Json::str(p.sched.name())),
                        ("latency_s", Json::num(p.latency_s)),
                        ("energy_j_per_step", Json::num(p.energy_j)),
                        ("area_mm2", Json::num(p.area_mm2)),
                        ("power_kw", Json::num(p.power_kw)),
                        ("mean_power_w", Json::num(p.mean_power_w)),
                        ("c_t", Json::num(p.c_t)),
                        ("retained", p.retained.map_or(Json::Null, Json::num)),
                        (
                            "serve_p99_ms",
                            p.serve.map_or(Json::Null, |s| Json::num(s.p99_ms)),
                        ),
                        (
                            "serve_goodput_rps",
                            p.serve.map_or(Json::Null, |s| Json::num(s.goodput_rps)),
                        ),
                    ])
                })
                .collect(),
        );
        let joint = Json::Arr(
            self.joint
                .iter()
                .map(|j| {
                    Json::obj([
                        ("candidate", Json::int(j.candidate)),
                        ("latency_s", Json::num(j.latency_s)),
                        ("energy_j_per_step", Json::num(j.energy_j)),
                        ("area_mm2", Json::num(j.area_mm2)),
                        ("power_w", Json::num(j.power_w)),
                        ("resilience", j.resilience.map_or(Json::Null, Json::num)),
                        ("p99_ms", j.p99_ms.map_or(Json::Null, Json::num)),
                        ("goodput_rps", j.goodput_rps.map_or(Json::Null, Json::num)),
                        ("feasible", Json::Bool(self.is_feasible(j.candidate))),
                        ("on_frontier", Json::Bool(self.archive.contains(&j.candidate))),
                        (
                            "cells",
                            Json::Arr(j.cells.iter().map(|&c| Json::int(c)).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        let frontier = Json::obj([
            (
                "members",
                Json::Arr(self.archive.iter().map(|&m| Json::int(m)).collect()),
            ),
            ("paper_point", Json::int(0)),
            ("paper_on_frontier", Json::Bool(self.archive.contains(&0))),
            (
                "paper_dominators",
                Json::Arr(
                    self.paper_dominators.iter().map(|&m| Json::int(m)).collect(),
                ),
            ),
        ]);
        let n_feasible = self.n_feasible();
        let feasibility = Json::obj([
            ("constrained", Json::Bool(self.cfg.constraints.any())),
            (
                "max_area_mm2",
                self.cfg.constraints.max_area_mm2.map_or(Json::Null, Json::num),
            ),
            (
                "max_power_w",
                self.cfg.constraints.max_power_w.map_or(Json::Null, Json::num),
            ),
            (
                "min_resilience",
                self.cfg
                    .constraints
                    .min_resilience
                    .as_ref()
                    .map_or(Json::Null, |mr| Json::num(mr.frac)),
            ),
            (
                "resilience_scenario",
                self.cfg
                    .constraints
                    .min_resilience
                    .as_ref()
                    .map_or(Json::Null, |mr| Json::str(mr.scenario.label())),
            ),
            ("feasible", Json::int(n_feasible)),
            (
                "infeasible",
                Json::int(self.candidates.len() - n_feasible),
            ),
            ("anchor_feasible", Json::Bool(self.is_feasible(0))),
        ]);
        let mut search = Json::obj([
            ("strategy", Json::str(self.cfg.strategy.name())),
            ("evaluations", Json::int(self.candidates.len())),
            ("surrogate_frac", Json::num(self.cfg.surrogate_frac)),
            ("feasibility", feasibility),
            (
                "convergence",
                Json::Arr(
                    self.convergence
                        .iter()
                        .map(|s| {
                            Json::obj([
                                ("generation", Json::int(s.generation)),
                                ("evaluations", Json::int(s.evaluations)),
                                ("feasible", Json::int(s.feasible)),
                                ("archive_size", Json::int(s.archive_size)),
                                ("hypervolume", Json::num(s.hypervolume)),
                                (
                                    "surrogate",
                                    s.surrogate.as_ref().map_or(Json::Null, |ss| {
                                        Json::obj([
                                            ("proposed", Json::int(ss.proposed)),
                                            ("simulated", Json::int(ss.simulated)),
                                            (
                                                "spearman",
                                                ss.spearman
                                                    .map_or(Json::Null, Json::num),
                                            ),
                                        ])
                                    }),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "hypervolume_ref",
                Json::Arr(self.hypervolume_ref.iter().map(|&v| Json::num(v)).collect()),
            ),
        ]);
        match self.cfg.strategy {
            SearchStrategy::Exhaustive => {}
            SearchStrategy::Random { samples, seed } => {
                search.push("samples", Json::int(samples));
                // string, not number: JSON numbers are f64 and would corrupt
                // u64 seeds above 2^53 (same policy as the top-level seed)
                search.push("strategy_seed", Json::str(seed.to_string()));
            }
            SearchStrategy::Evolutionary {
                population,
                generations,
                crossover_rate,
                mutation_rate,
                seed,
            } => {
                search.push("population", Json::int(population));
                search.push("generations", Json::int(generations));
                search.push("crossover_rate", Json::num(crossover_rate));
                search.push("mutation_rate", Json::num(mutation_rate));
                search.push("strategy_seed", Json::str(seed.to_string()));
            }
        }
        // throughput accounting: flat summaries of the preselection and the
        // memoization/re-timing layers (neither influences any reported
        // number — stripping these two objects from the artifact recovers
        // the uncached, unfiltered rendering byte for byte)
        let gens: Vec<&SurrogateStat> =
            self.convergence.iter().filter_map(|s| s.surrogate.as_ref()).collect();
        let proposed: usize = gens.iter().map(|s| s.proposed).sum();
        let simulated: usize = gens.iter().map(|s| s.simulated).sum();
        let rhos: Vec<f64> = gens.iter().filter_map(|s| s.spearman).collect();
        let surrogate = Json::obj([
            ("enabled", Json::Bool(self.cfg.surrogate_frac < 1.0)),
            ("frac", Json::num(self.cfg.surrogate_frac)),
            ("proposed", Json::int(proposed)),
            ("simulated", Json::int(simulated)),
            ("skipped", Json::int(proposed - simulated)),
            (
                "spearman_mean",
                if rhos.is_empty() {
                    Json::Null
                } else {
                    Json::num(rhos.iter().sum::<f64>() / rhos.len() as f64)
                },
            ),
        ]);
        Json::obj([
            ("explore", Json::str("design_space_search")),
            ("axes", axes),
            ("budget", Json::int(ex.budget)),
            ("seq_len", Json::int(ex.seq_len)),
            ("iters", Json::int(ex.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53 (same policy as BENCH_sweep.json)
            ("seed", Json::str(ex.seed.to_string())),
            ("base_dram", Json::str(ex.dram.name())),
            (
                "models",
                Json::Arr(ex.models.iter().map(|m| Json::str(m.name())).collect()),
            ),
            (
                "methods",
                Json::Arr(ex.methods.iter().map(|m| Json::str(m.name())).collect()),
            ),
            ("method_gene", Json::Bool(self.cfg.method_gene)),
            (
                "scheds",
                Json::Arr(ex.scheds.iter().map(|s| Json::str(s.name())).collect()),
            ),
            ("sched_gene", Json::Bool(self.cfg.sched_gene)),
            ("objective", Json::str(self.cfg.objective.name())),
            (
                "objectives",
                Json::Arr(vec![
                    Json::str(match self.cfg.objective {
                        Objective::Latency => "latency_s",
                        Objective::P99 => "p99_ms",
                        Objective::Goodput => "inverse_goodput_rps",
                    }),
                    Json::str("energy_j_per_step"),
                    Json::str("area_mm2"),
                ]),
            ),
            ("objective_mode", Json::str("worst_case_across_models")),
            (
                "serve_workload",
                self.cfg.serve_spec().map_or(Json::Null, |s| {
                    Json::obj([
                        ("arrivals", Json::str(s.arrivals.label())),
                        ("duration_s", Json::num(s.duration_s)),
                        ("slo_ms", Json::num(s.slo_ms)),
                        ("batch_close", Json::str(s.params.close.label())),
                    ])
                }),
            ),
            ("candidates", candidates),
            ("points", points),
            ("joint", joint),
            ("frontier", frontier),
            ("search", search),
            ("cache", self.eval.to_json()),
            ("surrogate", surrogate),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramKind, ModelId};
    use crate::coordinator::explore::parse_axes;

    fn axes_2x2() -> Vec<Axis> {
        parse_axes("tiles=36:64,dram").expect("axes parse")
    }

    #[test]
    fn mutation_always_moves_when_possible() {
        let axes = axes_2x2();
        let space = GenomeSpace::new(&axes, None, None);
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let g = random_genome(&space.card, &mut rng);
            let m = mutate(&space.card, &g, 0.0, &mut rng); // rate 0 -> forced move
            assert_ne!(g, m, "offspring must differ from parent");
            for (i, &v) in m.iter().enumerate() {
                assert!(v < space.card[i]);
            }
        }
    }

    #[test]
    fn resample_never_returns_current() {
        let mut rng = Rng::new(9);
        for n in 2..6 {
            for cur in 0..n {
                for _ in 0..50 {
                    let v = resample_different(n, cur, &mut rng);
                    assert!(v < n && v != cur, "n={n} cur={cur} v={v}");
                }
            }
        }
    }

    #[test]
    fn crossover_only_mixes_parent_genes() {
        let mut rng = Rng::new(21);
        let a = vec![0usize, 0, 0, 0];
        let b = vec![1usize, 1, 1, 1];
        let mut saw_a = false;
        let mut saw_b = false;
        for _ in 0..100 {
            let c = uniform_crossover(&a, &b, &mut rng);
            assert_eq!(c.len(), 4);
            for (i, &g) in c.iter().enumerate() {
                assert!(g == a[i] || g == b[i], "gene {i} from neither parent");
                saw_a |= g == a[i];
                saw_b |= g == b[i];
            }
        }
        assert!(saw_a && saw_b, "crossover never drew from one parent");
    }

    #[test]
    fn fresh_candidates_dedup_and_skip_anchor() {
        let axes = parse_axes("tiles=56:64").expect("axes parse");
        let space = GenomeSpace::new(&axes, None, None);
        // OlmoE's paper platform has 56 tiles -> genome [0] is the anchor
        let bases = vec![HwConfig::paper_for_model(ModelId::OlmoE_1B_7B, DramKind::Hbm2)];
        let mut seen = BTreeSet::new();
        let got = fresh_candidates(
            &space,
            vec![vec![0], vec![1], vec![1], vec![0]],
            &bases,
            None,
            None,
            &mut seen,
        );
        assert_eq!(got.len(), 1, "anchor-equal and duplicate genomes dropped");
        assert_eq!(got[0].label, "tiles=64");
        assert_eq!(got[0].method, None);
        assert_eq!(got[0].sched, None);
        // dropped genomes are registered too, so re-proposals skip early
        assert!(seen.contains(&vec![0]));
        assert!(seen.contains(&vec![1]));
        let again =
            fresh_candidates(&space, vec![vec![1], vec![0]], &bases, None, None, &mut seen);
        assert!(again.is_empty());
    }

    #[test]
    fn method_gene_widens_the_genome_and_anchor_skip() {
        let axes = parse_axes("tiles=56:64").expect("axes parse");
        let methods = [Method::Baseline, Method::MozartC];
        let space = GenomeSpace::new(&axes, Some(&methods), None);
        assert_eq!(space.card, vec![2, 2]);
        let (ov, m, s) = space.decode(&[1, 0]);
        assert_eq!(ov, vec![HwOverride::MoeTiles(64)]);
        assert_eq!(m, Some(Method::Baseline));
        assert_eq!(s, None);

        let bases = vec![HwConfig::paper_for_model(ModelId::OlmoE_1B_7B, DramKind::Hbm2)];
        let mut seen = BTreeSet::new();
        let got = fresh_candidates(
            &space,
            // anchor hw + anchor method (skipped), anchor hw + other method
            // (kept), other hw + anchor method (kept)
            vec![vec![0, 1], vec![0, 0], vec![1, 1]],
            &bases,
            Some(Method::MozartC),
            None,
            &mut seen,
        );
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].label, "tiles=56 method=Baseline");
        assert_eq!(got[0].method, Some(Method::Baseline));
        assert_eq!(got[1].label, "tiles=64 method=Mozart-C");
    }

    #[test]
    fn sched_gene_trails_the_method_gene() {
        let axes = parse_axes("tiles=56:64").expect("axes parse");
        let methods = [Method::Baseline, Method::MozartC];
        let scheds = [SchedPolicy::Streaming, SchedPolicy::Heft];
        let space = GenomeSpace::new(&axes, Some(&methods), Some(&scheds));
        assert_eq!(space.card, vec![2, 2, 2]);
        let (ov, m, s) = space.decode(&[1, 0, 1]);
        assert_eq!(ov, vec![HwOverride::MoeTiles(64)]);
        assert_eq!(m, Some(Method::Baseline));
        assert_eq!(s, Some(SchedPolicy::Heft));

        // without the method gene the sched gene sits right after the axes
        let space = GenomeSpace::new(&axes, None, Some(&scheds));
        assert_eq!(space.card, vec![2, 2]);
        let (_, m, s) = space.decode(&[0, 1]);
        assert_eq!(m, None);
        assert_eq!(s, Some(SchedPolicy::Heft));

        let bases = vec![HwConfig::paper_for_model(ModelId::OlmoE_1B_7B, DramKind::Hbm2)];
        let mut seen = BTreeSet::new();
        let got = fresh_candidates(
            &space,
            // anchor hw + anchor sched (skipped), anchor hw + other sched
            // (kept), other hw + anchor sched (kept)
            vec![vec![0, 0], vec![0, 1], vec![1, 0]],
            &bases,
            None,
            Some(SchedPolicy::Streaming),
            &mut seen,
        );
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].label, "tiles=56 sched=heft");
        assert_eq!(got[0].sched, Some(SchedPolicy::Heft));
        assert_eq!(got[1].label, "tiles=64 sched=streaming");
        assert_eq!(got[1].sched, Some(SchedPolicy::Streaming));
    }

    #[test]
    fn preferred_sched_is_streaming_when_available() {
        assert_eq!(preferred_sched(&SchedPolicy::ALL), SchedPolicy::Streaming);
        assert_eq!(
            preferred_sched(&[SchedPolicy::Heft, SchedPolicy::List]),
            SchedPolicy::Heft
        );
    }

    fn tiny_search(axes: &str, strategy: SearchStrategy) -> SearchConfig {
        let explore = ExploreConfig {
            axes: parse_axes(axes).expect("axes parse"),
            budget: 0,
            models: vec![ModelId::OlmoE_1B_7B],
            methods: vec![Method::MozartC],
            scheds: vec![SchedPolicy::Streaming],
            seq_len: 64,
            dram: DramKind::Hbm2,
            iters: 1,
            seed: 7,
            threads: 1,
            eval: crate::coordinator::cache::EvalOptions::default(),
        };
        SearchConfig::new(explore, strategy)
    }

    #[test]
    fn caching_layers_never_change_reported_numbers() {
        // a timing-only axis: every candidate shares the anchor's topology,
        // so the pooled delta re-timing path covers every non-anchor cell
        let strategy = SearchStrategy::Evolutionary {
            population: 4,
            generations: 3,
            crossover_rate: 0.9,
            mutation_rate: 0.4,
            seed: 11,
        };
        let fast = tiny_search("freq=0.8:1.2:1.4", strategy);
        let mut slow = fast.clone();
        slow.explore.eval = crate::coordinator::cache::EvalOptions {
            cache: false,
            retime: false,
            ..Default::default()
        };
        let a = search(&fast);
        let b = search(&slow);
        assert_eq!(a.archive, b.archive);
        assert_eq!(a.candidates.len(), b.candidates.len());
        for (x, y) in a.joint.iter().zip(b.joint.iter()) {
            assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits());
        }
        for (x, y) in a.convergence.iter().zip(b.convergence.iter()) {
            assert_eq!(x.hypervolume.to_bits(), y.hypervolume.to_bits());
        }
        // the throughput layers actually engaged on the fast run
        assert!(a.eval.cache_enabled && a.eval.retime_enabled);
        assert!(!b.eval.cache_enabled && !b.eval.retime_enabled);
        assert!(a.eval.cache.misses > 0);
        assert!(a.eval.retimes > 0, "freq-only deltas should re-time");
        assert_eq!(b.eval.retimes, 0);
    }

    #[test]
    fn surrogate_preselection_filters_and_logs() {
        // 12 draws over a 4-genome space: several distinct fresh offspring,
        // so frac=0.5 must actually skip some
        let strategy = SearchStrategy::Random { samples: 12, seed: 3 };
        let mut cfg = tiny_search("freq=0.8:1.2,tiles=36:64", strategy);
        cfg.surrogate_frac = 0.5;
        let out = search(&cfg);
        let stats: Vec<&SurrogateStat> =
            out.convergence.iter().filter_map(|s| s.surrogate.as_ref()).collect();
        assert!(!stats.is_empty(), "frac < 1 must log surrogate stats");
        for s in &stats {
            assert!(s.simulated <= s.proposed);
            assert!(s.simulated >= 1);
            if let Some(r) = s.spearman {
                assert!((-1.0..=1.0).contains(&r));
            }
        }
        assert!(
            stats.iter().any(|s| s.simulated < s.proposed),
            "half the offspring should be skipped"
        );
        // every archive member still points at an evaluated candidate, and
        // the artifact carries the throughput sections
        assert!(out.archive.iter().all(|&c| c < out.candidates.len()));
        let rendered = out.to_json().render();
        assert!(rendered.contains("\"surrogate\""));
        assert!(rendered.contains("\"cache\""));

        // frac = 1.0 (the default) never filters and never logs
        let full = search(&tiny_search(
            "freq=0.8:1.2,tiles=36:64",
            SearchStrategy::Random { samples: 12, seed: 3 },
        ));
        assert!(full.convergence.iter().all(|s| s.surrogate.is_none()));
        assert!(out.candidates.len() <= full.candidates.len());
    }

    #[test]
    fn serving_objective_search_is_deterministic_and_scored() {
        let strategy = SearchStrategy::Evolutionary {
            population: 3,
            generations: 2,
            crossover_rate: 0.9,
            mutation_rate: 0.5,
            seed: 5,
        };
        let mut cfg = tiny_search("freq=0.8:1.2,tiles=36:64", strategy);
        cfg.objective = Objective::P99;
        // a short workload keeps the test fast without losing coverage
        let mut spec = ServeEvalSpec::paper_default();
        spec.duration_s = 0.5;
        cfg.serve = Some(spec);
        let a = search(&cfg);
        let b = search(&cfg);
        assert!(a.candidates.len() > 1);
        // every candidate carries serving metrics and is ranked by them
        for j in &a.joint {
            let p99 = j.p99_ms.expect("p99 scored on every candidate");
            let good = j.goodput_rps.expect("goodput scored on every candidate");
            assert!(p99 > 0.0 && good >= 0.0, "p99={p99} goodput={good}");
            assert_eq!(j.objectives_for(Objective::P99)[0], p99);
        }
        assert_eq!(a.archive, b.archive, "seeded serving search must reproduce");
        for (x, y) in a.joint.iter().zip(b.joint.iter()) {
            assert_eq!(x.p99_ms.unwrap().to_bits(), y.p99_ms.unwrap().to_bits());
            assert_eq!(
                x.goodput_rps.unwrap().to_bits(),
                y.goodput_rps.unwrap().to_bits()
            );
        }
        assert_eq!(a.hypervolume_ref.len(), 3);
        assert_eq!(a.hypervolume_ref[0], 2.0 * a.joint[0].p99_ms.unwrap());
        // the artifact names the objective and echoes the workload
        let rendered = a.to_json().render_pretty();
        assert!(rendered.contains("\"objective\": \"p99\""));
        assert!(rendered.contains("\"serve_workload\""));
        assert!(rendered.contains("\"p99_ms\""));
        assert!(a.render_markdown().contains("Serve p99 (ms)"));
        // the default latency objective never scores serving at all
        let plain = search(&tiny_search("freq=0.8:1.2,tiles=36:64", strategy));
        assert!(plain.joint.iter().all(|j| j.p99_ms.is_none()));
        assert!(plain.cells.iter().all(|c| c.serve.is_none()));
        assert!(!plain
            .to_json()
            .render_pretty()
            .contains("\"serve_workload\": {"));
    }

    #[test]
    fn objective_parse_round_trips() {
        for obj in [Objective::Latency, Objective::P99, Objective::Goodput] {
            assert_eq!(Objective::parse(obj.name()), Ok(obj));
        }
        assert!(Objective::parse("throughput").is_err());
        assert_eq!(Objective::Latency.name(), "latency");
        assert!(!Objective::Latency.needs_serve());
        assert!(Objective::P99.needs_serve() && Objective::Goodput.needs_serve());
    }

    #[test]
    fn goodput_objective_inverts_and_guards_zero() {
        let jp = JointPoint {
            candidate: 1,
            latency_s: 2.0,
            energy_j: 3.0,
            area_mm2: 4.0,
            power_w: 5.0,
            resilience: None,
            p99_ms: Some(40.0),
            goodput_rps: Some(100.0),
            cells: vec![],
        };
        assert_eq!(jp.objectives(), vec![2.0, 3.0, 4.0]);
        assert_eq!(jp.objectives_for(Objective::P99)[0], 40.0);
        let inv = jp.objectives_for(Objective::Goodput)[0];
        assert!((inv - 0.01).abs() < 1e-6, "inverse of 100 req/s, got {inv}");
        // higher goodput -> smaller minimized value
        let mut better = jp.clone();
        better.goodput_rps = Some(200.0);
        assert!(
            better.objectives_for(Objective::Goodput)[0] < inv,
            "goodput must be maximized"
        );
        // zero goodput stays finite so the exact hypervolume never sees inf
        let mut dead = jp;
        dead.goodput_rps = Some(0.0);
        assert!(dead.objectives_for(Objective::Goodput)[0].is_finite());
    }

    #[test]
    fn preferred_method_is_mozart_c_when_available() {
        assert_eq!(preferred_method(&Method::ALL), Method::MozartC);
        assert_eq!(
            preferred_method(&[Method::Baseline, Method::MozartA]),
            Method::MozartA
        );
    }

    #[test]
    fn constraints_violation_and_describe() {
        let c = Constraints::none();
        assert!(!c.any());
        assert!(c.feasible(1e9, 1e9, None));
        assert_eq!(c.describe(), "");

        let c = Constraints {
            max_area_mm2: Some(1000.0),
            max_power_w: Some(50.0),
            ..Constraints::none()
        };
        assert!(c.any());
        assert!(c.feasible(1000.0, 50.0, None), "caps are inclusive");
        assert!(!c.feasible(1001.0, 50.0, None));
        assert!(!c.feasible(1000.0, 51.0, None));
        // violations accumulate across caps and scale with the excess
        let v1 = c.violation(1500.0, 50.0, None);
        let v2 = c.violation(2000.0, 50.0, None);
        let v3 = c.violation(2000.0, 100.0, None);
        assert!(v1 > 0.0 && v2 > v1 && v3 > v2);
        assert_eq!(c.violation(500.0, 25.0, None), 0.0);
        assert_eq!(c.describe(), "area <= 1000 mm^2, power <= 50 W");
    }

    #[test]
    fn resilience_floor_gates_feasibility() {
        let c = Constraints {
            min_resilience: Some(MinResilience {
                frac: 0.8,
                scenario: FaultScenario::parse("dead-chiplet:2", 7).unwrap(),
            }),
            ..Constraints::none()
        };
        assert!(c.any());
        assert!(c.fault_scenario().is_some());
        assert!(c.feasible(1e9, 1e9, Some(0.8)), "floor is inclusive");
        assert!(c.feasible(1e9, 1e9, Some(0.95)));
        assert!(!c.feasible(1e9, 1e9, Some(0.5)));
        // a missing resilience evaluation counts as a full violation
        assert!(!c.feasible(1e9, 1e9, None));
        assert_eq!(c.violation(1.0, 1.0, None), 1.0);
        // violations grow as retained throughput falls
        let v1 = c.violation(1.0, 1.0, Some(0.7));
        let v2 = c.violation(1.0, 1.0, Some(0.4));
        assert!(v1 > 0.0 && v2 > v1);
        assert_eq!(
            c.describe(),
            "resilience >= 0.8 under dead-chiplet:2"
        );
        assert_eq!(Constraints::none().fault_scenario(), None);
    }
}
