//! Guided design-space search (`mozart explore --strategy ...`).
//!
//! PR 3's explorer enumerates a declarative axis grid exhaustively. This
//! module turns the same cell-evaluation path into a *search*: a
//! [`SearchStrategy`] proposes hardware candidates over the axis value sets,
//! each candidate is evaluated through the explorer's shared cell path on
//! the work-stealing pool ([`parallel_map`]), and an incremental Pareto archive
//! ([`pareto::Frontier`]) tracks the non-dominated set in `O(n)` per point
//! instead of re-reducing the whole cloud per generation.
//!
//! **Joint frontiers.** The paper tunes the platform per model; the search
//! answers the harder co-design question "which hardware is good for *every*
//! model". A candidate's objectives are the **worst case** (maximum, since
//! all objectives are minimized) of latency / energy / area across every
//! configured (model × method) cell, with all per-cell values recorded. With
//! one model the joint frontier degenerates to that model's frontier.
//!
//! **Determinism.** All strategy randomness comes from one seeded
//! [`Rng`] driven on the coordinating thread; candidate evaluation derives
//! its randomness from each cell's own config (same discipline as the sweep
//! executor). Two runs with the same [`SearchConfig`] are therefore
//! bit-identical regardless of thread count — asserted in
//! `tests/integration_search.rs` and checked by `mozart bench --grid search`.
//!
//! **Convergence.** After every generation the archive's hypervolume proxy
//! (vs a fixed reference of 2× the paper anchor's objectives) is recorded;
//! the curve lands in the `EXPLORE_*.json` artifact's `search` section.

use std::collections::BTreeSet;

use crate::config::{HwConfig, HwOverride};
use crate::coordinator::explore::{self, Axis, ExploreConfig, ExplorePoint};
use crate::coordinator::sweep::{parallel_map, SweepOptions};
use crate::metrics::pareto;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::{scatter_plot, Table};

/// How the search proposes hardware candidates over the axis grid.
///
/// # Examples
///
/// A tiny seeded random search over one axis; the same seed reproduces the
/// same archive bit for bit:
///
/// ```
/// use mozart::config::{DramKind, HwOverride, Method, ModelId};
/// use mozart::coordinator::explore::{Axis, ExploreConfig};
/// use mozart::coordinator::search::{search, SearchConfig, SearchStrategy};
///
/// let explore = ExploreConfig {
///     axes: vec![Axis {
///         name: "tiles".to_string(),
///         values: vec![HwOverride::MoeTiles(36), HwOverride::MoeTiles(64)],
///     }],
///     budget: 0,
///     models: vec![ModelId::OlmoE_1B_7B],
///     methods: vec![Method::MozartC],
///     seq_len: 64,
///     dram: DramKind::Hbm2,
///     iters: 1,
///     seed: 7,
///     threads: 1,
/// };
/// let cfg = SearchConfig {
///     explore,
///     strategy: SearchStrategy::Random { samples: 2, seed: 7 },
/// };
/// let a = search(&cfg);
/// let b = search(&cfg);
/// assert_eq!(a.archive, b.archive); // deterministic for a fixed seed
/// assert!(!a.convergence.is_empty());
/// assert!(a.archive.iter().all(|&c| c < a.candidates.len()));
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SearchStrategy {
    /// Enumerate the full axis product (subject to the explore config's
    /// `budget` even-stride subsample) — the PR-3 grid semantics, now fed
    /// through the streaming archive.
    Exhaustive,
    /// Uniform seeded sampling of the axis product: `samples` proposals,
    /// de-duplicated, evaluated in one generation.
    Random {
        /// Number of candidate proposals (duplicates are evaluated once).
        samples: usize,
        /// Strategy RNG seed (independent of the simulation seed).
        seed: u64,
    },
    /// (μ+λ)-style evolutionary search: a seeded random initial population,
    /// then per generation every offspring is a mutated copy of a uniformly
    /// chosen *archive* member (elitist parent pool; mutation resamples each
    /// gene with probability `mutation_rate`, forcing at least one gene to
    /// move). Already-evaluated genomes are never re-simulated.
    Evolutionary {
        /// Proposals per generation.
        population: usize,
        /// Number of generations (the initial population is generation 1).
        generations: usize,
        /// Per-gene mutation probability in `[0, 1]`.
        mutation_rate: f64,
        /// Strategy RNG seed (independent of the simulation seed).
        seed: u64,
    },
}

impl SearchStrategy {
    /// Stable CLI / JSON name of the strategy kind.
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Exhaustive => "exhaustive",
            SearchStrategy::Random { .. } => "random",
            SearchStrategy::Evolutionary { .. } => "evolutionary",
        }
    }

    /// Human-readable one-line description including the parameters.
    pub fn describe(&self) -> String {
        match *self {
            SearchStrategy::Exhaustive => "exhaustive".to_string(),
            SearchStrategy::Random { samples, seed } => {
                format!("random (samples={samples}, seed={seed})")
            }
            SearchStrategy::Evolutionary {
                population,
                generations,
                mutation_rate,
                seed,
            } => format!(
                "evolutionary (population={population}, generations={generations}, \
                 mutation_rate={mutation_rate}, seed={seed})"
            ),
        }
    }
}

/// Full specification of one guided search run: the design space and
/// workload (reusing [`ExploreConfig`]) plus the proposal strategy.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Axes, models, methods, workload, simulation seed, and thread count.
    /// `budget` caps the grid only under [`SearchStrategy::Exhaustive`].
    pub explore: ExploreConfig,
    /// Candidate-proposal strategy.
    pub strategy: SearchStrategy,
}

/// One proposed hardware candidate (candidate 0 is always the paper anchor).
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Overrides applied on top of the per-model paper platform; empty for
    /// the anchor.
    pub overrides: Vec<HwOverride>,
    /// Display label (`"paper (Table 2)"` or `"tiles=36 dram=SSD"` style).
    pub label: String,
    /// Per-axis value indices the strategy proposed; `None` for the anchor,
    /// which is not a grid point.
    pub genome: Option<Vec<usize>>,
}

/// A candidate's joint (worst-case across models) objectives.
#[derive(Clone, Debug)]
pub struct JointPoint {
    /// Index into [`SearchOutcome::candidates`].
    pub candidate: usize,
    /// Worst mean step latency across all evaluated cells (s) — minimized.
    pub latency_s: f64,
    /// Worst energy per step across all evaluated cells (J) — minimized.
    pub energy_j: f64,
    /// Worst die area across all evaluated cells (mm²) — minimized.
    pub area_mm2: f64,
    /// Indices of this candidate's per-(model × method) cells in
    /// [`SearchOutcome::cells`].
    pub cells: Vec<usize>,
}

impl JointPoint {
    /// The minimized joint objective vector (latency, energy, area).
    pub fn objectives(&self) -> Vec<f64> {
        vec![self.latency_s, self.energy_j, self.area_mm2]
    }
}

/// Archive/convergence snapshot after one generation.
#[derive(Clone, Debug)]
pub struct GenStat {
    /// 1-based generation number.
    pub generation: usize,
    /// Cumulative unique candidates evaluated so far (incl. the anchor).
    pub evaluations: usize,
    /// Archive size after this generation.
    pub archive_size: usize,
    /// Hypervolume proxy of the archive vs the fixed reference point.
    pub hypervolume: f64,
}

impl GenStat {
    /// One-line rendering, shared by the CLI's live per-generation progress
    /// and the report's convergence section so the two never drift.
    pub fn render(&self) -> String {
        format!(
            "gen {:>2}: {:>4} candidates evaluated, archive {:>3}, hypervolume {:.4}",
            self.generation, self.evaluations, self.archive_size, self.hypervolume
        )
    }
}

/// Everything one guided search run produced.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// The configuration the run used.
    pub cfg: SearchConfig,
    /// Every evaluated candidate (candidate 0 is the paper anchor).
    pub candidates: Vec<Candidate>,
    /// Every evaluated (candidate × model × method) cell; the point's
    /// `variant` field holds the candidate index.
    pub cells: Vec<ExplorePoint>,
    /// Joint worst-case objectives, aligned with `candidates`.
    pub joint: Vec<JointPoint>,
    /// Candidate indices on the joint Pareto frontier, sorted ascending.
    pub archive: Vec<usize>,
    /// Candidate indices that jointly dominate the paper anchor; empty iff
    /// the anchor is itself on the joint frontier.
    pub paper_dominators: Vec<usize>,
    /// Per-generation convergence curve.
    pub convergence: Vec<GenStat>,
    /// Reference point of the hypervolume proxy (2× the anchor objectives).
    pub hypervolume_ref: Vec<f64>,
}

/// Evaluate a batch of fresh candidates over the work-stealing pool and fold
/// them into the outcome state. Cells are appended candidate-major (models
/// outer, methods inner), so a candidate's cells are contiguous.
///
/// A candidate whose overrides are a no-op for one model would simulate a
/// cell bit-identical to the anchor's (identical `ExperimentConfig`), so
/// that cell reuses candidate 0's result instead of re-running the
/// discrete-event simulation — the search-side mirror of the per-model
/// anchor-duplicate skip in [`explore::explore`].
fn eval_batch(
    ex: &ExploreConfig,
    bases: &[HwConfig],
    batch: Vec<Candidate>,
    candidates: &mut Vec<Candidate>,
    cells: &mut Vec<ExplorePoint>,
    joint: &mut Vec<JointPoint>,
    archive: &mut pareto::Frontier,
) {
    if batch.is_empty() {
        return;
    }
    let first = candidates.len();
    let n_models = ex.models.len();
    let n_methods = ex.methods.len();
    // which (candidate, model) pairs can reuse the anchor's cells (none
    // while evaluating the anchor batch itself)
    let mut reuse = vec![false; batch.len() * n_models];
    if first > 0 {
        for (off, cand) in batch.iter().enumerate() {
            for mi in 0..n_models {
                reuse[off * n_models + mi] =
                    explore::is_anchor_combo(&cand.overrides, &bases[mi]);
            }
        }
    }
    let mut specs: Vec<(usize, usize, usize)> = Vec::new();
    for off in 0..batch.len() {
        for mi in 0..n_models {
            if reuse[off * n_models + mi] {
                continue;
            }
            for ki in 0..n_methods {
                specs.push((off, mi, ki));
            }
        }
    }
    let threads = SweepOptions { threads: ex.threads }.effective_threads(specs.len());
    let pts = parallel_map(&specs, threads, |&(off, mi, ki)| {
        explore::eval_point(
            ex,
            &batch[off].overrides,
            first + off,
            ex.models[mi],
            ex.methods[ki],
        )
    });

    let mut fresh = pts.into_iter();
    for (off, cand) in batch.into_iter().enumerate() {
        let ci = first + off;
        let mut latency_s = 0.0f64;
        let mut energy_j = 0.0f64;
        let mut area_mm2 = 0.0f64;
        let mut cell_idx = Vec::with_capacity(n_models * n_methods);
        for mi in 0..n_models {
            for ki in 0..n_methods {
                let p = if reuse[off * n_models + mi] {
                    // the anchor's cells sit at the head of `cells` in the
                    // same (model-major, method-minor) order
                    let mut anchor_cell = cells[mi * n_methods + ki].clone();
                    anchor_cell.variant = ci;
                    anchor_cell
                } else {
                    fresh.next().expect("one simulated point per spec")
                };
                latency_s = latency_s.max(p.latency_s);
                energy_j = energy_j.max(p.energy_j);
                area_mm2 = area_mm2.max(p.area_mm2);
                cell_idx.push(cells.len());
                cells.push(p);
            }
        }
        let jp = JointPoint {
            candidate: ci,
            latency_s,
            energy_j,
            area_mm2,
            cells: cell_idx,
        };
        archive.insert(ci, &jp.objectives());
        joint.push(jp);
        candidates.push(cand);
    }
}

/// Turn proposed genomes into fresh [`Candidate`]s: drops genomes already
/// seen and combos that re-describe the paper anchor for every configured
/// model (the anchor is candidate 0 already). Every inspected genome —
/// including dropped ones — is registered in `seen`, so a re-proposal skips
/// the override rebuild and anchor check next time.
fn fresh_candidates(
    axes: &[Axis],
    genomes: Vec<Vec<usize>>,
    bases: &[HwConfig],
    seen: &mut BTreeSet<Vec<usize>>,
) -> Vec<Candidate> {
    let mut out: Vec<Candidate> = Vec::new();
    for g in genomes {
        if seen.contains(&g) {
            continue;
        }
        seen.insert(g.clone());
        let overrides: Vec<HwOverride> = axes
            .iter()
            .zip(g.iter())
            .map(|(a, &i)| a.values[i])
            .collect();
        if bases.iter().all(|b| explore::is_anchor_combo(&overrides, b)) {
            continue;
        }
        let label = overrides
            .iter()
            .map(|o| o.label())
            .collect::<Vec<_>>()
            .join(" ");
        out.push(Candidate {
            overrides,
            label,
            genome: Some(g),
        });
    }
    out
}

/// One uniformly random genome.
fn random_genome(axes: &[Axis], rng: &mut Rng) -> Vec<usize> {
    axes.iter().map(|a| rng.below(a.values.len())).collect()
}

/// Resample an index in `[0, n)` different from `cur` (requires `n > 1`).
fn resample_different(n: usize, cur: usize, rng: &mut Rng) -> usize {
    let j = rng.below(n - 1);
    if j >= cur {
        j + 1
    } else {
        j
    }
}

/// Mutate a genome: each gene moves to a different value of its axis with
/// probability `rate`; if nothing moved, one mutable gene is forced to move
/// so offspring always explore (when any axis has more than one value).
fn mutate(axes: &[Axis], genome: &[usize], rate: f64, rng: &mut Rng) -> Vec<usize> {
    let mut g = genome.to_vec();
    let mut changed = false;
    for (i, a) in axes.iter().enumerate() {
        if a.values.len() > 1 && rng.f64() < rate {
            g[i] = resample_different(a.values.len(), g[i], rng);
            changed = true;
        }
    }
    if !changed {
        let mutable: Vec<usize> = axes
            .iter()
            .enumerate()
            .filter(|(_, a)| a.values.len() > 1)
            .map(|(i, _)| i)
            .collect();
        if !mutable.is_empty() {
            let i = mutable[rng.below(mutable.len())];
            g[i] = resample_different(axes[i].values.len(), g[i], rng);
        }
    }
    g
}

/// Run a guided search (see [`search_with`] for the progress-callback form).
pub fn search(cfg: &SearchConfig) -> SearchOutcome {
    search_with(cfg, |_| {})
}

/// Run a guided search, invoking `on_generation` with each [`GenStat`] as it
/// is recorded (the CLI prints these as per-generation progress).
/// Deterministic for a fixed config regardless of `threads`.
pub fn search_with(
    cfg: &SearchConfig,
    mut on_generation: impl FnMut(&GenStat),
) -> SearchOutcome {
    let ex = &cfg.explore;
    let axes = &ex.axes;
    let bases: Vec<HwConfig> = ex
        .models
        .iter()
        .map(|&m| HwConfig::paper_for_model(m, ex.dram))
        .collect();

    let mut candidates: Vec<Candidate> = Vec::new();
    let mut cells: Vec<ExplorePoint> = Vec::new();
    let mut joint: Vec<JointPoint> = Vec::new();
    let mut archive = pareto::Frontier::new();
    let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
    let mut convergence: Vec<GenStat> = Vec::new();

    // the paper anchor is always candidate 0 and seeds both the archive and
    // the hypervolume reference point
    eval_batch(
        ex,
        &bases,
        vec![Candidate {
            overrides: Vec::new(),
            label: "paper (Table 2)".to_string(),
            genome: None,
        }],
        &mut candidates,
        &mut cells,
        &mut joint,
        &mut archive,
    );
    let hypervolume_ref: Vec<f64> =
        joint[0].objectives().iter().map(|v| v * 2.0).collect();

    // one macro per generation: evaluate a batch of genomes, then record
    let mut run_generation = |generation: usize,
                              genomes: Vec<Vec<usize>>,
                              candidates: &mut Vec<Candidate>,
                              cells: &mut Vec<ExplorePoint>,
                              joint: &mut Vec<JointPoint>,
                              archive: &mut pareto::Frontier,
                              seen: &mut BTreeSet<Vec<usize>>,
                              convergence: &mut Vec<GenStat>| {
        let batch = fresh_candidates(axes, genomes, &bases, seen);
        eval_batch(ex, &bases, batch, candidates, cells, joint, archive);
        let stat = GenStat {
            generation,
            evaluations: candidates.len(),
            archive_size: archive.len(),
            hypervolume: archive.hypervolume_proxy(&hypervolume_ref),
        };
        on_generation(&stat);
        convergence.push(stat);
    };

    match cfg.strategy {
        SearchStrategy::Exhaustive => {
            run_generation(
                1,
                explore::grid_genomes(axes, ex.budget),
                &mut candidates,
                &mut cells,
                &mut joint,
                &mut archive,
                &mut seen,
                &mut convergence,
            );
        }
        SearchStrategy::Random { samples, seed } => {
            let mut rng = Rng::new(seed ^ 0x5EA2_C417);
            let genomes: Vec<Vec<usize>> =
                (0..samples).map(|_| random_genome(axes, &mut rng)).collect();
            run_generation(
                1,
                genomes,
                &mut candidates,
                &mut cells,
                &mut joint,
                &mut archive,
                &mut seen,
                &mut convergence,
            );
        }
        SearchStrategy::Evolutionary {
            population,
            generations,
            mutation_rate,
            seed,
        } => {
            let population = population.max(1);
            let mut rng = Rng::new(seed ^ 0xE501_7104);
            for g in 0..generations.max(1) {
                let genomes: Vec<Vec<usize>> = if g == 0 {
                    (0..population).map(|_| random_genome(axes, &mut rng)).collect()
                } else {
                    // elitist parent pool: every archive member that is a
                    // grid point (the anchor has no genome)
                    let parents: Vec<usize> = archive
                        .keys()
                        .into_iter()
                        .filter(|&k| candidates[k].genome.is_some())
                        .collect();
                    (0..population)
                        .map(|_| {
                            if parents.is_empty() {
                                random_genome(axes, &mut rng)
                            } else {
                                let p = parents[rng.below(parents.len())];
                                let genome = candidates[p]
                                    .genome
                                    .as_ref()
                                    .expect("parents are genome-bearing");
                                mutate(axes, genome, mutation_rate, &mut rng)
                            }
                        })
                        .collect()
                };
                run_generation(
                    g + 1,
                    genomes,
                    &mut candidates,
                    &mut cells,
                    &mut joint,
                    &mut archive,
                    &mut seen,
                    &mut convergence,
                );
            }
        }
    }

    let joint_objs: Vec<Vec<f64>> = joint.iter().map(|j| j.objectives()).collect();
    let paper_dominators = pareto::dominators(&joint_objs[0], &joint_objs);
    SearchOutcome {
        cfg: cfg.clone(),
        candidates,
        cells,
        joint,
        archive: archive.keys(),
        paper_dominators,
        convergence,
        hypervolume_ref,
    }
}

impl SearchOutcome {
    /// Rendered markdown report: axis summary, the joint frontier table,
    /// an ASCII latency/energy scatter, the per-generation convergence
    /// curve, and the verdict on the paper's Table 2 configuration.
    pub fn render_markdown(&self) -> String {
        let ex = &self.cfg.explore;
        let mut t = Table::new("Design-space axes", &["Axis", "Values"]);
        for a in &ex.axes {
            t.row(&[
                a.name.clone(),
                a.values
                    .iter()
                    .map(|v| v.value_label())
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "({} candidates incl. the paper anchor; {} cells; strategy {})\n\n",
            self.candidates.len(),
            self.cells.len(),
            self.cfg.strategy.describe()
        ));

        let models = ex
            .models
            .iter()
            .map(|m| m.name())
            .collect::<Vec<_>>()
            .join(", ");
        let title = format!(
            "Joint Pareto frontier — worst case across [{models}] \
             ({} of {} candidates non-dominated)",
            self.archive.len(),
            self.candidates.len()
        );
        let mut t = Table::new(
            &title,
            &["Candidate", "Latency (s)", "Energy (J/step)", "Area (mm^2)"],
        );
        let mut members = self.archive.clone();
        members.sort_by(|&a, &b| self.joint[a].latency_s.total_cmp(&self.joint[b].latency_s));
        for &ci in &members {
            let j = &self.joint[ci];
            t.row(&[
                self.candidates[ci].label.clone(),
                format!("{:.4}", j.latency_s),
                format!("{:.1}", j.energy_j),
                format!("{:.0}", j.area_mm2),
            ]);
        }
        out.push_str(&t.render());

        // scatter: all points '.', frontier '*', paper anchor 'P' (drawn
        // last so it wins overlaps)
        let mut pts: Vec<(f64, f64, char)> = Vec::new();
        for j in &self.joint {
            if !self.archive.contains(&j.candidate) {
                pts.push((j.latency_s, j.energy_j, '.'));
            }
        }
        for &ci in &self.archive {
            pts.push((self.joint[ci].latency_s, self.joint[ci].energy_j, '*'));
        }
        let anchor = &self.joint[0];
        pts.push((anchor.latency_s, anchor.energy_j, 'P'));
        out.push('\n');
        out.push_str(&scatter_plot(
            "joint latency vs energy ('*' frontier, '.' dominated, 'P' paper)",
            "latency (s)",
            "energy (J/step)",
            &pts,
        ));

        out.push_str(
            "convergence (hypervolume proxy vs ref = 2x the paper anchor's objectives):\n",
        );
        for s in &self.convergence {
            out.push_str(&format!("  {}\n", s.render()));
        }

        if self.paper_dominators.is_empty() {
            out.push_str(
                "=> the paper's Table 2 configuration is ON the discovered joint \
                 frontier (no candidate beats it for every model at once).\n",
            );
        } else {
            let best = self
                .paper_dominators
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    self.joint[a].latency_s.total_cmp(&self.joint[b].latency_s)
                })
                .expect("non-empty dominator set");
            let j = &self.joint[best];
            out.push_str(&format!(
                "=> the paper's Table 2 configuration is jointly dominated by {} \
                 candidate(s); e.g. `{}`: {:+.1}% latency, {:+.1}% energy, {:+.1}% \
                 area (worst case across models) relative to paper.\n",
                self.paper_dominators.len(),
                self.candidates[best].label,
                (j.latency_s / anchor.latency_s - 1.0) * 100.0,
                (j.energy_j / anchor.energy_j - 1.0) * 100.0,
                (j.area_mm2 / anchor.area_mm2 - 1.0) * 100.0,
            ));
        }
        out
    }

    /// Machine-readable artifact (`EXPLORE_*.json` with a `search` section).
    pub fn to_json(&self) -> Json {
        let ex = &self.cfg.explore;
        let axes = Json::Arr(
            ex.axes
                .iter()
                .map(|a| {
                    Json::obj([
                        ("name", Json::str(a.name.clone())),
                        (
                            "values",
                            Json::Arr(
                                a.values.iter().map(|v| Json::str(v.value_label())).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let candidates = Json::Arr(
            self.candidates
                .iter()
                .map(|c| {
                    Json::obj([
                        ("label", Json::str(c.label.clone())),
                        (
                            "overrides",
                            Json::Obj(
                                c.overrides
                                    .iter()
                                    .map(|o| {
                                        (o.axis_name().to_string(), Json::str(o.value_label()))
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let points = Json::Arr(
            self.cells
                .iter()
                .map(|p| {
                    Json::obj([
                        ("candidate", Json::int(p.variant)),
                        ("model", Json::str(p.model.name())),
                        ("method", Json::str(p.method.name())),
                        ("latency_s", Json::num(p.latency_s)),
                        ("energy_j_per_step", Json::num(p.energy_j)),
                        ("area_mm2", Json::num(p.area_mm2)),
                        ("power_kw", Json::num(p.power_kw)),
                        ("c_t", Json::num(p.c_t)),
                    ])
                })
                .collect(),
        );
        let joint = Json::Arr(
            self.joint
                .iter()
                .map(|j| {
                    Json::obj([
                        ("candidate", Json::int(j.candidate)),
                        ("latency_s", Json::num(j.latency_s)),
                        ("energy_j_per_step", Json::num(j.energy_j)),
                        ("area_mm2", Json::num(j.area_mm2)),
                        ("on_frontier", Json::Bool(self.archive.contains(&j.candidate))),
                        (
                            "cells",
                            Json::Arr(j.cells.iter().map(|&c| Json::int(c)).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        let frontier = Json::obj([
            (
                "members",
                Json::Arr(self.archive.iter().map(|&m| Json::int(m)).collect()),
            ),
            ("paper_point", Json::int(0)),
            ("paper_on_frontier", Json::Bool(self.paper_dominators.is_empty())),
            (
                "paper_dominators",
                Json::Arr(
                    self.paper_dominators.iter().map(|&m| Json::int(m)).collect(),
                ),
            ),
        ]);
        let mut search = Json::obj([
            ("strategy", Json::str(self.cfg.strategy.name())),
            ("evaluations", Json::int(self.candidates.len())),
            (
                "convergence",
                Json::Arr(
                    self.convergence
                        .iter()
                        .map(|s| {
                            Json::obj([
                                ("generation", Json::int(s.generation)),
                                ("evaluations", Json::int(s.evaluations)),
                                ("archive_size", Json::int(s.archive_size)),
                                ("hypervolume", Json::num(s.hypervolume)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "hypervolume_ref",
                Json::Arr(self.hypervolume_ref.iter().map(|&v| Json::num(v)).collect()),
            ),
        ]);
        match self.cfg.strategy {
            SearchStrategy::Exhaustive => {}
            SearchStrategy::Random { samples, seed } => {
                search.push("samples", Json::int(samples));
                // string, not number: JSON numbers are f64 and would corrupt
                // u64 seeds above 2^53 (same policy as the top-level seed)
                search.push("strategy_seed", Json::str(seed.to_string()));
            }
            SearchStrategy::Evolutionary {
                population,
                generations,
                mutation_rate,
                seed,
            } => {
                search.push("population", Json::int(population));
                search.push("generations", Json::int(generations));
                search.push("mutation_rate", Json::num(mutation_rate));
                search.push("strategy_seed", Json::str(seed.to_string()));
            }
        }
        Json::obj([
            ("explore", Json::str("design_space_search")),
            ("axes", axes),
            ("budget", Json::int(ex.budget)),
            ("seq_len", Json::int(ex.seq_len)),
            ("iters", Json::int(ex.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53 (same policy as BENCH_sweep.json)
            ("seed", Json::str(ex.seed.to_string())),
            ("base_dram", Json::str(ex.dram.name())),
            (
                "models",
                Json::Arr(ex.models.iter().map(|m| Json::str(m.name())).collect()),
            ),
            (
                "methods",
                Json::Arr(ex.methods.iter().map(|m| Json::str(m.name())).collect()),
            ),
            (
                "objectives",
                Json::Arr(vec![
                    Json::str("latency_s"),
                    Json::str("energy_j_per_step"),
                    Json::str("area_mm2"),
                ]),
            ),
            ("objective_mode", Json::str("worst_case_across_models")),
            ("candidates", candidates),
            ("points", points),
            ("joint", joint),
            ("frontier", frontier),
            ("search", search),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramKind, ModelId};
    use crate::coordinator::explore::parse_axes;

    fn axes_2x2() -> Vec<Axis> {
        parse_axes("tiles=36:64,dram").expect("axes parse")
    }

    #[test]
    fn mutation_always_moves_when_possible() {
        let axes = axes_2x2();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let g = random_genome(&axes, &mut rng);
            let m = mutate(&axes, &g, 0.0, &mut rng); // rate 0 -> forced move
            assert_ne!(g, m, "offspring must differ from parent");
            for (i, &v) in m.iter().enumerate() {
                assert!(v < axes[i].values.len());
            }
        }
    }

    #[test]
    fn resample_never_returns_current() {
        let mut rng = Rng::new(9);
        for n in 2..6 {
            for cur in 0..n {
                for _ in 0..50 {
                    let v = resample_different(n, cur, &mut rng);
                    assert!(v < n && v != cur, "n={n} cur={cur} v={v}");
                }
            }
        }
    }

    #[test]
    fn fresh_candidates_dedup_and_skip_anchor() {
        let axes = parse_axes("tiles=56:64").expect("axes parse");
        // OlmoE's paper platform has 56 tiles -> genome [0] is the anchor
        let bases = vec![HwConfig::paper_for_model(ModelId::OlmoE_1B_7B, DramKind::Hbm2)];
        let mut seen = BTreeSet::new();
        let got = fresh_candidates(
            &axes,
            vec![vec![0], vec![1], vec![1], vec![0]],
            &bases,
            &mut seen,
        );
        assert_eq!(got.len(), 1, "anchor-equal and duplicate genomes dropped");
        assert_eq!(got[0].label, "tiles=64");
        // dropped genomes are registered too, so re-proposals skip early
        assert!(seen.contains(&vec![0]));
        assert!(seen.contains(&vec![1]));
        let again = fresh_candidates(&axes, vec![vec![1], vec![0]], &bases, &mut seen);
        assert!(again.is_empty());
    }
}
