//! `mozart serve` — online serving saturation sweeps.
//!
//! The training simulator scores one fixed step; this driver scores
//! *traffic*. For one (model, method, platform) cell it:
//!
//! 1. builds a [`ServiceModel`] — batch service times bucketed by token
//!    count, each bucket timed by a real step simulation of the cell at
//!    `batch_size = micro_batch = 1` and `seq_len = bucket`, scaled by
//!    [`FORWARD_FRACTION`] (serving runs the forward pass only; the
//!    backward pass is ~2x the forward FLOPs, so a full training step
//!    is ~3x a forward pass);
//! 2. replays the configured open-loop [`ArrivalProcess`] at each load
//!    multiplier through the [`simulate_serve`] queueing engine
//!    (continuous batching, the configured [`BatchClose`] policy);
//! 3. reports one [`ServePoint`] per load: goodput vs offered load,
//!    exact + P² streaming p50/p99/p999 latency, server utilization,
//!    tokens/s and tokens/s/mm² — the saturation curve.
//!
//! Every point's [`ServeTrace`] is checked by the queueing-invariant
//! oracle ([`ServeTrace::validate`]) *unconditionally* (not just in
//! debug builds), and the Little's-law residual ([`littles_law`]) is
//! recorded in the artifact so CI can assert it stays under 1%.
//!
//! Everything is seeded: the same `(config, seed)` reproduces the same
//! curve bit for bit at any `--threads` value (each load point derives
//! its own arrival seed from the master seed and its index).

use crate::config::{DramKind, ExperimentConfig, Method, ModelId, SchedPolicy};
use crate::coordinator::cache::{EvalOptions, EvalSession, EvalStats};
use crate::coordinator::sweep::{cell_config_sched, parallel_map, parallel_map_with, Cell};
use crate::metrics::slo::{littles_law, P2Quantile};
use crate::sim::serve::{simulate_serve, BatchClose, ServeParams, ServeTrace, ServiceModel};
use crate::trace::arrivals::{ArrivalProcess, RequestShape};
use crate::util::json::Json;
use crate::util::stats;
use crate::util::table::{scatter_plot, Table};

/// Fraction of a training-step latency attributed to the forward pass
/// (serving cost). The backward pass costs roughly twice the forward
/// FLOPs, so forward ≈ 1/3 of the step.
pub const FORWARD_FRACTION: f64 = 1.0 / 3.0;

/// Token ceilings of the service-model buckets: one step simulation per
/// bucket, covering single-job decodes up to full batched prefills.
pub const SERVICE_BUCKETS: [usize; 5] = [128, 256, 512, 1024, 2048];

/// Configuration of one serving sweep.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Model served by the cell.
    pub model: ModelId,
    /// Mozart ablation the cell runs.
    pub method: Method,
    /// DRAM technology of the platform.
    pub dram: DramKind,
    /// DAG scheduling policy for the service-model step simulations.
    pub sched: SchedPolicy,
    /// Open-loop arrival process at load multiplier 1.0.
    pub arrivals: ArrivalProcess,
    /// Token-count distribution of generated requests (file traces carry
    /// their own token counts).
    pub shape: RequestShape,
    /// Traffic duration per load point, seconds (the queue then drains).
    pub duration_s: f64,
    /// Latency SLO in milliseconds; completions within it count toward
    /// goodput.
    pub slo_ms: f64,
    /// Queueing-engine knobs (batch-close policy, queue cap, chunking).
    pub params: ServeParams,
    /// Load multipliers swept (each scales the arrival process via
    /// [`ArrivalProcess::at_load`]).
    pub loads: Vec<f64>,
    /// Cap on the number of load points simulated (0 = no cap); any
    /// truncation is reported, never silent.
    pub budget: usize,
    /// Simulated iterations averaged per service-model bucket.
    pub iters: usize,
    /// Master seed (service-model sims and arrival streams).
    pub seed: u64,
    /// Worker threads (0/1 = sequential); never changes a result bit.
    pub threads: usize,
    /// Evaluation-throughput toggles for the service-model simulations.
    pub eval: EvalOptions,
}

impl ServeConfig {
    /// Paper-flavoured default: the fastest model under the full Mozart
    /// method, Poisson traffic at 100 req/s, a 50 ms SLO, and a load
    /// sweep from 25% to 150% of the nominal rate.
    pub fn paper_default() -> ServeConfig {
        ServeConfig {
            model: ModelId::OlmoE_1B_7B,
            method: Method::MozartC,
            dram: DramKind::Hbm2,
            sched: SchedPolicy::Streaming,
            arrivals: ArrivalProcess::Poisson { rate: 100.0 },
            shape: RequestShape::default(),
            duration_s: 10.0,
            slo_ms: 50.0,
            params: ServeParams::default(),
            loads: vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5],
            budget: 0,
            iters: 2,
            seed: 7,
            threads: 0,
            eval: EvalOptions::default(),
        }
    }
}

/// Build the token-bucketed service model for one platform/model/method
/// combination: one step simulation per [`SERVICE_BUCKETS`] entry (the
/// `base` config with `seq_len = bucket`, `batch_size = micro_batch =
/// 1`), scaled by [`FORWARD_FRACTION`]. `run` is the evaluation hook —
/// typically `EvalCtx::run(..).latency`, so the memoization cache
/// applies and repeated bucket configs across search candidates are
/// never re-simulated. `base` carries the hardware (including any
/// explore overrides), model, method, seed, and scheduling policy.
pub fn build_service_model(
    mut run: impl FnMut(&ExperimentConfig) -> f64,
    base: &ExperimentConfig,
) -> ServiceModel {
    let buckets: Vec<(u64, f64)> = SERVICE_BUCKETS
        .iter()
        .map(|&b| {
            let mut ec = base.clone();
            ec.seq_len = b;
            ec.batch_size = 1;
            ec.micro_batch = 1;
            (b as u64, run(&ec) * FORWARD_FRACTION)
        })
        .collect();
    ServiceModel::new(buckets).expect("simulated bucket latencies are positive")
}

/// The serving workload a search candidate is scored on when the
/// NSGA-II objective is `p99` or `goodput` (`--objective`): one fixed
/// arrival stream replayed against each candidate's service model.
#[derive(Clone, Debug)]
pub struct ServeEvalSpec {
    /// Open-loop arrival process (replayed identically per candidate).
    pub arrivals: ArrivalProcess,
    /// Token-count distribution of the generated requests.
    pub shape: RequestShape,
    /// Traffic duration, seconds.
    pub duration_s: f64,
    /// Latency SLO, milliseconds (goodput counts completions within it).
    pub slo_ms: f64,
    /// Queueing-engine knobs.
    pub params: ServeParams,
}

impl ServeEvalSpec {
    /// Default search workload: Poisson at 100 req/s for 2 s under a
    /// 50 ms SLO — small enough to score every candidate, long enough
    /// for stable tail percentiles.
    pub fn paper_default() -> ServeEvalSpec {
        ServeEvalSpec {
            arrivals: ArrivalProcess::Poisson { rate: 100.0 },
            shape: RequestShape::default(),
            duration_s: 2.0,
            slo_ms: 50.0,
            params: ServeParams::default(),
        }
    }
}

/// The serving scores of one evaluated search cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeMetrics {
    /// Exact p99 sojourn latency, ms — minimized by `--objective p99`.
    pub p99_ms: f64,
    /// SLO-goodput, requests/s — maximized by `--objective goodput`.
    pub goodput_rps: f64,
}

/// Score one search cell on the serving workload: build the cell's
/// service model through `run` (cached — see [`build_service_model`]),
/// replay the spec's arrival stream, and measure p99 / goodput. The
/// arrival seed derives from `base.seed` only, so every candidate of
/// one search faces the identical traffic. The trace is validated by
/// the queueing-invariant oracle unconditionally.
pub fn serve_cell_eval(
    run: impl FnMut(&ExperimentConfig) -> f64,
    base: &ExperimentConfig,
    spec: &ServeEvalSpec,
) -> ServeMetrics {
    let model = build_service_model(run, base);
    let requests = spec
        .arrivals
        .generate(spec.duration_s, &spec.shape, base.seed ^ 0x5E2E_CE11);
    let trace = simulate_serve(&requests, &model, &spec.params);
    let p = measure_point(&trace, &model, 1.0, spec.slo_ms / 1e3, spec.duration_s, 0.0);
    ServeMetrics {
        p99_ms: p.p99_ms,
        goodput_rps: p.goodput_rps,
    }
}

/// One point on the saturation curve.
#[derive(Clone, Debug)]
pub struct ServePoint {
    /// Load multiplier applied to the arrival process.
    pub load: f64,
    /// Offered load actually generated, requests/s.
    pub offered_rps: f64,
    /// Requests offered over the duration.
    pub requests: usize,
    /// Requests completed.
    pub completed: usize,
    /// Requests dropped at admission (queue cap).
    pub dropped: usize,
    /// Completions within the SLO, per second of horizon.
    pub goodput_rps: f64,
    /// Completions within the SLO.
    pub slo_met: usize,
    /// Mean sojourn latency, ms.
    pub mean_ms: f64,
    /// Exact (sort-based) p50 sojourn latency, ms.
    pub p50_ms: f64,
    /// Exact p99 sojourn latency, ms.
    pub p99_ms: f64,
    /// Exact p999 sojourn latency, ms.
    pub p999_ms: f64,
    /// Streaming P² p50 estimate, ms (cross-checked against `p50_ms`).
    pub p2_p50_ms: f64,
    /// Streaming P² p99 estimate, ms.
    pub p2_p99_ms: f64,
    /// Streaming P² p999 estimate, ms.
    pub p2_p999_ms: f64,
    /// Time-average requests in system (Little's law LHS).
    pub little_l: f64,
    /// Little's-law relative residual `|L - λW| / L` (must be < 0.01).
    pub little_rel_err: f64,
    /// Server busy fraction over the horizon.
    pub utilization: f64,
    /// Tokens served per second of horizon.
    pub tokens_per_s: f64,
    /// Tokens served per second per mm² of wafer area.
    pub tokens_per_s_mm2: f64,
    /// Batches executed.
    pub batches: usize,
    /// Horizon the rates are normalized over (max of duration and the
    /// drain end), seconds.
    pub horizon_s: f64,
}

/// Measure one load point from its queueing trace. `area_mm2` feeds the
/// tokens/s/mm² density metric; `slo_s`/`duration_s` come from the
/// sweep config. Validates the trace against the oracle (always, not
/// just in debug builds) before measuring.
pub fn measure_point(
    trace: &ServeTrace,
    model: &ServiceModel,
    load: f64,
    slo_s: f64,
    duration_s: f64,
    area_mm2: f64,
) -> ServePoint {
    trace
        .validate(model)
        .expect("serve trace failed the queueing-invariant oracle");
    let spans = trace.completed_spans();
    let drain_end = trace.batches.last().map_or(0.0, |b| b.finish_s);
    let horizon = duration_s.max(drain_end);

    let mut lat_ms: Vec<f64> = spans.iter().map(|&(a, f)| (f - a) * 1e3).collect();
    let mut p2 = [
        P2Quantile::new(0.5),
        P2Quantile::new(0.99),
        P2Quantile::new(0.999),
    ];
    for &l in &lat_ms {
        for q in p2.iter_mut() {
            q.observe(l);
        }
    }
    lat_ms.sort_by(f64::total_cmp);
    let pct = |p: f64| {
        if lat_ms.is_empty() {
            0.0
        } else {
            stats::percentile(&lat_ms, p)
        }
    };
    let p2v = |q: &P2Quantile| if q.count() == 0 { 0.0 } else { q.value() };

    let slo_met = spans.iter().filter(|&&(a, f)| f - a <= slo_s).count();
    let little = littles_law(&spans, horizon);
    let busy: f64 = trace.batches.iter().map(|b| b.finish_s - b.start_s).sum();
    let tokens: u64 = trace.batches.iter().map(|b| b.tokens).sum();

    ServePoint {
        load,
        offered_rps: trace.requests.len() as f64 / duration_s,
        requests: trace.requests.len(),
        completed: spans.len(),
        dropped: trace.dropped(),
        goodput_rps: slo_met as f64 / horizon,
        slo_met,
        mean_ms: if lat_ms.is_empty() { 0.0 } else { stats::mean(&lat_ms) },
        p50_ms: pct(50.0),
        p99_ms: pct(99.0),
        p999_ms: pct(99.9),
        p2_p50_ms: p2v(&p2[0]),
        p2_p99_ms: p2v(&p2[1]),
        p2_p999_ms: p2v(&p2[2]),
        little_l: little.l,
        little_rel_err: little.rel_err,
        utilization: busy / horizon,
        tokens_per_s: tokens as f64 / horizon,
        tokens_per_s_mm2: if area_mm2 > 0.0 {
            tokens as f64 / horizon / area_mm2
        } else {
            0.0
        },
        batches: trace.batches.len(),
        horizon_s: horizon,
    }
}

/// Outcome of a serving sweep: the saturation curve plus accounting.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Sweep configuration echo.
    pub cfg: ServeConfig,
    /// The service model the queueing engine used.
    pub model: ServiceModel,
    /// Wafer area of the platform, mm² (density metric denominator).
    pub area_mm2: f64,
    /// One point per simulated load, in `cfg.loads` order.
    pub points: Vec<ServePoint>,
    /// Load points dropped by `cfg.budget`.
    pub dropped_loads: usize,
    /// Evaluation accounting for the service-model simulations.
    pub eval: EvalStats,
}

/// Run the sweep: build the service model (through the evaluation
/// cache), then simulate every load point on the work-stealing pool.
/// Deterministic and thread-invariant.
pub fn run(cfg: &ServeConfig) -> ServeOutcome {
    assert!(!cfg.loads.is_empty(), "serve sweep needs at least one load");
    assert!(cfg.duration_s > 0.0, "serve duration must be > 0");
    assert!(cfg.slo_ms > 0.0, "SLO must be > 0");

    let cell = Cell {
        model: cfg.model,
        method: cfg.method,
        seq_len: SERVICE_BUCKETS[0],
        dram: cfg.dram,
    };
    let session = EvalSession::new(cfg.eval.clone());
    // service model: one bucket each, through the session's cache/pool
    let bucket_jobs: Vec<usize> = (0..SERVICE_BUCKETS.len()).collect();
    let bucket_lat: Vec<f64> = parallel_map_with(
        &bucket_jobs,
        cfg.threads,
        session.pools(),
        || session.new_pool(),
        |pool, &bi| {
            let mut ec = cell_config_sched(cell, cfg.iters, cfg.seed, cfg.sched);
            ec.seq_len = SERVICE_BUCKETS[bi];
            ec.batch_size = 1;
            ec.micro_batch = 1;
            let mut ctx = session.ctx(pool);
            ctx.run(&ec).latency
        },
    );
    let model = ServiceModel::new(
        SERVICE_BUCKETS
            .iter()
            .zip(bucket_lat.iter())
            .map(|(&b, &l)| (b as u64, l * FORWARD_FRACTION))
            .collect(),
    )
    .expect("simulated bucket latencies are positive");

    let probe = cell_config_sched(cell, cfg.iters, cfg.seed, cfg.sched);
    let area_mm2 = crate::arch::area::hw_metrics(&probe.model, &probe.hw).total_area_mm2;

    let mut loads = cfg.loads.clone();
    let total = loads.len();
    if cfg.budget > 0 && loads.len() > cfg.budget {
        loads.truncate(cfg.budget);
    }
    let dropped_loads = total - loads.len();

    let jobs: Vec<(usize, f64)> = loads.iter().copied().enumerate().collect();
    let points: Vec<ServePoint> = parallel_map(&jobs, cfg.threads, |&(pi, load)| {
        // every point derives its own arrival seed: independent streams,
        // identical at any thread count
        let pseed = cfg
            .seed
            .wrapping_add((pi as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let requests = cfg
            .arrivals
            .at_load(load)
            .generate(cfg.duration_s, &cfg.shape, pseed);
        let trace = simulate_serve(&requests, &model, &cfg.params);
        measure_point(&trace, &model, load, cfg.slo_ms / 1e3, cfg.duration_s, area_mm2)
    });

    ServeOutcome {
        cfg: cfg.clone(),
        model,
        area_mm2,
        points,
        dropped_loads,
        eval: session.finish(),
    }
}

impl ServeOutcome {
    /// Human-readable report: the saturation table plus ASCII p99 and
    /// goodput curves against offered load.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# Online serving saturation sweep\n\n");
        out.push_str(&format!(
            "- cell: {} / {} / {} / sched={}\n- arrivals: {} (x{} load points), duration {} s\n- batching: {}, decode chunk {}, queue cap {}\n- SLO: {} ms\n\n",
            self.cfg.model.name(),
            self.cfg.method.name(),
            self.cfg.dram.name(),
            self.cfg.sched.name(),
            self.cfg.arrivals.label(),
            self.points.len(),
            self.cfg.duration_s,
            self.cfg.params.close.label(),
            self.cfg.params.decode_chunk,
            self.cfg.params.queue_cap,
            self.cfg.slo_ms,
        ));
        if self.dropped_loads > 0 {
            out.push_str(&format!(
                "> budget truncation: {} load point(s) NOT simulated \
                 (--budget {}); the curve below is partial\n\n",
                self.dropped_loads, self.cfg.budget
            ));
        }
        let mut t = Table::new(
            "saturation curve",
            &[
                "load", "offered r/s", "done", "drop", "goodput r/s", "p50 ms",
                "p99 ms", "p999 ms", "util", "tok/s/mm2",
            ],
        );
        let mut p99_plot: Vec<(f64, f64, char)> = Vec::new();
        let mut good_plot: Vec<(f64, f64, char)> = Vec::new();
        for p in &self.points {
            t.row(&[
                format!("{:.2}", p.load),
                format!("{:.1}", p.offered_rps),
                format!("{}", p.completed),
                format!("{}", p.dropped),
                format!("{:.1}", p.goodput_rps),
                format!("{:.2}", p.p50_ms),
                format!("{:.2}", p.p99_ms),
                format!("{:.2}", p.p999_ms),
                format!("{:.2}", p.utilization),
                format!("{:.3}", p.tokens_per_s_mm2),
            ]);
            p99_plot.push((p.offered_rps, p.p99_ms, '9'));
            good_plot.push((p.offered_rps, p.goodput_rps, 'g'));
        }
        out.push_str(&t.render());
        out.push('\n');
        out.push_str(&scatter_plot(
            "p99 latency vs offered load (the knee is saturation)",
            "offered req/s",
            "p99 ms",
            &p99_plot,
        ));
        out.push('\n');
        out.push_str(&scatter_plot(
            &format!("goodput vs offered load (SLO {} ms)", self.cfg.slo_ms),
            "offered req/s",
            "goodput req/s",
            &good_plot,
        ));
        out.push('\n');
        out
    }

    /// Machine-readable artifact (`SERVE_*.json`, schema version 1).
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                Json::obj([
                    ("load", Json::num(p.load)),
                    ("offered_rps", Json::num(p.offered_rps)),
                    ("requests", Json::int(p.requests)),
                    ("completed", Json::int(p.completed)),
                    ("dropped", Json::int(p.dropped)),
                    ("goodput_rps", Json::num(p.goodput_rps)),
                    ("slo_met", Json::int(p.slo_met)),
                    ("mean_ms", Json::num(p.mean_ms)),
                    ("p50_ms", Json::num(p.p50_ms)),
                    ("p99_ms", Json::num(p.p99_ms)),
                    ("p999_ms", Json::num(p.p999_ms)),
                    ("p2_p50_ms", Json::num(p.p2_p50_ms)),
                    ("p2_p99_ms", Json::num(p.p2_p99_ms)),
                    ("p2_p999_ms", Json::num(p.p2_p999_ms)),
                    ("little_l", Json::num(p.little_l)),
                    ("little_rel_err", Json::num(p.little_rel_err)),
                    ("utilization", Json::num(p.utilization)),
                    ("tokens_per_s", Json::num(p.tokens_per_s)),
                    ("tokens_per_s_mm2", Json::num(p.tokens_per_s_mm2)),
                    ("batches", Json::int(p.batches)),
                    ("horizon_s", Json::num(p.horizon_s)),
                ])
            })
            .collect();
        let buckets: Vec<Json> = self
            .model
            .buckets()
            .iter()
            .map(|&(t, l)| {
                Json::obj([
                    ("max_tokens", Json::int(t as usize)),
                    ("latency_s", Json::num(l)),
                ])
            })
            .collect();
        Json::obj([
            ("artifact", Json::str("serve")),
            ("version", Json::int(1)),
            ("model", Json::str(self.cfg.model.name())),
            ("method", Json::str(self.cfg.method.name())),
            ("dram", Json::str(self.cfg.dram.name())),
            ("sched", Json::str(self.cfg.sched.name())),
            ("arrivals", Json::str(&self.cfg.arrivals.label())),
            ("duration_s", Json::num(self.cfg.duration_s)),
            ("slo_ms", Json::num(self.cfg.slo_ms)),
            ("batch_close", Json::str(&self.cfg.params.close.label())),
            ("max_batch_jobs", Json::int(self.cfg.params.max_batch_jobs)),
            ("queue_cap", Json::int(self.cfg.params.queue_cap)),
            ("decode_chunk", Json::int(self.cfg.params.decode_chunk as usize)),
            ("iters", Json::int(self.cfg.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53, breaking reproduction from the artifact
            ("seed", Json::str(self.cfg.seed.to_string())),
            ("forward_fraction", Json::num(FORWARD_FRACTION)),
            ("area_mm2", Json::num(self.area_mm2)),
            ("oracle", Json::str("validated")),
            ("dropped_by_budget", Json::int(self.dropped_loads)),
            ("service_model", Json::Arr(buckets)),
            ("cache", self.eval.to_json()),
            ("points", Json::Arr(points)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(threads: usize) -> ServeConfig {
        ServeConfig {
            arrivals: ArrivalProcess::Poisson { rate: 150.0 },
            duration_s: 1.0,
            loads: vec![0.5, 1.0],
            iters: 1,
            seed: 11,
            threads,
            ..ServeConfig::paper_default()
        }
    }

    #[test]
    fn sweep_points_pass_oracle_and_littles_law() {
        let out = run(&tiny(1));
        assert_eq!(out.points.len(), 2);
        for p in &out.points {
            assert!(p.requests > 0, "no traffic generated");
            assert_eq!(p.completed + p.dropped, p.requests, "conservation");
            assert!(
                p.little_rel_err < 0.01,
                "Little's law violated: rel_err {}",
                p.little_rel_err
            );
            assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
            assert!(p.utilization > 0.0 && p.utilization <= 1.0 + 1e-9);
            assert!(p.tokens_per_s > 0.0 && p.tokens_per_s_mm2 > 0.0);
        }
        // higher load => more offered traffic
        assert!(out.points[1].offered_rps > out.points[0].offered_rps);
    }

    #[test]
    fn sweep_is_reproducible_and_thread_invariant() {
        let a = run(&tiny(1));
        let b = run(&tiny(2));
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.requests, y.requests);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.p99_ms.to_bits(), y.p99_ms.to_bits());
            assert_eq!(x.goodput_rps.to_bits(), y.goodput_rps.to_bits());
            assert_eq!(x.little_rel_err.to_bits(), y.little_rel_err.to_bits());
            assert_eq!(x.tokens_per_s.to_bits(), y.tokens_per_s.to_bits());
        }
    }

    #[test]
    fn budget_truncates_load_points_loudly() {
        let mut cfg = tiny(1);
        cfg.budget = 1;
        let out = run(&cfg);
        assert_eq!(out.points.len(), 1);
        assert_eq!(out.dropped_loads, 1);
        assert!(out.render_markdown().contains("budget truncation"));
    }

    #[test]
    fn p2_estimates_track_exact_percentiles_in_the_artifact() {
        let mut cfg = tiny(1);
        cfg.duration_s = 2.0;
        cfg.loads = vec![1.0];
        let out = run(&cfg);
        let p = &out.points[0];
        assert!(p.completed > 100, "need enough samples, got {}", p.completed);
        // p50 estimates agree within 15% of the exact spread
        let spread = (p.p999_ms - p.p50_ms).max(p.p50_ms).max(1e-9);
        assert!(
            (p.p2_p50_ms - p.p50_ms).abs() / spread < 0.15,
            "p2 p50 {} vs exact {}",
            p.p2_p50_ms,
            p.p50_ms
        );
    }

    #[test]
    fn report_and_json_are_well_formed() {
        let out = run(&tiny(0));
        let md = out.render_markdown();
        assert!(md.contains("saturation curve"));
        assert!(md.contains("p99 latency vs offered load"));
        assert!(md.contains("goodput vs offered load"));
        let js = out.to_json().render_pretty();
        for key in [
            "\"artifact\"", "\"version\"", "\"arrivals\"", "\"slo_ms\"",
            "\"batch_close\"", "\"service_model\"", "\"points\"",
            "\"goodput_rps\"", "\"p99_ms\"", "\"p2_p99_ms\"",
            "\"little_rel_err\"", "\"tokens_per_s_mm2\"", "\"oracle\"",
        ] {
            assert!(js.contains(key), "missing {key}");
        }
        assert!(js.contains("\"seed\": \"11\""));
        assert!(js.contains("\"artifact\": \"serve\""));
    }

    #[test]
    fn service_model_buckets_are_positive_and_ordered() {
        let out = run(&tiny(1));
        let b = out.model.buckets();
        assert_eq!(b.len(), SERVICE_BUCKETS.len());
        for w in b.windows(2) {
            assert!(w[0].0 < w[1].0);
            // more tokens never costs less (step latency grows with seq_len)
            assert!(w[0].1 <= w[1].1, "bucket latencies not monotone: {b:?}");
        }
    }
}
