//! Evaluation memoization + delta re-timing for the explorer/search hot
//! path (ROADMAP item 3: search-throughput overhaul).
//!
//! Two reuse layers, both bit-transparent (they never change a reported
//! number — only how fast it is produced):
//!
//! 1. **Cell memoization** ([`EvalCache`]): a concurrent map from the
//!    canonical evaluation-cell key ([`CellKey`]: hardware fingerprint +
//!    model + method + workload shape + seeds + fault scenario) to the
//!    finished [`ExperimentResult`]. Duplicate cells — re-proposed
//!    genomes, the repeated healthy baseline of `--min-resilience` runs,
//!    back-to-back searches sharing a `--cache-file` — are served as a
//!    clone of the first simulation's result, which is bit-identical by
//!    construction. Because every cell is a pure function of its key,
//!    concurrent insert races are benign (both workers computed the same
//!    value).
//!
//! 2. **Delta re-timing** ([`EvalPool`]): a small per-worker pool of
//!    prepared topologies (trace generator, expert layouts, [`PlanCache`]
//!    arena). A cell whose *topology words* match a pooled entry — same
//!    model, workload shape, seed, dead-chiplet set, and every
//!    topology-shaping hardware field — differs only in calibration knobs,
//!    core clock, or fault severities, so the pooled plan is
//!    [`PlanCache::retime`]d instead of rebuilt from scratch, skipping
//!    trace profiling, layout derivation, and topology emission. The
//!    re-timed plan emits bit-identically to a fresh build (asserted in
//!    `pipeline::plan_builder` tests and end-to-end here).
//!
//! Thread discipline: the cache is shared (`&EvalCache` is `Sync`); pools
//! are per-worker mutable state threaded through
//! [`sweep::parallel_map_with`](super::sweep::parallel_map_with). Which
//! worker owns which pooled topology varies run to run, but since re-timed
//! and fresh evaluations are bit-identical, results never depend on it;
//! only the hit/miss *counters* may differ across parallel runs.

use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::allocation::ExpertLayout;
use crate::config::{ExperimentConfig, MethodConfig, ModelConfig};
use crate::metrics::energy::EnergyBreakdown;
use crate::pipeline::PlanCache;
use crate::sim::{SimScratch, Tag, TagBreakdown};
use crate::trace::TraceGen;
use crate::util::json::Json;

use super::{layouts_for, run_experiment, run_prepared, ExperimentResult};

/// Canonical key of one evaluation cell, split like
/// [`HwFingerprint`](crate::config::HwFingerprint) into the words that
/// shape the plan topology and the words that only re-time it. Equal
/// `topo` words ⇒ the cells share placements, byte/FLOP model, and plan
/// structure (the [`EvalPool`] reuse criterion); equal `topo` *and*
/// `timing` words ⇒ the same cell (the [`EvalCache`] criterion). All
/// floats are encoded via `f64::to_bits`, strings length-prefixed, so two
/// distinct cells never collide.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CellKey {
    /// Topology-shaping words: hardware topo fingerprint, model
    /// architecture, method toggles, workload shape, seed, fault dead-set.
    pub topo: Vec<u64>,
    /// Re-timing words: hardware timing fingerprint, iteration count,
    /// full fault scenario (label + placement seed).
    pub timing: Vec<u64>,
}

/// Length-prefixed little-endian packing of a string into key words.
fn push_str(words: &mut Vec<u64>, s: &str) {
    let b = s.as_bytes();
    words.push(b.len() as u64);
    for chunk in b.chunks(8) {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        words.push(u64::from_le_bytes(w));
    }
}

/// Derive the canonical [`CellKey`] of an experiment config. Every field
/// of [`ExperimentConfig`] is encoded exactly once (exhaustive
/// destructuring guards against new fields silently escaping the key).
pub fn cell_key(cfg: &ExperimentConfig) -> CellKey {
    let ExperimentConfig {
        model,
        hw,
        method,
        seq_len,
        batch_size,
        micro_batch,
        iters,
        seed,
        fault,
        sched,
    } = cfg;
    let fp = hw.fingerprint();
    let mut topo = fp.topo;

    let ModelConfig {
        id,
        vocab,
        hidden,
        n_layers,
        n_dense_layers,
        dense_intermediate,
        n_heads,
        n_kv_heads,
        head_dim,
        n_experts,
        n_shared_experts,
        expert_intermediate,
        top_k,
        bytes_per_param,
    } = model;
    push_str(&mut topo, id.name());
    for v in [
        *vocab,
        *hidden,
        *n_layers,
        *n_dense_layers,
        *dense_intermediate,
        *n_heads,
        *n_kv_heads,
        *head_dim,
        *n_experts,
        *n_shared_experts,
        *expert_intermediate,
        *top_k,
        *bytes_per_param,
    ] {
        topo.push(v as u64);
    }

    let MethodConfig {
        method: method_id,
        expert_layout,
        efficient_a2a,
        overlap,
    } = method;
    push_str(&mut topo, method_id.name());
    topo.push(
        *expert_layout as u64 | (*efficient_a2a as u64) << 1 | (*overlap as u64) << 2,
    );

    topo.push(*seq_len as u64);
    topo.push(*batch_size as u64);
    topo.push(*micro_batch as u64);
    topo.push(*seed);

    // The dead-chiplet set is the only fault aspect that reshapes the
    // topology (expert spill); severities and bandwidth degradations enter
    // purely through the duration constants and stay in the timing words.
    if fault.is_healthy() {
        topo.push(0);
    } else {
        let dead = fault.effects(hw.n_moe_chiplets, hw.n_groups).dead();
        topo.push(dead.len() as u64);
        for d in dead {
            topo.push(d as u64);
        }
    }

    let mut timing = fp.timing;
    timing.push(*iters as u64);
    timing.push(fault.seed);
    push_str(&mut timing, &fault.label());
    // The scheduling policy changes when tasks run, never which tasks
    // exist, so it re-times the same plan topology: encoding it in the
    // timing words keeps [`EvalPool`] topology reuse valid across
    // policies while [`EvalCache`] entries never collide.
    timing.push(sched.index() as u64);
    CellKey { topo, timing }
}

/// Evaluation toggles threaded from the CLI into the explorer/search
/// evaluation pipeline. Defaults are all-on — both layers are
/// bit-transparent, so there is no accuracy reason to disable them; the
/// `--no-eval-cache` / `--no-delta-retime` flags exist for A/B timing
/// (the `bench --grid search` evaluations-per-second grid) and debugging.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalOptions {
    /// Memoize finished cells in a shared [`EvalCache`].
    pub cache: bool,
    /// Reuse pooled plan topologies across knob/frequency variants.
    pub retime: bool,
    /// Warm-start the cache from this file and write it back after the
    /// run (the cross-run persistence behind the CI throughput smoke).
    pub cache_file: Option<String>,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            cache: true,
            retime: true,
            cache_file: None,
        }
    }
}

/// Hit/miss accounting of one [`EvalCache`], snapshotted into the
/// `EXPLORE_*.json` artifacts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups served from the cache (no simulation ran).
    pub hits: u64,
    /// Lookups that fell through to a simulation.
    pub misses: u64,
    /// Entries resident at snapshot time.
    pub entries: usize,
    /// Entries warm-loaded from `--cache-file` at startup.
    pub loaded: usize,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The artifact's `cache` section.
    pub fn to_json(&self, enabled: bool) -> Json {
        Json::obj([
            ("enabled", Json::Bool(enabled)),
            ("hits", Json::int(self.hits as usize)),
            ("misses", Json::int(self.misses as usize)),
            ("hit_rate", Json::num(self.hit_rate())),
            ("entries", Json::int(self.entries)),
            ("loaded", Json::int(self.loaded)),
        ])
    }
}

/// Combined accounting of one evaluation session — the cache counters plus
/// the pooled-retiming counters summed over every worker pool. Rendered as
/// the flat `cache` object of the `EXPLORE_*.json` artifacts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalStats {
    /// Whether cell memoization was enabled.
    pub cache_enabled: bool,
    /// Whether delta re-timing was enabled.
    pub retime_enabled: bool,
    /// Cache hit/miss counters (all zero when the cache was disabled).
    pub cache: CacheStats,
    /// Fresh topology builds across all worker pools.
    pub builds: u64,
    /// Cells served by re-timing a pooled topology.
    pub retimes: u64,
}

impl EvalStats {
    /// The artifact's `cache` section (flat on purpose: bit-identity tests
    /// strip it with a non-nested `"cache":{...}` match).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("enabled", Json::Bool(self.cache_enabled)),
            ("hits", Json::int(self.cache.hits as usize)),
            ("misses", Json::int(self.cache.misses as usize)),
            ("hit_rate", Json::num(self.cache.hit_rate())),
            ("entries", Json::int(self.cache.entries)),
            ("loaded", Json::int(self.cache.loaded)),
            ("retime_enabled", Json::Bool(self.retime_enabled)),
            ("builds", Json::int(self.builds as usize)),
            ("retimes", Json::int(self.retimes as usize)),
        ])
    }
}

/// One evaluation session: the shared memoization cache (optionally
/// file-backed) plus the pool of per-worker [`EvalPool`]s threaded through
/// [`sweep::parallel_map_with`](super::sweep::parallel_map_with). Owned by
/// one `explore`/`search`/`degrade` run; [`EvalSession::finish`] aggregates
/// the counters and writes the cache file back.
pub struct EvalSession {
    opts: EvalOptions,
    cache: Option<EvalCache>,
    pools: super::sweep::StatePool<EvalPool>,
}

impl EvalSession {
    /// Open a session: allocate the cache (warm-loaded from
    /// `opts.cache_file` when set) and an empty pool-of-pools.
    pub fn new(opts: EvalOptions) -> EvalSession {
        let cache = opts.cache.then(|| match &opts.cache_file {
            Some(path) => EvalCache::load(path),
            None => EvalCache::new(),
        });
        EvalSession {
            opts,
            cache,
            pools: super::sweep::StatePool::new(),
        }
    }

    /// The shared cache, when memoization is enabled.
    pub fn cache(&self) -> Option<&EvalCache> {
        self.cache.as_ref()
    }

    /// The per-worker pool store (pass to `parallel_map_with`).
    pub fn pools(&self) -> &super::sweep::StatePool<EvalPool> {
        &self.pools
    }

    /// A fresh worker pool honoring this session's re-timing toggle (the
    /// `init` closure of `parallel_map_with`).
    pub fn new_pool(&self) -> EvalPool {
        EvalPool::new(self.opts.retime)
    }

    /// Borrow an evaluation context for one worker's pool.
    pub fn ctx<'a>(&'a self, pool: &'a mut EvalPool) -> EvalCtx<'a> {
        EvalCtx {
            cache: self.cache(),
            pool,
        }
    }

    /// Close the session: drain the worker pools, sum their counters, write
    /// the cache file back (a failed write warns on stderr — persistence is
    /// best-effort), and return the aggregated stats.
    pub fn finish(&self) -> EvalStats {
        let mut stats = EvalStats {
            cache_enabled: self.opts.cache,
            retime_enabled: self.opts.retime,
            ..EvalStats::default()
        };
        for pool in self.pools.drain() {
            stats.builds += pool.builds;
            stats.retimes += pool.retimes;
        }
        if let Some(cache) = &self.cache {
            stats.cache = cache.stats();
            if let Some(path) = &self.opts.cache_file {
                if let Err(e) = cache.save(path) {
                    eprintln!("warning: could not write eval cache `{path}`: {e}");
                }
            }
        }
        stats
    }
}

/// Borrowed evaluation context — the session's shared cache plus one
/// worker's mutable pool — threaded through the cell-evaluation path.
pub struct EvalCtx<'a> {
    /// Shared memoization cache, if enabled.
    pub cache: Option<&'a EvalCache>,
    /// This worker's topology pool.
    pub pool: &'a mut EvalPool,
}

impl EvalCtx<'_> {
    /// Evaluate one cell through the cache and the pool (see [`run_cell`]).
    pub fn run(&mut self, cfg: &ExperimentConfig) -> ExperimentResult {
        run_cell(cfg, self.cache, self.pool)
    }

    /// A context with no memoization cache — runs go straight to `pool`
    /// (which re-times or rebuilds per its own toggle). For callers outside
    /// any session (tests, one-off evaluations).
    pub fn detached(pool: &mut EvalPool) -> EvalCtx<'_> {
        EvalCtx { cache: None, pool }
    }
}

/// Concurrent cell-memoization cache: [`CellKey`] → [`ExperimentResult`].
/// Shared by reference across sweep workers and across search
/// generations; optionally persisted to a `--cache-file` so repeated runs
/// (CI smokes, iterative co-design sessions) never re-simulate a cell.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: Mutex<HashMap<CellKey, ExperimentResult>>,
    hits: AtomicU64,
    misses: AtomicU64,
    loaded: usize,
}

/// Magic first line of the persisted cache format.
// v2: cell keys grew a scheduling-policy timing word (PR 8); v1 files are
// discarded on load rather than carried as permanently-dead entries.
const CACHE_HEADER: &str = "mozart-evalcache v2";

impl EvalCache {
    /// An empty cache.
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// A cache warm-started from `path`. A missing, unreadable, corrupt,
    /// or version-mismatched file yields an empty cache — persistence is
    /// an accelerator, never a correctness dependency.
    pub fn load(path: &str) -> EvalCache {
        let mut cache = EvalCache::new();
        let Ok(text) = std::fs::read_to_string(path) else {
            return cache;
        };
        let mut lines = text.lines();
        if lines.next() != Some(CACHE_HEADER) {
            return cache;
        }
        let map = cache.map.get_mut().expect("fresh cache lock");
        for line in lines {
            let mut parts = line.split('|');
            let (Some(t), Some(m), Some(r)) = (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            if parts.next().is_some() {
                continue;
            }
            let (Some(topo), Some(timing), Some(words)) =
                (parse_words(t), parse_words(m), parse_words(r))
            else {
                continue;
            };
            let Some(result) = decode_result(&words) else {
                continue;
            };
            map.insert(CellKey { topo, timing }, result);
        }
        cache.loaded = map.len();
        cache
    }

    /// Write every entry back to `path` (sorted by key for deterministic
    /// bytes). Errors are reported to the caller; the in-memory cache is
    /// unaffected.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        let map = self.map.lock().expect("eval cache poisoned");
        let mut entries: Vec<(&CellKey, &ExperimentResult)> = map.iter().collect();
        entries.sort_by(|a, b| a.0.topo.cmp(&b.0.topo).then(a.0.timing.cmp(&b.0.timing)));
        let mut out = String::with_capacity(entries.len() * 256 + 32);
        out.push_str(CACHE_HEADER);
        out.push('\n');
        for (key, result) in entries {
            render_words(&mut out, &key.topo);
            out.push('|');
            render_words(&mut out, &key.timing);
            out.push('|');
            render_words(&mut out, &encode_result(result));
            out.push('\n');
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())
    }

    /// Look up a finished cell, counting the hit or miss.
    pub fn lookup(&self, key: &CellKey) -> Option<ExperimentResult> {
        let map = self.map.lock().expect("eval cache poisoned");
        match map.get(key) {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a freshly simulated cell. Racing inserts of the same key are
    /// benign: both workers computed the same deterministic result.
    pub fn insert(&self, key: CellKey, result: ExperimentResult) {
        let mut map = self.map.lock().expect("eval cache poisoned");
        map.insert(key, result);
    }

    /// Snapshot the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().expect("eval cache poisoned").len(),
            loaded: self.loaded,
        }
    }
}

/// Hex words, space-separated.
fn render_words(out: &mut String, words: &[u64]) {
    use std::fmt::Write as _;
    for (i, w) in words.iter().enumerate() {
        if i > 0 {
            out.push(' ');
        }
        write!(out, "{w:x}").expect("write to string");
    }
}

fn parse_words(s: &str) -> Option<Vec<u64>> {
    s.split_whitespace()
        .map(|w| u64::from_str_radix(w, 16).ok())
        .collect()
}

/// Bit-exact flat encoding of an [`ExperimentResult`]: scalar fields, the
/// two tag breakdowns in [`Tag::ALL`] order, and the energy components.
fn encode_result(r: &ExperimentResult) -> Vec<u64> {
    let ExperimentResult {
        latency,
        latency_std,
        c_t,
        tag_busy,
        critical,
        energy,
        group_imbalance,
        moe_utilization,
        iters,
    } = r;
    let mut words = Vec::with_capacity(6 + 2 * Tag::COUNT + 5);
    for v in [*latency, *latency_std, *c_t, *group_imbalance, *moe_utilization] {
        words.push(v.to_bits());
    }
    words.push(*iters as u64);
    for b in [tag_busy, critical] {
        for (_, v) in b.iter() {
            words.push(v.to_bits());
        }
    }
    let EnergyBreakdown {
        compute_j,
        dram_j,
        nop_j,
        sram_j,
        static_j,
    } = energy;
    for v in [*compute_j, *dram_j, *nop_j, *sram_j, *static_j] {
        words.push(v.to_bits());
    }
    words
}

fn decode_result(words: &[u64]) -> Option<ExperimentResult> {
    if words.len() != 6 + 2 * Tag::COUNT + 5 {
        return None;
    }
    let f = |i: usize| f64::from_bits(words[i]);
    let mut tag_busy = TagBreakdown::zero();
    let mut critical = TagBreakdown::zero();
    for (i, tag) in Tag::ALL.into_iter().enumerate() {
        tag_busy.add(tag, f64::from_bits(words[6 + i]));
        critical.add(tag, f64::from_bits(words[6 + Tag::COUNT + i]));
    }
    let e = 6 + 2 * Tag::COUNT;
    Some(ExperimentResult {
        latency: f(0),
        latency_std: f(1),
        c_t: f(2),
        group_imbalance: f(3),
        moe_utilization: f(4),
        iters: words[5] as usize,
        tag_busy,
        critical,
        energy: EnergyBreakdown {
            compute_j: f(e),
            dram_j: f(e + 1),
            nop_j: f(e + 2),
            sram_j: f(e + 3),
            static_j: f(e + 4),
        },
    })
}

/// Upper bound on pooled topologies per worker. Each slot holds a trace
/// generator, per-layer layouts, and a plan arena — a few MB for the paper
/// models — and a search batch rarely cycles through more than a handful
/// of distinct topologies per worker between re-timing opportunities.
const POOL_CAP: usize = 4;

struct PoolSlot {
    topo: Vec<u64>,
    gen: TraceGen,
    layouts: Vec<ExpertLayout>,
    plan: PlanCache,
}

/// Per-worker pool of prepared topologies for delta re-timing, plus the
/// reusable simulator scratch. Created once per sweep worker (via
/// [`sweep::StatePool`](super::sweep::StatePool)) and reused across every
/// cell that worker evaluates — including across search generations.
pub struct EvalPool {
    enabled: bool,
    scratch: SimScratch,
    slots: Vec<PoolSlot>,
    /// Fresh topology builds (pool misses + disabled-path runs).
    pub builds: u64,
    /// Cells served by re-timing a pooled topology.
    pub retimes: u64,
}

impl EvalPool {
    /// A pool that re-times when `enabled`, or transparently falls back to
    /// full [`run_experiment`] builds when not.
    pub fn new(enabled: bool) -> EvalPool {
        EvalPool {
            enabled,
            scratch: SimScratch::new(),
            slots: Vec::new(),
            builds: 0,
            retimes: 0,
        }
    }

    /// Simulate `cfg`, re-timing a pooled topology when one matches.
    fn run(&mut self, cfg: &ExperimentConfig, key: Option<&CellKey>) -> ExperimentResult {
        let Some(key) = key.filter(|_| self.enabled) else {
            self.builds += 1;
            return run_experiment(cfg);
        };
        if let Some(i) = self.slots.iter().position(|s| s.topo == key.topo) {
            // MRU ordering: keep hot topologies at the front.
            let mut slot = self.slots.remove(i);
            slot.plan.retime(cfg);
            let r = run_prepared(cfg, &slot.gen, &slot.layouts, &mut slot.plan, &mut self.scratch);
            self.slots.insert(0, slot);
            self.retimes += 1;
            return r;
        }
        // Pool miss: prepare the topology exactly like `run_experiment`
        // (same derivation order, same validation), then keep it.
        let gen = TraceGen::for_model(&cfg.model, cfg.seed);
        let layouts = layouts_for(cfg, &gen);
        for layout in &layouts {
            layout.validate().expect("layout invariants");
        }
        let mut plan = PlanCache::new(cfg, &layouts);
        let r = run_prepared(cfg, &gen, &layouts, &mut plan, &mut self.scratch);
        self.slots.insert(
            0,
            PoolSlot {
                topo: key.topo.clone(),
                gen,
                layouts,
                plan,
            },
        );
        self.slots.truncate(POOL_CAP);
        self.builds += 1;
        r
    }
}

/// Evaluate one cell through both reuse layers: cache lookup first, then a
/// pooled (re-timed) or fresh simulation, then cache insert. This is the
/// single simulation entry point of the explorer, the guided search, and
/// the degrade sweep; with `cache: None` and a disabled pool it is exactly
/// [`run_experiment`].
pub fn run_cell(
    cfg: &ExperimentConfig,
    cache: Option<&EvalCache>,
    pool: &mut EvalPool,
) -> ExperimentResult {
    let key = (cache.is_some() || pool.enabled).then(|| cell_key(cfg));
    if let (Some(c), Some(k)) = (cache, key.as_ref()) {
        if let Some(r) = c.lookup(k) {
            return r;
        }
    }
    let r = pool.run(cfg, key.as_ref());
    if let (Some(c), Some(k)) = (cache, key) {
        c.insert(k, r.clone());
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        DramKind, HwConfig, HwOverride, KnobId, Method, ModelConfig, ModelId,
    };

    fn small_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default(
            ModelConfig::preset(ModelId::OlmoE_1B_7B),
            Method::MozartC.config(),
        );
        c.seq_len = 64;
        c.iters = 2;
        c
    }

    #[test]
    fn cell_key_splits_topology_from_timing() {
        let base = small_cfg();
        let k0 = cell_key(&base);
        assert_eq!(k0, cell_key(&base.clone()));

        let mut knob = base.clone();
        knob.hw = knob.hw.with_overrides(&[HwOverride::Knob(KnobId::MxuUtil, 0.5)]);
        let k1 = cell_key(&knob);
        assert_eq!(k0.topo, k1.topo, "knob change must keep the topology words");
        assert_ne!(k0.timing, k1.timing);

        let mut tiles = base.clone();
        tiles.hw = tiles.hw.with_overrides(&[HwOverride::MoeTiles(36)]);
        assert_ne!(k0.topo, cell_key(&tiles).topo);

        // bandwidth faults re-time; dead chiplets reshape the topology
        let mut bw = base.clone();
        bw.fault = crate::comm::FaultScenario::parse("dram-throttle:0.3", bw.seed).unwrap();
        let kbw = cell_key(&bw);
        assert_eq!(k0.topo, kbw.topo);
        assert_ne!(k0.timing, kbw.timing);
        let mut dead = base.clone();
        dead.fault = crate::comm::FaultScenario::parse("dead-chiplet:2", dead.seed).unwrap();
        assert_ne!(k0.topo, cell_key(&dead).topo);

        // every workload knob lands in the key
        for f in [
            |c: &mut ExperimentConfig| c.seq_len = 128,
            |c: &mut ExperimentConfig| c.iters = 3,
            |c: &mut ExperimentConfig| c.seed ^= 1,
            |c: &mut ExperimentConfig| c.method = Method::Baseline.config(),
            |c: &mut ExperimentConfig| {
                c.model = ModelConfig::preset(ModelId::TinyMoE);
            },
        ] {
            let mut v = base.clone();
            f(&mut v);
            assert_ne!(cell_key(&v), k0);
        }
    }

    /// The end-to-end delta re-timing contract: a pool that re-times across
    /// knob / frequency / bandwidth-fault variants reproduces the uncached
    /// `run_experiment` bit for bit.
    #[test]
    fn pooled_run_is_bit_identical_to_run_experiment() {
        let base = small_cfg();
        let mut variants = vec![base.clone()];
        for ov in [
            vec![HwOverride::FreqGhz(1.25)],
            vec![HwOverride::Knob(KnobId::DramEff, 0.7)],
            vec![
                HwOverride::Knob(KnobId::NopEff, 0.6),
                HwOverride::Knob(KnobId::SwitchAggFactor, 3.0),
            ],
        ] {
            let mut c = base.clone();
            c.hw = c.hw.with_overrides(&ov);
            variants.push(c);
        }
        let mut faulted = base.clone();
        faulted.fault =
            crate::comm::FaultScenario::parse("nop-degrade:0.5,hb-degrade:0.25", faulted.seed)
                .unwrap();
        variants.push(faulted);
        // a topology change in the middle forces a pool miss mid-stream
        let mut retiled = base.clone();
        retiled.hw = retiled.hw.with_overrides(&[HwOverride::MoeTiles(36)]);
        variants.push(retiled);
        variants.push(base.clone()); // back to a pooled topology

        let mut pool = EvalPool::new(true);
        for (i, cfg) in variants.iter().enumerate() {
            let fresh = run_experiment(cfg);
            let pooled = run_cell(cfg, None, &mut pool);
            assert_eq!(
                fresh.latency.to_bits(),
                pooled.latency.to_bits(),
                "variant {i} latency"
            );
            assert_eq!(fresh.latency_std.to_bits(), pooled.latency_std.to_bits());
            assert_eq!(fresh.c_t.to_bits(), pooled.c_t.to_bits());
            assert_eq!(
                fresh.energy.total_j().to_bits(),
                pooled.energy.total_j().to_bits(),
                "variant {i} energy"
            );
            assert_eq!(fresh.tag_busy, pooled.tag_busy, "variant {i}");
            assert_eq!(fresh.critical, pooled.critical, "variant {i}");
            assert_eq!(
                fresh.group_imbalance.to_bits(),
                pooled.group_imbalance.to_bits()
            );
            assert_eq!(
                fresh.moe_utilization.to_bits(),
                pooled.moe_utilization.to_bits()
            );
        }
        assert!(pool.retimes >= 4, "retimes {} — pool never re-timed", pool.retimes);
        assert_eq!(
            pool.builds + pool.retimes,
            variants.len() as u64,
            "every variant ran exactly once"
        );
    }

    #[test]
    fn cache_serves_duplicates_without_resimulating() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let mut pool = EvalPool::new(true);
        let a = run_cell(&cfg, Some(&cache), &mut pool);
        let b = run_cell(&cfg, Some(&cache), &mut pool);
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(pool.builds, 1, "second lookup must not simulate");
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_file_round_trips_bit_exactly() {
        let cfg = small_cfg();
        let cache = EvalCache::new();
        let mut pool = EvalPool::new(false);
        let fresh = run_cell(&cfg, Some(&cache), &mut pool);

        let dir = std::env::temp_dir().join("mozart-evalcache-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.txt");
        let path = path.to_str().unwrap();
        cache.save(path).unwrap();

        let warmed = EvalCache::load(path);
        assert_eq!(warmed.loaded, 1);
        let key = cell_key(&cfg);
        let replayed = warmed.lookup(&key).expect("persisted cell present");
        assert_eq!(fresh.latency.to_bits(), replayed.latency.to_bits());
        assert_eq!(fresh.latency_std.to_bits(), replayed.latency_std.to_bits());
        assert_eq!(fresh.c_t.to_bits(), replayed.c_t.to_bits());
        assert_eq!(fresh.tag_busy, replayed.tag_busy);
        assert_eq!(fresh.critical, replayed.critical);
        assert_eq!(
            fresh.energy.total_j().to_bits(),
            replayed.energy.total_j().to_bits()
        );
        assert_eq!(fresh.iters, replayed.iters);
        let s = warmed.stats();
        assert_eq!((s.hits, s.misses), (1, 0));

        // corrupt / mismatched files load as empty, never panic
        std::fs::write(path, "not a cache\n1 2 3").unwrap();
        assert_eq!(EvalCache::load(path).stats().entries, 0);
        std::fs::write(path, format!("{CACHE_HEADER}\nzz|yy|xx\n1 2|3\n")).unwrap();
        assert_eq!(EvalCache::load(path).stats().entries, 0);
        assert_eq!(EvalCache::load("/nonexistent/evalcache").stats().entries, 0);
    }

    #[test]
    fn result_encoding_is_lossless() {
        let cfg = small_cfg();
        let r = run_experiment(&cfg);
        let decoded = decode_result(&encode_result(&r)).expect("well-formed words");
        assert_eq!(r.latency.to_bits(), decoded.latency.to_bits());
        assert_eq!(r.tag_busy, decoded.tag_busy);
        assert_eq!(r.critical, decoded.critical);
        assert_eq!(
            r.energy.mean_power_w(r.latency).to_bits(),
            decoded.energy.mean_power_w(decoded.latency).to_bits()
        );
        assert!(decode_result(&[1, 2, 3]).is_none());
    }

    #[test]
    fn pool_caps_resident_topologies() {
        let base = small_cfg();
        let mut pool = EvalPool::new(true);
        for tiles in [36, 40, 44, 48, 52, 56] {
            let mut c = base.clone();
            c.hw = c.hw.with_overrides(&[HwOverride::MoeTiles(tiles)]);
            c.iters = 1;
            run_cell(&c, None, &mut pool);
        }
        assert!(pool.slots.len() <= POOL_CAP);
        assert_eq!(pool.builds, 6);
    }

    #[test]
    fn disabled_pool_and_cache_fall_back_to_plain_runs() {
        let cfg = small_cfg();
        let fresh = run_experiment(&cfg);
        let mut pool = EvalPool::new(false);
        let r = run_cell(&cfg, None, &mut pool);
        assert_eq!(fresh.latency.to_bits(), r.latency.to_bits());
        assert!(pool.slots.is_empty());
        assert_eq!(pool.builds, 1);
    }

    #[test]
    fn paper_default_hw_fingerprint_is_stable_across_clones() {
        let hw = HwConfig::paper_for_model(ModelId::Qwen3_30B_A3B, DramKind::Hbm2);
        assert_eq!(hw.fingerprint(), hw.clone().fingerprint());
    }
}
