//! `mozart tenants` — multi-tenant wafer partitioning with a
//! partition-isolation oracle and per-tenant SLO accounting.
//!
//! One wafer, several independent workloads: the chiplet grid is split
//! into contiguous runs of switch groups (the partition unit — a group's
//! NoP trunk and DRAM channel cannot be shared between tenants), each
//! tenant's cell is evaluated on its carved sub-platform
//! ([`HwConfig::carve`]), and the fleet is scored on the minimized
//! triple ([`fleet_objectives`]): worst per-tenant SLO violation,
//! negated total token throughput, aggregate mean package power.
//!
//! * **Training tenants** (`train:MODEL:METHOD:WEIGHT`) run the step
//!   simulator on their sub-wafer; their throughput is tokens per
//!   training step over the mean step latency and their power is the
//!   step-energy mean
//!   ([`mean_power_w`](crate::metrics::energy::EnergyBreakdown::mean_power_w)).
//! * **Serving tenants** (`serve:MODEL:LOAD_RPS:SLO_MS`) get their own
//!   queueing instance ([`TenantServer`]): a service model built from
//!   real step simulations of the carved platform
//!   ([`build_service_model`]), a Poisson arrival stream at the
//!   declared load, and the same measurement path as `mozart serve`
//!   ([`measure_point`]) — so a tenant owning 100% of the wafer
//!   reproduces [`serve_cell_eval`](crate::coordinator::serve::serve_cell_eval)
//!   bit for bit.
//!
//! Four partitioning policies are swept: `even`, `weighted` (by
//! declared demand), `slo-greedy` (hill-climbs groups toward the worst
//! violator), and `search` (NSGA-II over the share vector, reusing the
//! constrained selection machinery of `metrics::pareto`). Every
//! evaluated feasible partition becomes one artifact point, and every
//! point's [`PartitionTrace`] is checked by [`PartitionTrace::validate`]
//! *unconditionally* before it is emitted — exclusive chiplet
//! ownership, NoP-subtree realizability, resource conservation against
//! the parent wafer, and the shared package power budget.
//!
//! Everything is seeded and thread-invariant: the same config
//! reproduces the same `TENANTS_*.json` bit for bit at any `--threads`.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::comm::NopTree;
use crate::config::{
    DramKind, ExperimentConfig, HwConfig, Method, ModelConfig, ModelId, PartitionSlice,
    SchedPolicy,
};
use crate::coordinator::cache::{EvalCtx, EvalOptions, EvalSession, EvalStats};
use crate::coordinator::serve::{build_service_model, measure_point, SERVICE_BUCKETS};
use crate::coordinator::sweep::parallel_map_with;
use crate::metrics::pareto::{constrained_selection_order, pareto_frontier};
use crate::metrics::slo::{fleet_objectives, slo_violation};
use crate::sim::serve::{ServeParams, TenantServer};
use crate::trace::arrivals::{ArrivalProcess, RequestShape};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// What one tenant runs on its slice of the wafer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TenantKind {
    /// A training tenant: repeated training steps of `method`, weighted
    /// by `weight` in the demand-proportional policy.
    Train {
        /// Mozart ablation the tenant trains with.
        method: Method,
        /// Relative demand weight (> 0) for the `weighted` policy.
        weight: f64,
    },
    /// A serving tenant: an open-loop Poisson stream against the
    /// tenant's own continuous-batching queue.
    Serve {
        /// Offered load, requests per second (> 0).
        load_rps: f64,
        /// Latency SLO on the p99 sojourn time, milliseconds (> 0).
        slo_ms: f64,
    },
}

/// One tenant of the multi-tenant wafer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantSpec {
    /// Model the tenant runs (paper Table 1 presets).
    pub model: ModelId,
    /// Training or serving workload, with its policy inputs.
    pub kind: TenantKind,
}

impl TenantSpec {
    /// Parse one CLI tenant spec: `train:MODEL:METHOD:WEIGHT` or
    /// `serve:MODEL:LOAD_RPS:SLO_MS` (model/method names as everywhere
    /// else on the CLI).
    pub fn parse(s: &str) -> std::result::Result<TenantSpec, String> {
        let parts: Vec<&str> = s.split(':').map(str::trim).collect();
        match parts.as_slice() {
            ["train", model, method, weight] => {
                let model = ModelId::from_name(model)
                    .ok_or_else(|| format!("unknown model `{model}` in tenant `{s}`"))?;
                let method = Method::from_name(method)
                    .ok_or_else(|| format!("unknown method `{method}` in tenant `{s}`"))?;
                let weight: f64 = weight
                    .parse()
                    .map_err(|_| format!("bad weight `{weight}` in tenant `{s}`"))?;
                if !(weight.is_finite() && weight > 0.0) {
                    return Err(format!("tenant weight must be > 0, got `{weight}` in `{s}`"));
                }
                Ok(TenantSpec {
                    model,
                    kind: TenantKind::Train { method, weight },
                })
            }
            ["serve", model, load, slo] => {
                let model = ModelId::from_name(model)
                    .ok_or_else(|| format!("unknown model `{model}` in tenant `{s}`"))?;
                let load_rps: f64 = load
                    .parse()
                    .map_err(|_| format!("bad load `{load}` in tenant `{s}`"))?;
                let slo_ms: f64 = slo
                    .parse()
                    .map_err(|_| format!("bad SLO `{slo}` in tenant `{s}`"))?;
                if !(load_rps.is_finite() && load_rps > 0.0) {
                    return Err(format!("tenant load must be > 0 req/s in `{s}`"));
                }
                if !(slo_ms.is_finite() && slo_ms > 0.0) {
                    return Err(format!("tenant SLO must be > 0 ms in `{s}`"));
                }
                Ok(TenantSpec {
                    model,
                    kind: TenantKind::Serve { load_rps, slo_ms },
                })
            }
            _ => Err(format!(
                "tenant `{s}` must be train:MODEL:METHOD:WEIGHT or serve:MODEL:LOAD_RPS:SLO_MS"
            )),
        }
    }

    /// Parse the comma-separated `--tenant` list.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<TenantSpec>, String> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            out.push(TenantSpec::parse(part)?);
        }
        if out.is_empty() {
            return Err("need at least one tenant spec".to_string());
        }
        Ok(out)
    }

    /// Stable human-readable label (artifact + report key).
    pub fn label(&self) -> String {
        match self.kind {
            TenantKind::Train { method, weight } => {
                format!("train:{}:{}:w{}", self.model.name(), method.name(), weight)
            }
            TenantKind::Serve { load_rps, slo_ms } => {
                format!("serve:{}:{}rps:{}ms", self.model.name(), load_rps, slo_ms)
            }
        }
    }

    /// Demand weight in the `weighted` policy: the declared training
    /// weight, or the declared serving load.
    pub fn weight(&self) -> f64 {
        match self.kind {
            TenantKind::Train { weight, .. } => weight,
            TenantKind::Serve { load_rps, .. } => load_rps,
        }
    }

    /// The method the tenant's step simulations run (serving tenants
    /// always serve the full Mozart method).
    pub fn method(&self) -> Method {
        match self.kind {
            TenantKind::Train { method, .. } => method,
            TenantKind::Serve { .. } => Method::MozartC,
        }
    }
}

/// How the share vector (groups per tenant) is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Equal group shares (largest remainder on equal weights).
    Even,
    /// Shares proportional to declared demand ([`TenantSpec::weight`]).
    Weighted,
    /// Hill-climb from `even`: move one group at a time to the worst
    /// SLO violator while the fleet objectives strictly improve.
    SloGreedy,
    /// NSGA-II over the share vector (the partition as a search gene),
    /// constrained by the package power budget.
    Search,
}

impl PartitionPolicy {
    /// Every policy, in sweep order.
    pub const ALL: [PartitionPolicy; 4] = [
        PartitionPolicy::Even,
        PartitionPolicy::Weighted,
        PartitionPolicy::SloGreedy,
        PartitionPolicy::Search,
    ];

    /// CLI / artifact name.
    pub fn name(self) -> &'static str {
        match self {
            PartitionPolicy::Even => "even",
            PartitionPolicy::Weighted => "weighted",
            PartitionPolicy::SloGreedy => "slo-greedy",
            PartitionPolicy::Search => "search",
        }
    }

    /// Inverse of [`PartitionPolicy::name`] (case-insensitive).
    pub fn from_name(s: &str) -> Option<PartitionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "even" => Some(PartitionPolicy::Even),
            "weighted" => Some(PartitionPolicy::Weighted),
            "slo-greedy" | "slo_greedy" | "greedy" => Some(PartitionPolicy::SloGreedy),
            "search" => Some(PartitionPolicy::Search),
            _ => None,
        }
    }

    /// Parse the `--policies` spelling: `all` or a comma-separated
    /// list, duplicates collapsed, order preserved.
    pub fn parse_list(s: &str) -> std::result::Result<Vec<PartitionPolicy>, String> {
        if s.trim().eq_ignore_ascii_case("all") {
            return Ok(PartitionPolicy::ALL.to_vec());
        }
        let mut out: Vec<PartitionPolicy> = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let p = PartitionPolicy::from_name(part)
                .ok_or_else(|| format!("unknown policy `{part}` (even|weighted|slo-greedy|search|all)"))?;
            if !out.contains(&p) {
                out.push(p);
            }
        }
        if out.is_empty() {
            return Err("need at least one partition policy".to_string());
        }
        Ok(out)
    }
}

/// Configuration of one multi-tenant partitioning sweep.
#[derive(Clone, Debug)]
pub struct TenantsConfig {
    /// The tenants sharing the wafer (CLI `--tenant`, comma-separated).
    pub tenants: Vec<TenantSpec>,
    /// DRAM technology of the parent platform.
    pub dram: DramKind,
    /// DAG scheduling policy for every step simulation.
    pub sched: SchedPolicy,
    /// Partitioning policies swept (each yields one share vector).
    pub policies: Vec<PartitionPolicy>,
    /// Sequence length of the training tenants' steps.
    pub seq_len: usize,
    /// Traffic duration per serving tenant, seconds.
    pub duration_s: f64,
    /// Shared package power budget, W (`f64::INFINITY` = unbounded;
    /// the CLI spells unbounded as `--power-budget 0`).
    pub budget_w: f64,
    /// Queueing-engine knobs for every serving tenant.
    pub params: ServeParams,
    /// Simulated iterations averaged per step evaluation.
    pub iters: usize,
    /// Master seed (step sims, arrival streams, the search policy).
    pub seed: u64,
    /// Worker threads (0/1 = sequential); never changes a result bit.
    pub threads: usize,
    /// NSGA-II population of the `search` policy.
    pub search_population: usize,
    /// NSGA-II generations of the `search` policy.
    pub search_generations: usize,
    /// Evaluation-throughput toggles for the step simulations.
    pub eval: EvalOptions,
}

impl TenantsConfig {
    /// Paper-flavoured default: one training tenant and one serving
    /// tenant of the fastest model, all four policies, no power cap.
    pub fn paper_default() -> TenantsConfig {
        TenantsConfig {
            tenants: vec![
                TenantSpec {
                    model: ModelId::OlmoE_1B_7B,
                    kind: TenantKind::Train {
                        method: Method::MozartC,
                        weight: 1.0,
                    },
                },
                TenantSpec {
                    model: ModelId::OlmoE_1B_7B,
                    kind: TenantKind::Serve {
                        load_rps: 100.0,
                        slo_ms: 50.0,
                    },
                },
            ],
            dram: DramKind::Hbm2,
            sched: SchedPolicy::Streaming,
            policies: PartitionPolicy::ALL.to_vec(),
            seq_len: 256,
            duration_s: 2.0,
            budget_w: f64::INFINITY,
            params: ServeParams::default(),
            iters: 2,
            seed: 0x4D6F_5A54, // "MoZT"
            threads: 0,
            search_population: 8,
            search_generations: 3,
            eval: EvalOptions::default(),
        }
    }
}

/// The experiment config a tenant's step simulations run: the paper
/// default of the tenant's (model, method) with the sweep's workload
/// knobs and `hw` — pass the carved sub-platform for a real tenant, or
/// the parent wafer to reproduce the un-partitioned path (the
/// single-tenant differential contract).
pub fn tenant_base_config(spec: &TenantSpec, hw: &HwConfig, cfg: &TenantsConfig) -> ExperimentConfig {
    let mut ec = ExperimentConfig::paper_default(
        ModelConfig::preset(spec.model),
        spec.method().config(),
    );
    ec.hw = hw.clone();
    ec.seq_len = cfg.seq_len;
    ec.iters = cfg.iters;
    ec.seed = cfg.seed;
    ec.sched = cfg.sched;
    ec
}

/// Measured outcome of one tenant on one partition. Fields that do not
/// apply to the tenant kind are zero (e.g. `p99_ms` for training).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantMetrics {
    /// Tenant label ([`TenantSpec::label`]).
    pub label: String,
    /// `"train"` or `"serve"`.
    pub kind: &'static str,
    /// Switch groups the tenant owns under this partition.
    pub groups: usize,
    /// Training: mean step latency. Serving: mean sojourn latency. ms.
    pub latency_ms: f64,
    /// Serving p99 sojourn latency, ms (0 for training tenants).
    pub p99_ms: f64,
    /// Serving SLO-goodput, requests/s (0 for training tenants).
    pub goodput_rps: f64,
    /// Declared SLO, ms (0 for training tenants).
    pub slo_ms: f64,
    /// Relative p99 SLO violation ([`slo_violation`]; 0 = within SLO,
    /// and always 0 for training tenants).
    pub slo_violation: f64,
    /// Tokens per second the tenant processes on its slice.
    pub tokens_per_s: f64,
    /// Mean package power the tenant draws, W.
    pub power_w: f64,
}

/// Evaluate one tenant on its carved slice of `parent`.
fn eval_tenant(
    ctx: &mut EvalCtx<'_>,
    cfg: &TenantsConfig,
    parent: &HwConfig,
    spec: &TenantSpec,
    slice: &PartitionSlice,
) -> TenantMetrics {
    let sub = parent.carve(slice);
    let base = tenant_base_config(spec, &sub, cfg);
    match spec.kind {
        TenantKind::Train { .. } => {
            let r = ctx.run(&base);
            TenantMetrics {
                label: spec.label(),
                kind: "train",
                groups: slice.groups,
                latency_ms: r.latency * 1e3,
                p99_ms: 0.0,
                goodput_rps: 0.0,
                slo_ms: 0.0,
                slo_violation: 0.0,
                tokens_per_s: base.tokens_per_step() as f64 / r.latency,
                power_w: r.energy.mean_power_w(r.latency),
            }
        }
        TenantKind::Serve { load_rps, slo_ms } => {
            // Identical op sequence to `serve_cell_eval` so the
            // single-tenant whole-wafer partition is bit-identical to
            // the un-partitioned serving path.
            let model = build_service_model(|ec| ctx.run(ec).latency, &base);
            let server = TenantServer {
                label: spec.label(),
                model,
                params: cfg.params.clone(),
            };
            let requests = ArrivalProcess::Poisson { rate: load_rps }.generate(
                cfg.duration_s,
                &RequestShape::default(),
                base.seed ^ 0x5E2E_CE11,
            );
            let trace = server.run(&requests);
            let p = measure_point(&trace, &server.model, 1.0, slo_ms / 1e3, cfg.duration_s, 0.0);
            // Power: the slice's busy power (largest service bucket — a
            // cache hit, it was just simulated for the model) derated by
            // the measured server utilization.
            let mut probe = base.clone();
            probe.seq_len = SERVICE_BUCKETS[SERVICE_BUCKETS.len() - 1];
            probe.batch_size = 1;
            probe.micro_batch = 1;
            let r = ctx.run(&probe);
            let busy_w = r.energy.mean_power_w(r.latency);
            TenantMetrics {
                label: spec.label(),
                kind: "serve",
                groups: slice.groups,
                latency_ms: p.mean_ms,
                p99_ms: p.p99_ms,
                goodput_rps: p.goodput_rps,
                slo_ms,
                slo_violation: slo_violation(p.p99_ms, slo_ms),
                tokens_per_s: p.tokens_per_s,
                power_w: busy_w * p.utilization.min(1.0),
            }
        }
    }
}

/// One evaluated partition: the share vector, its slices, every
/// tenant's metrics, and the fleet objectives.
#[derive(Clone, Debug)]
pub struct PartitionEval {
    /// Groups per tenant (the gene).
    pub shares: Vec<usize>,
    /// Per-tenant resource slices ([`HwConfig::partition_slices`]).
    pub slices: Vec<PartitionSlice>,
    /// Per-tenant measured metrics, tenant order.
    pub tenants: Vec<TenantMetrics>,
    /// Minimized fleet objectives ([`fleet_objectives`]).
    pub objectives: [f64; 3],
    /// Aggregate mean package power, W.
    pub power_w: f64,
    /// Whether the partition respects the package power budget.
    pub feasible: bool,
}

/// Memoizing partition evaluator shared by every policy: the same share
/// vector is never evaluated twice, and every evaluation is one
/// deterministic, thread-invariant parallel map over the tenants.
struct Evaluator<'a> {
    cfg: &'a TenantsConfig,
    parent: &'a HwConfig,
    session: &'a EvalSession,
    memo: BTreeMap<Vec<usize>, PartitionEval>,
}

impl Evaluator<'_> {
    fn eval(&mut self, shares: &[usize]) -> PartitionEval {
        if let Some(e) = self.memo.get(shares) {
            return e.clone();
        }
        let cfg = self.cfg;
        let parent = self.parent;
        let session = self.session;
        let slices = parent
            .partition_slices(shares)
            .expect("partition policies emit realizable share vectors");
        let jobs: Vec<(usize, PartitionSlice)> = slices.iter().copied().enumerate().collect();
        let tenants: Vec<TenantMetrics> = parallel_map_with(
            &jobs,
            cfg.threads,
            session.pools(),
            || session.new_pool(),
            |pool, &(ti, slice)| {
                let mut ctx = session.ctx(pool);
                eval_tenant(&mut ctx, cfg, parent, &cfg.tenants[ti], &slice)
            },
        );
        let power_w: f64 = tenants.iter().map(|t| t.power_w).sum();
        let violations: Vec<f64> = tenants.iter().map(|t| t.slo_violation).collect();
        let tokens: f64 = tenants.iter().map(|t| t.tokens_per_s).sum();
        let eval = PartitionEval {
            shares: shares.to_vec(),
            slices,
            tenants,
            objectives: fleet_objectives(&violations, tokens, power_w),
            power_w,
            feasible: power_w <= cfg.budget_w,
        };
        #[cfg(debug_assertions)]
        if eval.feasible {
            build_trace("debug", cfg, parent, &eval)
                .validate(parent)
                .expect("partition failed the isolation oracle");
        }
        self.memo.insert(shares.to_vec(), eval.clone());
        eval
    }
}

/// One tenant's entry in a [`PartitionTrace`].
#[derive(Clone, Debug)]
pub struct TenantAssignment {
    /// Tenant index (must equal the position in the assignment list).
    pub tenant: usize,
    /// Tenant label (diagnostics).
    pub label: String,
    /// The resource slice the tenant was planned.
    pub slice: PartitionSlice,
    /// Flat chiplet indices the tenant owns on the parent wafer.
    pub chiplets: Vec<usize>,
    /// Mean package power the tenant draws, W.
    pub power_w: f64,
}

/// The auditable record of one partition, checked by
/// [`PartitionTrace::validate`] — the PR's isolation oracle.
#[derive(Clone, Debug)]
pub struct PartitionTrace {
    /// Policy that proposed the partition (diagnostics).
    pub policy: String,
    /// Groups per tenant.
    pub shares: Vec<usize>,
    /// Per-tenant assignments, tenant order.
    pub assignments: Vec<TenantAssignment>,
    /// Owner per flat chiplet index (`None` = idle).
    pub chiplet_owner: Vec<Option<usize>>,
    /// Switch groups left idle.
    pub idle_groups: usize,
    /// Group DRAM stacks left idle.
    pub idle_group_dram_stacks: usize,
    /// Attention tiles left idle.
    pub idle_attn_tiles: usize,
    /// Aggregate mean package power, W.
    pub power_w: f64,
    /// Package power budget, W (`f64::INFINITY` = unbounded).
    pub budget_w: f64,
}

/// Build the auditable trace of one evaluated partition.
pub fn build_trace(
    policy: &str,
    cfg: &TenantsConfig,
    parent: &HwConfig,
    eval: &PartitionEval,
) -> PartitionTrace {
    let per = parent.chiplets_per_group();
    let mut chiplet_owner: Vec<Option<usize>> = vec![None; parent.n_moe_chiplets];
    let mut assignments = Vec::with_capacity(eval.slices.len());
    for (t, slice) in eval.slices.iter().enumerate() {
        let chiplets: Vec<usize> =
            (slice.start_group * per..(slice.start_group + slice.groups) * per).collect();
        for &c in &chiplets {
            chiplet_owner[c] = Some(t);
        }
        assignments.push(TenantAssignment {
            tenant: t,
            label: eval.tenants[t].label.clone(),
            slice: *slice,
            chiplets,
            power_w: eval.tenants[t].power_w,
        });
    }
    let owned_groups: usize = eval.shares.iter().sum();
    let owned_stacks: usize = eval.slices.iter().map(|s| s.group_dram_stacks).sum();
    let owned_tiles: usize = eval.slices.iter().map(|s| s.attn_tiles).sum();
    PartitionTrace {
        policy: policy.to_string(),
        shares: eval.shares.clone(),
        assignments,
        chiplet_owner,
        idle_groups: parent.n_groups - owned_groups,
        idle_group_dram_stacks: parent.mem.group_dram_stacks - owned_stacks,
        idle_attn_tiles: parent.attn_chiplet.tiles - owned_tiles,
        power_w: eval.power_w,
        budget_w: cfg.budget_w,
    }
}

impl PartitionTrace {
    /// The partition-isolation oracle. Rejects the trace unless:
    ///
    /// 1. **Tenant-id integrity** — assignments are non-empty, carry
    ///    their own index, and every chiplet owner refers to a live
    ///    tenant (no stale tenant ids);
    /// 2. **Exclusive assignment** — every chiplet belongs to at most
    ///    one tenant and the owner map matches the assignments;
    /// 3. **Subtree realizability** — each tenant's chiplets are a
    ///    contiguous whole-group run of the parent's NoP tree matching
    ///    its slice, so no NoP trunk is shared across tenants;
    /// 4. **Resource conservation** — groups, DRAM stacks and attention
    ///    tiles over tenants plus the idle remainder reconstruct the
    ///    parent exactly (and a single tenant owning everything carves
    ///    a platform fingerprint-identical to the parent);
    /// 5. **Power budget** — per-tenant powers are finite and
    ///    non-negative, their sum matches the aggregate, and the
    ///    aggregate respects the package budget.
    pub fn validate(&self, parent: &HwConfig) -> Result<()> {
        // 1. tenant-id integrity
        ensure!(!self.assignments.is_empty(), "partition has no tenants");
        for (i, a) in self.assignments.iter().enumerate() {
            ensure!(
                a.tenant == i,
                "stale tenant id: assignment {i} claims tenant {}",
                a.tenant
            );
        }
        ensure!(
            self.chiplet_owner.len() == parent.n_moe_chiplets,
            "owner map covers {} chiplets, wafer has {}",
            self.chiplet_owner.len(),
            parent.n_moe_chiplets
        );
        for (c, owner) in self.chiplet_owner.iter().enumerate() {
            if let Some(t) = owner {
                ensure!(
                    *t < self.assignments.len(),
                    "stale tenant id: chiplet {c} owned by unknown tenant {t}"
                );
            }
        }
        ensure!(
            self.shares.len() == self.assignments.len()
                && self
                    .shares
                    .iter()
                    .zip(self.assignments.iter())
                    .all(|(&s, a)| s == a.slice.groups),
            "share vector {:?} disagrees with the assignments",
            self.shares
        );

        // 2. exclusive assignment
        let mut owner: Vec<Option<usize>> = vec![None; parent.n_moe_chiplets];
        for a in &self.assignments {
            for &c in &a.chiplets {
                ensure!(c < owner.len(), "chiplet {c} outside the wafer");
                ensure!(
                    owner[c].is_none(),
                    "chiplet {c} assigned to more than one tenant ({} and {})",
                    owner[c].unwrap(),
                    a.tenant
                );
                owner[c] = Some(a.tenant);
            }
        }
        ensure!(
            owner == self.chiplet_owner,
            "chiplet owner map disagrees with the assignments"
        );

        // 3. subtree realizability on the parent's NoP tree
        let tree = NopTree::from_hw(parent);
        for a in &self.assignments {
            let run = tree.group_run_of(&a.chiplets);
            ensure!(
                run == Some((a.slice.start_group, a.slice.groups)),
                "tenant {} chiplets are not the contiguous whole-group NoP subtree \
                 [{}, +{}) its slice claims (got {run:?})",
                a.tenant,
                a.slice.start_group,
                a.slice.groups
            );
        }

        // 4. resource conservation vs the parent wafer
        let owned_groups: usize = self.assignments.iter().map(|a| a.slice.groups).sum();
        ensure!(
            owned_groups + self.idle_groups == parent.n_groups,
            "group conservation violated: {owned_groups} owned + {} idle != {} on the wafer",
            self.idle_groups,
            parent.n_groups
        );
        let owned_stacks: usize = self
            .assignments
            .iter()
            .map(|a| a.slice.group_dram_stacks)
            .sum();
        ensure!(
            owned_stacks + self.idle_group_dram_stacks == parent.mem.group_dram_stacks,
            "DRAM-stack conservation violated: {owned_stacks} owned + {} idle != {} on the wafer",
            self.idle_group_dram_stacks,
            parent.mem.group_dram_stacks
        );
        let owned_tiles: usize = self.assignments.iter().map(|a| a.slice.attn_tiles).sum();
        ensure!(
            owned_tiles + self.idle_attn_tiles == parent.attn_chiplet.tiles,
            "attention-tile conservation violated: {owned_tiles} owned + {} idle != {} on the chiplet",
            self.idle_attn_tiles,
            parent.attn_chiplet.tiles
        );
        for a in &self.assignments {
            ensure!(
                a.slice.group_dram_stacks >= 1 && a.slice.attn_tiles >= 1,
                "tenant {} slice starves a resource class: {:?}",
                a.tenant,
                a.slice
            );
        }
        if self.assignments.len() == 1 && owned_groups == parent.n_groups {
            // the single-tenant whole-wafer partition must be
            // indistinguishable from the un-partitioned platform
            let sub = parent.carve(&self.assignments[0].slice);
            ensure!(
                sub.fingerprint() == parent.fingerprint(),
                "single-tenant whole-wafer carve does not reproduce the parent platform"
            );
        }

        // 5. power accounting and the package budget
        let mut sum = 0.0;
        for a in &self.assignments {
            ensure!(
                a.power_w.is_finite() && a.power_w >= 0.0,
                "tenant {} power {} W is not a sane draw",
                a.tenant,
                a.power_w
            );
            sum += a.power_w;
        }
        ensure!(
            (sum - self.power_w).abs() <= 1e-9 * self.power_w.abs().max(1.0),
            "aggregate power {} W does not match the per-tenant sum {} W",
            self.power_w,
            sum
        );
        ensure!(
            self.power_w <= self.budget_w,
            "aggregate power {:.1} W exceeds the package power budget {:.1} W",
            self.power_w,
            self.budget_w
        );
        Ok(())
    }
}

/// Equal shares: every tenant gets the same group count (largest
/// remainder, floor one group each, no idle remainder).
pub fn even_shares(tenants: usize, parent: &HwConfig) -> Vec<usize> {
    crate::config::split_proportional(parent.n_groups, &vec![1.0; tenants], 1, 0.0)
}

/// Demand-proportional shares ([`TenantSpec::weight`]).
pub fn weighted_shares(specs: &[TenantSpec], parent: &HwConfig) -> Vec<usize> {
    let weights: Vec<f64> = specs.iter().map(TenantSpec::weight).collect();
    crate::config::split_proportional(parent.n_groups, &weights, 1, 0.0)
}

/// A random share vector: one group each, remainder scattered.
pub fn random_shares(rng: &mut Rng, tenants: usize, groups: usize) -> Vec<usize> {
    assert!(tenants >= 1 && groups >= tenants, "{tenants} tenants > {groups} groups");
    let mut shares = vec![1usize; tenants];
    for _ in 0..groups - tenants {
        shares[rng.below(tenants)] += 1;
    }
    shares
}

/// Seeded mutation: move one group from a random donor (share > 1) to a
/// random other tenant. No-op when no move is possible.
pub fn mutate_shares(rng: &mut Rng, shares: &mut [usize]) {
    if shares.len() < 2 {
        return;
    }
    let donors: Vec<usize> = (0..shares.len()).filter(|&i| shares[i] > 1).collect();
    if donors.is_empty() {
        return;
    }
    let d = donors[rng.below(donors.len())];
    let mut r = rng.below(shares.len() - 1);
    if r >= d {
        r += 1;
    }
    shares[d] -= 1;
    shares[r] += 1;
}

/// Seeded uniform crossover with deterministic repair: each gene comes
/// from either parent, then groups are taken from the largest gene (or
/// given to the smallest) until the child sums to `groups` with every
/// gene >= 1.
pub fn crossover_shares(rng: &mut Rng, a: &[usize], b: &[usize], groups: usize) -> Vec<usize> {
    assert_eq!(a.len(), b.len(), "crossover arity mismatch");
    let mut c: Vec<usize> = a
        .iter()
        .zip(b.iter())
        .map(|(&x, &y)| if rng.f64() < 0.5 { x } else { y })
        .collect();
    loop {
        let sum: usize = c.iter().sum();
        if sum == groups {
            return c;
        }
        if sum > groups {
            // take from the largest gene that can give (ties: lowest index)
            let i = (0..c.len())
                .filter(|&i| c[i] > 1)
                .max_by(|&x, &y| c[x].cmp(&c[y]).then(y.cmp(&x)))
                .expect("sum > groups >= tenants implies a gene > 1");
            c[i] -= 1;
        } else {
            // give to the smallest gene (ties: lowest index)
            let i = (0..c.len())
                .min_by(|&x, &y| c[x].cmp(&c[y]).then(x.cmp(&y)))
                .expect("crossover needs at least one gene");
            c[i] += 1;
        }
    }
}

/// The `slo-greedy` policy: from even shares, repeatedly move one group
/// from the least-violating donor to the worst SLO violator, keeping a
/// move only if the fleet objectives strictly improve lexicographically
/// (worst violation, then negated throughput). Never worse than `even`.
fn slo_greedy(ev: &mut Evaluator<'_>) -> Vec<usize> {
    let mut shares = even_shares(ev.cfg.tenants.len(), ev.parent);
    let mut cur = ev.eval(&shares);
    for _ in 0..2 * ev.parent.n_groups {
        let worst = cur
            .tenants
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.slo_violation.total_cmp(&b.1.slo_violation).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .expect("at least one tenant");
        if cur.tenants[worst].slo_violation <= 0.0 {
            break; // every tenant already meets its SLO
        }
        let donor = cur
            .tenants
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != worst && shares[i] > 1)
            .min_by(|a, b| a.1.slo_violation.total_cmp(&b.1.slo_violation).then(a.0.cmp(&b.0)))
            .map(|(i, _)| i);
        let Some(donor) = donor else { break };
        let mut cand = shares.clone();
        cand[donor] -= 1;
        cand[worst] += 1;
        let ce = ev.eval(&cand);
        if (ce.objectives[0], ce.objectives[1]) < (cur.objectives[0], cur.objectives[1]) {
            shares = cand;
            cur = ce;
        } else {
            break;
        }
    }
    shares
}

/// The `search` policy: NSGA-II over the share vector, seeded from the
/// deterministic policies, constrained by the power budget, returning
/// the best feasible partition evaluated anywhere in the run.
fn search_shares(ev: &mut Evaluator<'_>) -> Vec<usize> {
    let t = ev.cfg.tenants.len();
    let g = ev.parent.n_groups;
    let pop_n = ev.cfg.search_population.max(2);
    let mut rng = Rng::new(ev.cfg.seed ^ 0x7E4A_475E);
    let mut pop: Vec<Vec<usize>> = vec![
        even_shares(t, ev.parent),
        weighted_shares(&ev.cfg.tenants, ev.parent),
    ];
    pop.dedup();
    while pop.len() < pop_n {
        pop.push(random_shares(&mut rng, t, g));
    }
    for _ in 0..ev.cfg.search_generations {
        let mut children = Vec::with_capacity(pop.len());
        for _ in 0..pop.len() {
            let pa = pop[rng.below(pop.len())].clone();
            let pb = pop[rng.below(pop.len())].clone();
            let mut child = crossover_shares(&mut rng, &pa, &pb, g);
            mutate_shares(&mut rng, &mut child);
            children.push(child);
        }
        let mut all = pop.clone();
        all.extend(children);
        all.sort();
        all.dedup();
        let evals: Vec<PartitionEval> = all.iter().map(|s| ev.eval(s)).collect();
        let pts: Vec<Vec<f64>> = evals.iter().map(|e| e.objectives.to_vec()).collect();
        // constraint violation = watts over budget (0 under an
        // unbounded budget: x - inf saturates below zero)
        let viol: Vec<f64> = evals
            .iter()
            .map(|e| (e.power_w - ev.cfg.budget_w).max(0.0))
            .collect();
        let order = constrained_selection_order(&pts, &viol);
        pop = order.iter().take(pop_n).map(|&i| all[i].clone()).collect();
    }
    best_shares(ev)
}

/// The best share vector evaluated so far: feasible before infeasible,
/// then lexicographic on the minimized objectives; deterministic ties
/// resolve to the memo's (sorted) first entry.
fn best_shares(ev: &Evaluator<'_>) -> Vec<usize> {
    let mut best: Option<(Vec<usize>, (u8, f64, f64, f64))> = None;
    for (s, e) in &ev.memo {
        let key = (
            u8::from(!e.feasible),
            e.objectives[0],
            e.objectives[1],
            e.objectives[2],
        );
        let replace = match &best {
            None => true,
            Some((_, bk)) => key < *bk,
        };
        if replace {
            best = Some((s.clone(), key));
        }
    }
    best.expect("search evaluated at least one partition").0
}

/// One policy's chosen partition.
#[derive(Clone, Debug)]
pub struct PolicyOutcome {
    /// The policy.
    pub policy: PartitionPolicy,
    /// The share vector it chose.
    pub shares: Vec<usize>,
    /// Whether the chosen partition respects the power budget.
    pub feasible: bool,
    /// Its fleet objectives.
    pub objectives: [f64; 3],
}

/// One evaluated partition in the artifact.
#[derive(Clone, Debug)]
pub struct PartitionPoint {
    /// Groups per tenant.
    pub shares: Vec<usize>,
    /// Per-tenant metrics.
    pub tenants: Vec<TenantMetrics>,
    /// Minimized fleet objectives.
    pub objectives: [f64; 3],
    /// Aggregate mean package power, W.
    pub power_w: f64,
    /// Whether the partition respects the power budget.
    pub feasible: bool,
    /// The validated isolation trace (feasible partitions only —
    /// over-budget points are reported but carry no realizable trace).
    pub trace: Option<PartitionTrace>,
}

/// Outcome of one multi-tenant partitioning sweep.
#[derive(Clone, Debug)]
pub struct TenantsOutcome {
    /// Sweep configuration echo.
    pub cfg: TenantsConfig,
    /// The parent (un-partitioned) wafer.
    pub parent: HwConfig,
    /// One outcome per swept policy, `cfg.policies` order.
    pub policies: Vec<PolicyOutcome>,
    /// Every distinct evaluated partition, sorted by share vector.
    pub points: Vec<PartitionPoint>,
    /// Indices into `points` of the feasible Pareto frontier over
    /// (worst SLO violation, -total tokens/s, power).
    pub frontier: Vec<usize>,
    /// Evaluation accounting for the step simulations.
    pub eval: EvalStats,
}

/// Run the sweep: evaluate every policy's partition (sharing one
/// memoized evaluator, so policies agreeing on a share vector cost one
/// evaluation), validate every feasible partition against the isolation
/// oracle, and take the feasible Pareto frontier.
pub fn run(cfg: &TenantsConfig) -> TenantsOutcome {
    assert!(!cfg.tenants.is_empty(), "tenants sweep needs tenants");
    assert!(!cfg.policies.is_empty(), "tenants sweep needs a policy");
    assert!(cfg.duration_s > 0.0, "serve duration must be > 0");
    assert!(cfg.budget_w > 0.0, "power budget must be > 0 (or unbounded)");
    let parent = HwConfig::mozart_wafer(cfg.dram);
    assert!(
        cfg.tenants.len() <= parent.n_groups,
        "{} tenants cannot each own a switch group on a {}-group wafer",
        cfg.tenants.len(),
        parent.n_groups
    );
    let session = EvalSession::new(cfg.eval.clone());
    let mut ev = Evaluator {
        cfg,
        parent: &parent,
        session: &session,
        memo: BTreeMap::new(),
    };
    let mut policies = Vec::with_capacity(cfg.policies.len());
    for &p in &cfg.policies {
        let shares = match p {
            PartitionPolicy::Even => even_shares(cfg.tenants.len(), &parent),
            PartitionPolicy::Weighted => weighted_shares(&cfg.tenants, &parent),
            PartitionPolicy::SloGreedy => slo_greedy(&mut ev),
            PartitionPolicy::Search => search_shares(&mut ev),
        };
        let e = ev.eval(&shares);
        policies.push(PolicyOutcome {
            policy: p,
            shares,
            feasible: e.feasible,
            objectives: e.objectives,
        });
    }
    let mut points = Vec::with_capacity(ev.memo.len());
    for (shares, e) in &ev.memo {
        let trace = if e.feasible {
            let tr = build_trace("evaluated", cfg, &parent, e);
            // every emitted partition passes the oracle, in every build
            tr.validate(&parent)
                .expect("partition failed the isolation oracle");
            Some(tr)
        } else {
            None
        };
        points.push(PartitionPoint {
            shares: shares.clone(),
            tenants: e.tenants.clone(),
            objectives: e.objectives,
            power_w: e.power_w,
            feasible: e.feasible,
            trace,
        });
    }
    let feas: Vec<usize> = (0..points.len()).filter(|&i| points[i].feasible).collect();
    let objs: Vec<Vec<f64>> = feas.iter().map(|&i| points[i].objectives.to_vec()).collect();
    let frontier: Vec<usize> = pareto_frontier(&objs).into_iter().map(|k| feas[k]).collect();
    drop(ev);
    TenantsOutcome {
        cfg: cfg.clone(),
        parent,
        policies,
        points,
        frontier,
        eval: session.finish(),
    }
}

impl TenantsOutcome {
    /// Human-readable report: the policy table plus per-tenant metrics
    /// of every policy's chosen partition.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# Multi-tenant wafer partitioning\n\n");
        out.push_str(&format!(
            "- wafer: {} groups x {} chiplets/group, {} DRAM stacks, {} attention tiles ({})\n",
            self.parent.n_groups,
            self.parent.chiplets_per_group(),
            self.parent.mem.group_dram_stacks,
            self.parent.attn_chiplet.tiles,
            self.cfg.dram.name(),
        ));
        out.push_str(&format!(
            "- power budget: {}\n- tenants:\n",
            if self.cfg.budget_w.is_finite() {
                format!("{:.0} W", self.cfg.budget_w)
            } else {
                "unbounded".to_string()
            }
        ));
        for t in &self.cfg.tenants {
            out.push_str(&format!("  - {}\n", t.label()));
        }
        out.push('\n');

        let mut pt = Table::new(
            "policies",
            &["policy", "shares", "feasible", "worst SLO viol", "tokens/s", "power W"],
        );
        for p in &self.policies {
            pt.row(&[
                p.policy.name().to_string(),
                format!("{:?}", p.shares),
                format!("{}", p.feasible),
                format!("{:.4}", p.objectives[0]),
                format!("{:.1}", -p.objectives[1]),
                format!("{:.1}", p.objectives[2]),
            ]);
        }
        out.push_str(&pt.render());
        out.push('\n');

        for p in &self.policies {
            let Some(point) = self.points.iter().find(|x| x.shares == p.shares) else {
                continue;
            };
            let mut tt = Table::new(
                &format!("{} partition {:?}", p.policy.name(), p.shares),
                &["tenant", "groups", "lat ms", "p99 ms", "SLO ms", "viol", "tokens/s", "power W"],
            );
            for t in &point.tenants {
                tt.row(&[
                    t.label.clone(),
                    format!("{}", t.groups),
                    format!("{:.2}", t.latency_ms),
                    format!("{:.2}", t.p99_ms),
                    format!("{:.0}", t.slo_ms),
                    format!("{:.4}", t.slo_violation),
                    format!("{:.1}", t.tokens_per_s),
                    format!("{:.1}", t.power_w),
                ]);
            }
            out.push_str(&tt.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "frontier: {} of {} evaluated partitions are Pareto-optimal \
             (worst SLO violation, total throughput, power)\n",
            self.frontier.len(),
            self.points.len()
        ));
        out
    }

    /// Machine-readable artifact (`TENANTS_*.json`, schema version 1).
    /// Re-validates every emitted partition trace against the isolation
    /// oracle before rendering.
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .cfg
            .tenants
            .iter()
            .map(|t| match t.kind {
                TenantKind::Train { method, weight } => Json::obj([
                    ("kind", Json::str("train")),
                    ("model", Json::str(t.model.name())),
                    ("method", Json::str(method.name())),
                    ("weight", Json::num(weight)),
                    ("label", Json::str(&t.label())),
                ]),
                TenantKind::Serve { load_rps, slo_ms } => Json::obj([
                    ("kind", Json::str("serve")),
                    ("model", Json::str(t.model.name())),
                    ("load_rps", Json::num(load_rps)),
                    ("slo_ms", Json::num(slo_ms)),
                    ("label", Json::str(&t.label())),
                ]),
            })
            .collect();
        let policies: Vec<Json> = self
            .policies
            .iter()
            .map(|p| {
                Json::obj([
                    ("policy", Json::str(p.policy.name())),
                    (
                        "shares",
                        Json::Arr(p.shares.iter().map(|&s| Json::int(s)).collect()),
                    ),
                    ("feasible", Json::Bool(p.feasible)),
                    (
                        "objectives",
                        Json::Arr(p.objectives.iter().map(|&o| Json::num(o)).collect()),
                    ),
                ])
            })
            .collect();
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let tenants: Vec<Json> = p
                    .tenants
                    .iter()
                    .map(|t| {
                        Json::obj([
                            ("label", Json::str(&t.label)),
                            ("kind", Json::str(t.kind)),
                            ("groups", Json::int(t.groups)),
                            ("latency_ms", Json::num(t.latency_ms)),
                            ("p99_ms", Json::num(t.p99_ms)),
                            ("goodput_rps", Json::num(t.goodput_rps)),
                            ("slo_ms", Json::num(t.slo_ms)),
                            ("slo_violation", Json::num(t.slo_violation)),
                            ("tokens_per_s", Json::num(t.tokens_per_s)),
                            ("power_w", Json::num(t.power_w)),
                        ])
                    })
                    .collect();
                let trace = p.trace.as_ref().map(|tr| {
                    // the artifact only ever carries oracle-clean traces
                    tr.validate(&self.parent)
                        .expect("partition failed the isolation oracle");
                    let assignments: Vec<Json> = tr
                        .assignments
                        .iter()
                        .map(|a| {
                            Json::obj([
                                ("tenant", Json::int(a.tenant)),
                                ("label", Json::str(&a.label)),
                                ("start_group", Json::int(a.slice.start_group)),
                                ("groups", Json::int(a.slice.groups)),
                                ("group_dram_stacks", Json::int(a.slice.group_dram_stacks)),
                                ("attn_tiles", Json::int(a.slice.attn_tiles)),
                                (
                                    "chiplets",
                                    Json::Arr(a.chiplets.iter().map(|&c| Json::int(c)).collect()),
                                ),
                                ("power_w", Json::num(a.power_w)),
                            ])
                        })
                        .collect();
                    let owner: Vec<Json> = tr
                        .chiplet_owner
                        .iter()
                        .map(|o| match o {
                            Some(t) => Json::int(*t),
                            None => Json::num(-1.0), // -1 = idle chiplet
                        })
                        .collect();
                    Json::obj([
                        ("assignments", Json::Arr(assignments)),
                        ("chiplet_owner", Json::Arr(owner)),
                        ("idle_groups", Json::int(tr.idle_groups)),
                        ("idle_group_dram_stacks", Json::int(tr.idle_group_dram_stacks)),
                        ("idle_attn_tiles", Json::int(tr.idle_attn_tiles)),
                    ])
                });
                Json::obj([
                    (
                        "shares",
                        Json::Arr(p.shares.iter().map(|&s| Json::int(s)).collect()),
                    ),
                    ("feasible", Json::Bool(p.feasible)),
                    ("worst_slo_violation", Json::num(p.objectives[0])),
                    ("total_tokens_per_s", Json::num(-p.objectives[1])),
                    ("power_w", Json::num(p.power_w)),
                    (
                        "objectives",
                        Json::Arr(p.objectives.iter().map(|&o| Json::num(o)).collect()),
                    ),
                    ("tenants", Json::Arr(tenants)),
                    (
                        "partition",
                        trace.unwrap_or(Json::Bool(false)),
                    ),
                ])
            })
            .collect();
        Json::obj([
            ("artifact", Json::str("tenants")),
            ("version", Json::int(1)),
            ("dram", Json::str(self.cfg.dram.name())),
            ("sched", Json::str(self.cfg.sched.name())),
            ("seq_len", Json::int(self.cfg.seq_len)),
            ("duration_s", Json::num(self.cfg.duration_s)),
            ("iters", Json::int(self.cfg.iters)),
            // string, not number: JSON numbers are f64 and would corrupt
            // u64 seeds above 2^53, breaking reproduction from the artifact
            ("seed", Json::str(self.cfg.seed.to_string())),
            // 0 spells "unbounded" (JSON has no Infinity literal)
            (
                "power_budget_w",
                Json::num(if self.cfg.budget_w.is_finite() {
                    self.cfg.budget_w
                } else {
                    0.0
                }),
            ),
            ("oracle", Json::str("validated")),
            (
                "wafer",
                Json::obj([
                    ("n_groups", Json::int(self.parent.n_groups)),
                    ("n_moe_chiplets", Json::int(self.parent.n_moe_chiplets)),
                    (
                        "group_dram_stacks",
                        Json::int(self.parent.mem.group_dram_stacks),
                    ),
                    ("attn_tiles", Json::int(self.parent.attn_chiplet.tiles)),
                ]),
            ),
            ("tenants", Json::Arr(tenants)),
            ("policies", Json::Arr(policies)),
            ("points", Json::Arr(points)),
            (
                "frontier",
                Json::Arr(self.frontier.iter().map(|&i| Json::int(i)).collect()),
            ),
            ("cache", self.eval.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(threads: usize) -> TenantsConfig {
        TenantsConfig {
            tenants: vec![
                TenantSpec {
                    model: ModelId::TinyMoE,
                    kind: TenantKind::Train {
                        method: Method::MozartC,
                        weight: 1.0,
                    },
                },
                TenantSpec {
                    model: ModelId::TinyMoE,
                    kind: TenantKind::Serve {
                        load_rps: 60.0,
                        slo_ms: 50.0,
                    },
                },
            ],
            policies: vec![PartitionPolicy::Even, PartitionPolicy::Weighted],
            seq_len: 64,
            duration_s: 0.5,
            iters: 1,
            seed: 13,
            threads,
            ..TenantsConfig::paper_default()
        }
    }

    #[test]
    fn tenant_spec_parse_roundtrip_and_errors() {
        let t = TenantSpec::parse("train:tiny:mozart-c:2.5").unwrap();
        assert_eq!(t.model, ModelId::TinyMoE);
        assert_eq!(
            t.kind,
            TenantKind::Train {
                method: Method::MozartC,
                weight: 2.5
            }
        );
        assert_eq!(t.weight(), 2.5);
        let s = TenantSpec::parse("serve:olmoe:120:50").unwrap();
        assert_eq!(s.model, ModelId::OlmoE_1B_7B);
        assert_eq!(s.method(), Method::MozartC);
        assert!(s.label().contains("120rps"));
        for bad in [
            "train:tiny:mozart-c", // missing weight
            "serve:tiny:0:50",     // zero load
            "serve:tiny:100:0",    // zero SLO
            "train:gpt5:c:1",      // unknown model
            "train:tiny:z:1",      // unknown method
            "park:tiny:c:1",       // unknown kind
        ] {
            assert!(TenantSpec::parse(bad).is_err(), "`{bad}` parsed");
        }
        let list = TenantSpec::parse_list("train:tiny:c:1, serve:tiny:80:40").unwrap();
        assert_eq!(list.len(), 2);
        assert!(TenantSpec::parse_list("  ,  ").is_err());
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in PartitionPolicy::ALL {
            assert_eq!(PartitionPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(
            PartitionPolicy::parse_list("all").unwrap(),
            PartitionPolicy::ALL.to_vec()
        );
        assert_eq!(
            PartitionPolicy::parse_list("even,search,even").unwrap(),
            vec![PartitionPolicy::Even, PartitionPolicy::Search]
        );
        assert!(PartitionPolicy::parse_list("fair").is_err());
    }

    #[test]
    fn even_and_weighted_shares_conserve_the_wafer() {
        let parent = HwConfig::mozart_wafer(DramKind::Hbm2);
        let cfg = tiny(0);
        let even = even_shares(cfg.tenants.len(), &parent);
        assert_eq!(even.iter().sum::<usize>(), parent.n_groups);
        assert!(even.iter().all(|&s| s >= 1));
        let mut heavy = cfg.tenants.clone();
        heavy[1] = TenantSpec {
            model: ModelId::TinyMoE,
            kind: TenantKind::Serve {
                load_rps: 300.0,
                slo_ms: 50.0,
            },
        };
        let w = weighted_shares(&heavy, &parent);
        assert_eq!(w.iter().sum::<usize>(), parent.n_groups);
        assert!(w[1] > w[0], "heavier tenant should own more groups: {w:?}");
    }

    #[test]
    fn seeded_share_operators_are_reproducible() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let sa = random_shares(&mut a, 3, 8);
        let sb = random_shares(&mut b, 3, 8);
        assert_eq!(sa, sb);
        assert_eq!(sa.iter().sum::<usize>(), 8);
        let (mut ma, mut mb) = (sa.clone(), sb);
        mutate_shares(&mut a, &mut ma);
        mutate_shares(&mut b, &mut mb);
        assert_eq!(ma, mb);
        assert_eq!(ma.iter().sum::<usize>(), 8);
        let ca = crossover_shares(&mut a, &sa, &ma, 8);
        let cb = crossover_shares(&mut b, &sa, &ma, 8);
        assert_eq!(ca, cb);
        assert_eq!(ca.iter().sum::<usize>(), 8);
        assert!(ca.iter().all(|&s| s >= 1));
    }

    #[test]
    fn run_emits_validated_points_and_a_frontier() {
        let out = run(&tiny(1));
        assert_eq!(out.policies.len(), 2);
        assert!(!out.points.is_empty());
        for p in &out.points {
            assert_eq!(p.tenants.len(), 2);
            assert!(p.feasible, "unbounded budget cannot be infeasible");
            let tr = p.trace.as_ref().expect("feasible point carries a trace");
            tr.validate(&out.parent).expect("oracle");
            assert!(p.power_w > 0.0);
            assert_eq!(p.tenants[0].kind, "train");
            assert_eq!(p.tenants[1].kind, "serve");
            assert!(p.tenants[0].tokens_per_s > 0.0);
        }
        assert!(!out.frontier.is_empty());
        for &i in &out.frontier {
            assert!(i < out.points.len());
        }
        let md = out.render_markdown();
        assert!(md.contains("policies"));
        assert!(md.contains("frontier:"));
        let js = out.to_json().render_pretty();
        for key in [
            "\"artifact\": \"tenants\"",
            "\"oracle\": \"validated\"",
            "\"power_budget_w\"",
            "\"worst_slo_violation\"",
            "\"slo_violation\"",
            "\"chiplet_owner\"",
            "\"frontier\"",
            "\"seed\": \"13\"",
        ] {
            assert!(js.contains(key), "missing {key}");
        }
    }

    #[test]
    fn over_budget_partitions_are_reported_infeasible_without_traces() {
        let mut cfg = tiny(0);
        cfg.budget_w = 1e-3; // nothing fits
        let out = run(&cfg);
        assert!(out.points.iter().all(|p| !p.feasible && p.trace.is_none()));
        assert!(out.frontier.is_empty());
        assert!(out.policies.iter().all(|p| !p.feasible));
    }
}
