//! `mozart` CLI — the L3 coordinator entrypoint.
//!
//! [`HELP`] below is the single source of truth for the subcommand list and
//! every flag; a unit test asserts each subcommand in [`SUBCOMMANDS`]
//! appears there, so the dispatch table and the documentation cannot drift.

use anyhow::{bail, Context, Result};
use mozart::comm::FaultScenario;
use mozart::config::{
    DramKind, ExperimentConfig, HwOverride, Method, ModelConfig, ModelId, SchedPolicy,
};
use mozart::coordinator::cache::{EvalOptions, EvalSession};
use mozart::coordinator::degrade::{self, DegradeConfig};
use mozart::coordinator::explore::{self, ExploreConfig};
use mozart::coordinator::search::{
    self, Constraints, MinResilience, Objective, SearchConfig, SearchStrategy,
};
use mozart::coordinator::serve::{self, ServeConfig};
use mozart::coordinator::sweep::{
    self, cell_config, cell_config_sched, parallel_map_with, run_cells_seq, run_cells_sched,
    run_cells_with, Cell, SweepOptions,
};
use mozart::coordinator::tenants::{self, PartitionPolicy, TenantSpec, TenantsConfig};
use mozart::report::{self, ReportOpts};
use mozart::sim::serve::BatchClose;
use mozart::testkit::bench;
use mozart::trace::arrivals::ArrivalProcess;
use mozart::util::cli::Args;
use mozart::util::json::Json;

/// Every dispatchable subcommand, in help order.
const SUBCOMMANDS: [&str; 11] = [
    "report", "simulate", "layout", "bench", "explore", "degrade", "serve", "tenants",
    "train", "platform", "help",
];

/// The full usage text (`mozart help`). Documents every subcommand and every
/// flag in one place; keep in sync with the `match` in [`main`] (enforced by
/// the `help_lists_every_subcommand` test).
const HELP: &str = "\
mozart — MoE training on 3.5D wafer-scale chiplets (NeurIPS 2025 reproduction)

USAGE: mozart <command> [options]

COMMANDS:
  report <what>   regenerate a paper table/figure: table1 table2 table3
                  table4 fig1 fig3 fig6b fig6c fig7 fig8 fig9 fig10_13
                  fig14_16 q1 q2 q3 all   [--iters N] [--seed N]
  simulate        one experiment cell: --model qwen3|olmoe|deepseek|tiny
                  --method baseline|a|b|c [--seq N] [--dram hbm2|ssd]
                  [--sched streaming|list|heft|greedy]
                  [--iters N] [--seed N] [--config file]
  layout          expert clustering + allocation: --model ... [--seed N]
  bench           time the sweep + explore + search grids (sequential vs
                  parallel executor) and write BENCH_sweep.json. The search
                  grid also times a duplicate-heavy evaluation batch through
                  every (memoization x delta-re-timing) mode and reports
                  evaluations/second plus the speedup over the no-reuse
                  baseline. The sched grid times the Table 3 sweep under
                  every scheduling policy (per-policy cells/second) and
                  checks streaming reproduces the default path bit for bit.
                  The serve grid times a short saturation sweep (simulated
                  requests/second, sequential vs parallel load points,
                  bit-identical by construction). The tenants grid times a
                  small two-tenant partition sweep sequentially and in
                  parallel, bailing if any per-tenant metric diverges by a
                  bit:
                  [--grid table3|appendix|explore|search|degrade|sched|serve
                   |tenants|all]
                  [--iters N]
                  [--seed N] [--threads N] [--reps N] [--out BENCH_sweep.json]
  explore         design-space exploration: enumerate or search a hardware
                  axis grid, run every (variant x model x method) cell,
                  report the Pareto frontier over (latency, energy, area) vs
                  the paper's Table 2 point, and write an EXPLORE_*.json
                  artifact. With --strategy, a guided search maintains a
                  streaming archive over the JOINT (worst-case across models)
                  objectives and records a per-generation convergence curve;
                  --strategy evolutionary is a constrained NSGA-II (uniform
                  crossover + non-dominated-sort rank / crowding-distance
                  selection). --max-area/--max-power are hard caps: the
                  frontier only admits candidates inside the budgets, and
                  infeasible candidates rank behind all feasible ones.
                  --methods (requires --strategy) makes the Mozart ablation
                  a searchable gene (each candidate picks one method), so
                  the frontier answers which ablation to deploy on which
                  platform.
                  --sched pins one DAG scheduling policy for every cell;
                  --scheds evaluates several. Without --strategy the grid
                  explorer runs every listed policy per variant and reports
                  a per-platform schedule frontier (which policy wins on
                  which hardware); with --strategy the policy becomes a
                  searchable gene, one per candidate, alongside --methods.
                  --min-resilience FRAC:SCENARIO additionally requires each
                  candidate to retain at least FRAC of its healthy
                  throughput under the injected fault SCENARIO (same
                  grammar as degrade's --fault), rejecting fragile
                  platforms the unconstrained search would keep.
                  --objective p99|goodput (requires --strategy) swaps the
                  first minimized objective from training-step latency to
                  an online-serving score: every candidate replays one
                  fixed seeded arrival stream against its own simulated
                  service model (see `serve`) and is scored on its
                  worst-case serving p99 (minimized) or SLO-goodput
                  (maximized) across models.
                  Evaluation reuse is on by default and bit-transparent:
                  identical cells are served from a memoization cache and
                  timing-only variants re-time a pooled topology instead of
                  rebuilding it (--no-eval-cache / --no-delta-retime turn
                  the layers off; --cache-file persists the cache across
                  runs). --surrogate-frac F (0 < F <= 1, default 1 = off)
                  ranks each generation's offspring by a cheap roofline
                  estimate and fully simulates only the top fraction,
                  logging the surrogate-vs-simulator Spearman rho per
                  generation:
                  [--axes tiles,nop_bw,dram | tiles=36:64:100,
                   knob=dram_eff:0.6:0.95,...]
                  [--strategy exhaustive|random|evolutionary]
                  [--objective latency|p99|goodput]
                  [--budget N] [--samples N] [--population N]
                  [--generations N] [--crossover R] [--mutation R]
                  [--max-area MM2] [--max-power W]
                  [--min-resilience FRAC:SCENARIO]
                  [--surrogate-frac F]
                  [--no-eval-cache] [--no-delta-retime] [--cache-file FILE]
                  [--models qwen3|olmoe|deepseek|tiny|all] [--model ...]
                  [--method baseline|a|b|c|all]
                  [--methods baseline,a,b,c|all]
                  [--sched streaming|list|heft|greedy]
                  [--scheds streaming,list,heft,greedy|all]
                  [--seq N] [--dram hbm2|ssd]
                  [--iters N] [--seed N] [--threads N]
                  [--out EXPLORE_design_space.json]
  degrade         fault-injection severity sweep: for each (model x method)
                  cell and each fault scenario, scale the scenario from
                  severity 0 (healthy) to 1 (as written), re-simulate, and
                  report retained throughput (healthy / faulted latency) as
                  tables + ASCII curves, writing a DEGRADE_*.json artifact.
                  A scenario is a comma/plus list of faults —
                  dead-chiplet:N | nop-degrade:F | hb-degrade:F |
                  dram-throttle:F — and --fault takes a semicolon-separated
                  list of scenarios (default: one curve per fault kind):
                  [--fault 'dead-chiplet:4;nop-degrade:0.25,hb-degrade:0.5']
                  [--steps N] [--budget N  cap on faulted points, 0 = all]
                  [--no-eval-cache] [--no-delta-retime] [--cache-file FILE]
                  [--models qwen3|olmoe|deepseek|tiny|all] [--model ...]
                  [--method baseline|a|b|c|all] [--seq N] [--dram hbm2|ssd]
                  [--sched streaming|list|heft|greedy]
                  [--iters N] [--seed N] [--threads N]
                  [--out DEGRADE_curves.json]
  serve           online serving simulator: open-loop request traffic
                  through the continuous-batching queueing engine at a
                  sweep of load multipliers, reporting the saturation
                  curve (goodput vs offered load, exact + P2 streaming
                  p50/p99/p999 latency, utilization, tokens/s/mm^2) and
                  writing a SERVE_*.json artifact. Batch service times
                  come from real step simulations of the chosen cell,
                  bucketed by token count. Every point's trace passes the
                  queueing-invariant oracle (FIFO order, no service before
                  arrival, conservation, server exclusivity) and records
                  its Little's-law residual, asserted < 1% in CI.
                  --arrivals picks the process: poisson:RATE |
                  mmpp:RATE[:BURST[:DWELL_S]] (alias bursty) |
                  diurnal:RATE[:PERIOD_S[:AMPLITUDE]] | trace:FILE;
                  --trace FILE is shorthand for trace:FILE. --batch picks
                  the batch-close policy: size:N | timeout:MS |
                  hybrid:MS:N. --loads lists the swept multipliers of the
                  nominal arrival rate:
                  [--arrivals poisson:100] [--trace FILE]
                  [--slo MS] [--duration S] [--loads 0.25,0.5,1.0,1.5]
                  [--batch hybrid:5:8] [--queue-cap N] [--decode-chunk N]
                  [--budget N  cap on load points, 0 = all]
                  [--model qwen3|olmoe|deepseek|tiny]
                  [--method baseline|a|b|c] [--dram hbm2|ssd]
                  [--sched streaming|list|heft|greedy]
                  [--no-eval-cache] [--no-delta-retime] [--cache-file FILE]
                  [--iters N] [--seed N] [--threads N]
                  [--out SERVE_saturation.json]
  tenants         multi-tenant wafer partitioning: split the chiplet grid
                  among N tenants — each owns a contiguous run of switch
                  groups (the partition unit: a group's NoP trunk and DRAM
                  channel are never shared) — evaluate every tenant on its
                  carved sub-platform (training tenants run the step
                  simulator; serving tenants get their own continuous-
                  batching queue with per-tenant SLO accounting), sweep the
                  partition policies under a shared package power budget,
                  and write a TENANTS_*.json artifact with the feasible
                  Pareto frontier over (worst-tenant SLO violation, total
                  throughput, power). Every emitted partition passes the
                  partition-isolation oracle unconditionally: exclusive
                  chiplet ownership, contiguous NoP subtrees, resource
                  conservation against the parent wafer, power within
                  budget — and a single tenant owning the whole wafer
                  reproduces the un-partitioned simulate/serve paths bit
                  for bit. --tenant is a comma-separated list of
                  train:MODEL:METHOD:WEIGHT and serve:MODEL:LOAD_RPS:SLO_MS
                  specs; --policies picks from
                  even|weighted|slo-greedy|search|all; --power-budget caps
                  aggregate mean power in watts (0 = unbounded);
                  --population/--generations size the search policy's
                  NSGA-II over the share vector:
                  [--tenant train:olmoe:c:1,serve:olmoe:100:50]
                  [--policies all] [--power-budget 0]
                  [--duration S] [--seq N] [--dram hbm2|ssd]
                  [--sched streaming|list|heft|greedy]
                  [--population N] [--generations N]
                  [--no-eval-cache] [--no-delta-retime] [--cache-file FILE]
                  [--iters N] [--seed N] [--threads N]
                  [--out TENANTS_partition.json]
  train           real end-to-end training of the tiny MoE via PJRT:
                  [--steps N] [--artifacts artifacts/] [--log-every N]
                  [--seed N]
  platform        print the PJRT platform (runtime smoke check)
  help            print this message";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "report" => cmd_report(&args),
        "simulate" => cmd_simulate(&args),
        "layout" => cmd_layout(&args),
        "bench" => cmd_bench(&args),
        "explore" => cmd_explore(&args),
        "degrade" => cmd_degrade(&args),
        "serve" => cmd_serve(&args),
        "tenants" => cmd_tenants(&args),
        "train" => cmd_train(&args),
        "platform" => cmd_platform(),
        "help" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `mozart help`)"),
    }
}

fn report_opts(args: &Args) -> Result<ReportOpts> {
    Ok(ReportOpts {
        iters: args.get_parse("iters", 4)?,
        seed: args.get_parse("seed", 7)?,
    })
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = report_opts(args)?;
    let emit = |name: &str| -> Result<()> {
        let out = match name {
            "table1" => report::table1(),
            "table2" => report::table2(),
            "table3" => report::table3(opts).0,
            "table4" => report::table4(opts),
            "fig1" => report::fig1(),
            "fig3" => report::fig3(opts),
            "fig6b" => report::fig6b(opts),
            "fig6c" => report::fig6c(opts),
            "fig7" => report::appendix_fig(128, opts),
            "fig8" => report::appendix_fig(256, opts),
            "fig9" => report::appendix_fig(512, opts),
            "fig10_13" => report::fig10_13(),
            "fig14_16" => report::fig14_16(opts),
            "q1" => report::q1(opts),
            "q2" => report::q2(opts),
            "q3" => report::q3(opts),
            other => bail!("unknown report `{other}`"),
        };
        println!("{out}");
        Ok(())
    };
    if what == "all" {
        for name in [
            "table1", "table2", "table3", "table4", "fig1", "fig3", "fig6b", "fig6c",
            "fig7", "fig8", "fig9", "fig10_13", "fig14_16", "q1", "q2", "q3",
        ] {
            emit(name)?;
        }
        Ok(())
    } else {
        emit(what)
    }
}

/// Shared `--dram` option parsing (one spelling table for every subcommand).
fn parse_dram(args: &Args) -> Result<DramKind> {
    DramKind::from_name(args.get_or("dram", "hbm2"))
        .context("unknown --dram (hbm2|ssd)")
}

/// Shared `--sched` option parsing — the DAG dispatch policy the simulator
/// runs under. Streaming is the paper's schedule and the engine default.
fn parse_sched(args: &Args) -> Result<SchedPolicy> {
    SchedPolicy::from_name(args.get_or("sched", "streaming"))
        .context("unknown --sched (streaming|list|heft|greedy)")
}

/// Shared evaluation-reuse options (`explore` and `degrade`). Both reuse
/// layers default ON because they are bit-transparent; the `--no-*` switches
/// exist for A/B timing and for falsifying that claim.
fn parse_eval(args: &Args) -> EvalOptions {
    EvalOptions {
        cache: !args.flag("no-eval-cache"),
        retime: !args.flag("no-delta-retime"),
        cache_file: args.get("cache-file").map(str::to_string),
    }
}

fn parse_cell(args: &Args) -> Result<Cell> {
    let model = ModelId::from_name(args.get_or("model", "qwen3"))
        .context("unknown --model (qwen3|olmoe|deepseek|tiny)")?;
    let method = Method::from_name(args.get_or("method", "c"))
        .context("unknown --method (baseline|a|b|c)")?;
    let dram = parse_dram(args)?;
    Ok(Cell {
        model,
        method,
        seq_len: args.get_parse("seq", 256)?,
        dram,
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cell = parse_cell(args)?;
    let iters = args.get_parse("iters", 4)?;
    let seed = args.get_parse("seed", 7)?;
    let sched = parse_sched(args)?;
    let mut cfg: ExperimentConfig = cell_config_sched(cell, iters, seed, sched);
    if let Some(path) = args.get("config") {
        let kv = mozart::config::parse::KvConfig::load(path)?;
        kv.apply_knobs(&mut cfg.hw.knobs)?;
        cfg.seq_len = kv.get_usize("workload.seq_len", cfg.seq_len)?;
        cfg.batch_size = kv.get_usize("workload.batch_size", cfg.batch_size)?;
        cfg.micro_batch = kv.get_usize("workload.micro_batch", cfg.micro_batch)?;
    }
    let r = mozart::coordinator::run_experiment(&cfg);
    println!(
        "model={} method={} seq={} dram={} sched={} iters={}",
        cell.model.name(),
        cell.method.name(),
        cell.seq_len,
        cell.dram.name(),
        sched.name(),
        iters
    );
    println!(
        "latency: {:.4} s/step (std {:.4})   C_T: {:.2}   energy: {:.1} J/step",
        r.latency,
        r.latency_std,
        r.c_t,
        r.energy.total_j()
    );
    println!(
        "group imbalance: {:.3}   MoE utilization: {:.3}",
        r.group_imbalance, r.moe_utilization
    );
    println!("\nbusy time per component (s/step):");
    let mut rows = r.tag_busy.to_vec();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (tag, v) in rows.iter().filter(|(_, v)| *v > 0.0) {
        println!("  {:<18} {:.4}", tag.name(), v);
    }
    println!("\ncritical path (s/step):");
    let mut rows = r.critical.to_vec();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (tag, v) in rows.iter().filter(|(_, v)| *v > 0.0) {
        println!("  {:<18} {:.4}", tag.name(), v);
    }
    Ok(())
}

/// Resolve the `--strategy` option plus its parameter flags into a
/// [`SearchStrategy`]. `--samples` defaults to the grid budget so
/// `--strategy random --budget 8` means "8 random proposals" — and when
/// `--budget 0` (the "no cap" sentinel) it defaults to the full grid size
/// instead, mirroring the exhaustive semantics. The strategy RNG is seeded
/// from `--seed` so one flag controls the whole run.
fn parse_strategy(
    spec: &str,
    args: &Args,
    budget: usize,
    grid_total: usize,
    seed: u64,
) -> Result<SearchStrategy> {
    Ok(match spec.to_ascii_lowercase().as_str() {
        "exhaustive" => SearchStrategy::Exhaustive,
        "random" => SearchStrategy::Random {
            samples: args.get_parse(
                "samples",
                if budget > 0 { budget } else { grid_total.max(1) },
            )?,
            seed,
        },
        "evolutionary" => {
            let mutation_rate: f64 = args.get_parse("mutation", 0.3)?;
            if !(mutation_rate.is_finite() && (0.0..=1.0).contains(&mutation_rate)) {
                bail!("--mutation must be a probability in [0, 1], got {mutation_rate}");
            }
            let crossover_rate: f64 = args.get_parse("crossover", 0.9)?;
            if !(crossover_rate.is_finite() && (0.0..=1.0).contains(&crossover_rate)) {
                bail!("--crossover must be a probability in [0, 1], got {crossover_rate}");
            }
            SearchStrategy::Evolutionary {
                population: args.get_parse("population", 8)?,
                generations: args.get_parse("generations", 6)?,
                crossover_rate,
                mutation_rate,
                seed,
            }
        }
        other => bail!("unknown --strategy `{other}` (exhaustive|random|evolutionary)"),
    })
}

/// `mozart explore`: expand or search the hardware axis grid, evaluate the
/// (variant x model x method) cells over the work-stealing pool, print the
/// Pareto report, and write the `EXPLORE_*.json` artifact. Without
/// `--strategy` this is the PR-3 exhaustive grid with per-(model, method)
/// frontiers; with it, the guided search engine with joint frontiers and a
/// convergence curve.
fn cmd_explore(args: &Args) -> Result<()> {
    let axes = match explore::parse_axes(args.get_or("axes", "tiles,nop_bw,dram")) {
        Ok(a) => a,
        Err(e) => bail!("bad --axes: {e}"),
    };
    // `--models` (plural, matching the joint-frontier semantics) and the
    // PR-3 `--model` spelling are interchangeable
    let model_spec = args.get("models").or_else(|| args.get("model")).unwrap_or("qwen3");
    let models: Vec<ModelId> = match model_spec.to_ascii_lowercase().as_str() {
        "all" => ModelId::PAPER_MODELS.to_vec(),
        s => vec![ModelId::from_name(s)
            .context("unknown --models (qwen3|olmoe|deepseek|tiny|all)")?],
    };
    // `--methods` (plural) makes the Mozart ablation a searchable gene and
    // therefore needs the search engine; the PR-3 `--method` spelling keeps
    // the evaluate-every-method (worst-case) semantics
    let (methods, method_gene): (Vec<Method>, bool) = match args.get("methods") {
        Some(spec) => {
            if args.get("strategy").is_none() {
                bail!(
                    "--methods makes the method a searchable gene and requires \
                     --strategy (use --method all for the worst-case grid semantics)"
                );
            }
            if args.get("method").is_some() {
                bail!("--methods and --method conflict; pass exactly one");
            }
            (
                Method::parse_list(spec).map_err(|e| anyhow::anyhow!("bad --methods: {e}"))?,
                true,
            )
        }
        None => (
            match args.get_or("method", "c").to_ascii_lowercase().as_str() {
                "all" => Method::ALL.to_vec(),
                s => vec![
                    Method::from_name(s).context("unknown --method (baseline|a|b|c|all)")?,
                ],
            },
            false,
        ),
    };
    // `--scheds` (plural) spans several dispatch policies: without
    // --strategy the grid explorer evaluates every listed policy per variant
    // and reports the schedule frontier; with --strategy the policy becomes
    // a searchable gene (each candidate picks one). `--sched` pins a single
    // policy either way.
    let (scheds, sched_gene): (Vec<SchedPolicy>, bool) = match args.get("scheds") {
        Some(spec) => {
            if args.get("sched").is_some() {
                bail!("--scheds and --sched conflict; pass exactly one");
            }
            (
                SchedPolicy::parse_list(spec)
                    .map_err(|e| anyhow::anyhow!("bad --scheds: {e}"))?,
                args.get("strategy").is_some(),
            )
        }
        None => (vec![parse_sched(args)?], false),
    };
    // hard design-envelope caps (constrained-NSGA-II ranking); the flags are
    // fetched with literal string-keyed `args` accessor calls so the HELP
    // source-scan test keeps covering them
    let parse_cap = |name: &str, raw: Option<&str>| -> Result<Option<f64>> {
        match raw {
            None => Ok(None),
            Some(s) => {
                let v: f64 = s
                    .parse()
                    .with_context(|| format!("invalid value for --{name}: {s}"))?;
                if !(v.is_finite() && v > 0.0) {
                    bail!("--{name} must be finite and > 0, got {v}");
                }
                Ok(Some(v))
            }
        }
    };
    let seed: u64 = args.get_parse("seed", 7)?;
    // resilience floor: FRAC:SCENARIO, e.g. 0.8:dead-chiplet:2 — the
    // scenario grammar (and its placement seed) is shared with `degrade`
    let min_resilience = match args.get("min-resilience") {
        None => None,
        Some(spec) => {
            let (frac_s, scen_s) = spec.split_once(':').ok_or_else(|| {
                anyhow::anyhow!(
                    "--min-resilience wants FRAC:SCENARIO, e.g. 0.8:dead-chiplet:2"
                )
            })?;
            let frac: f64 = frac_s
                .parse()
                .with_context(|| format!("invalid --min-resilience fraction `{frac_s}`"))?;
            if !(frac.is_finite() && frac > 0.0 && frac <= 1.0) {
                bail!("--min-resilience fraction must be in (0, 1], got {frac}");
            }
            let scenario = FaultScenario::parse(scen_s, seed)
                .map_err(|e| anyhow::anyhow!("bad --min-resilience scenario: {e}"))?;
            if scenario.is_healthy() {
                bail!("--min-resilience needs a non-empty fault scenario");
            }
            Some(MinResilience { frac, scenario })
        }
    };
    let constraints = Constraints {
        max_area_mm2: parse_cap("max-area", args.get("max-area"))?,
        max_power_w: parse_cap("max-power", args.get("max-power"))?,
        min_resilience,
    };
    if constraints.any() && args.get("strategy").is_none() {
        bail!(
            "--max-area/--max-power/--min-resilience require --strategy \
             (the constrained search engine)"
        );
    }
    // serving objectives re-target the search engine's first minimized
    // objective; the plain grid explorer only knows step latency
    let objective = match args.get("objective") {
        None => Objective::Latency,
        Some(spec) => {
            if args.get("strategy").is_none() {
                bail!("--objective requires --strategy (it re-targets the search engine)");
            }
            Objective::parse(spec).map_err(|e| anyhow::anyhow!("bad --objective: {e}"))?
        }
    };
    // surrogate preselection only makes sense for the generational search
    // engine (it filters proposed offspring before full simulation)
    let surrogate_frac: f64 = args.get_parse("surrogate-frac", 1.0)?;
    if !(surrogate_frac.is_finite() && surrogate_frac > 0.0 && surrogate_frac <= 1.0) {
        bail!("--surrogate-frac must be in (0, 1], got {surrogate_frac}");
    }
    if args.get("surrogate-frac").is_some() && args.get("strategy").is_none() {
        bail!("--surrogate-frac requires --strategy (it filters search offspring)");
    }
    let dram = parse_dram(args)?;
    let budget = args.get_parse("budget", 64)?;
    let cfg = ExploreConfig {
        axes,
        budget,
        models,
        methods,
        scheds,
        seq_len: args.get_parse("seq", 256)?,
        dram,
        iters: args.get_parse("iters", 2)?,
        seed,
        threads: args.get_parse("threads", 0)?,
        eval: parse_eval(args),
    };
    let out_path = args.get_or("out", "EXPLORE_design_space.json");
    let json = match args.get("strategy") {
        None => {
            let outcome = explore::explore(&cfg);
            println!("{}", outcome.render_markdown());
            outcome.to_json()
        }
        Some(spec) => {
            let grid_total: usize = cfg.axes.iter().map(|a| a.values.len()).product();
            let strategy = parse_strategy(spec, args, budget, grid_total, seed)?;
            let scfg = SearchConfig {
                explore: cfg,
                strategy,
                constraints,
                method_gene,
                sched_gene,
                surrogate_frac,
                objective,
                serve: None,
            };
            let outcome = search::search_with(&scfg, |s| println!("{}", s.render()));
            println!();
            println!("{}", outcome.render_markdown());
            outcome.to_json()
        }
    };
    std::fs::write(out_path, json.render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `mozart degrade`: fault-injection severity sweep — one retained-
/// throughput curve per (model x method x scenario), printed as tables and
/// ASCII plots and written to a `DEGRADE_*.json` artifact.
fn cmd_degrade(args: &Args) -> Result<()> {
    let model_spec = args
        .get("models")
        .or_else(|| args.get("model"))
        .unwrap_or("olmoe");
    let models: Vec<ModelId> = match model_spec.to_ascii_lowercase().as_str() {
        "all" => ModelId::PAPER_MODELS.to_vec(),
        s => vec![ModelId::from_name(s)
            .context("unknown --models (qwen3|olmoe|deepseek|tiny|all)")?],
    };
    let methods: Vec<Method> =
        match args.get_or("method", "c").to_ascii_lowercase().as_str() {
            "all" => Method::ALL.to_vec(),
            s => vec![
                Method::from_name(s).context("unknown --method (baseline|a|b|c|all)")?,
            ],
        };
    let seed: u64 = args.get_parse("seed", 7)?;
    // one scenario per semicolon-separated part; commas/pluses compose
    // faults WITHIN a scenario (FaultScenario grammar)
    let scenarios = match args.get("fault") {
        None => degrade::default_scenarios(seed),
        Some(spec) => {
            let mut v = Vec::new();
            for part in spec.split(';').filter(|s| !s.trim().is_empty()) {
                let sc = FaultScenario::parse(part.trim(), seed)
                    .map_err(|e| anyhow::anyhow!("bad --fault scenario `{part}`: {e}"))?;
                if sc.is_healthy() {
                    bail!("--fault scenario `{part}` is empty");
                }
                v.push(sc);
            }
            if v.is_empty() {
                bail!("--fault needs at least one scenario");
            }
            v
        }
    };
    let steps: usize = args.get_parse("steps", 4)?;
    if steps == 0 {
        bail!("--steps must be >= 1");
    }
    let cfg = DegradeConfig {
        models,
        methods,
        dram: parse_dram(args)?,
        scenarios,
        steps,
        seq_len: args.get_parse("seq", 128)?,
        iters: args.get_parse("iters", 2)?,
        seed,
        threads: args.get_parse("threads", 0)?,
        budget: args.get_parse("budget", 0)?,
        sched: parse_sched(args)?,
        eval: parse_eval(args),
    };
    let outcome = degrade::run(&cfg);
    println!("{}", outcome.render_markdown());
    let out_path = args.get_or("out", "DEGRADE_curves.json");
    std::fs::write(out_path, outcome.to_json().render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `mozart serve`: online-serving saturation sweep — open-loop traffic
/// through the continuous-batching queueing engine at each load multiplier,
/// SLO metrics per point, and a `SERVE_*.json` artifact. Every point's
/// trace passes the queueing-invariant oracle and records its Little's-law
/// residual.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = ServeConfig::paper_default();
    cfg.model = ModelId::from_name(args.get_or("model", "olmoe"))
        .context("unknown --model (qwen3|olmoe|deepseek|tiny)")?;
    cfg.method = Method::from_name(args.get_or("method", "c"))
        .context("unknown --method (baseline|a|b|c)")?;
    cfg.dram = parse_dram(args)?;
    cfg.sched = parse_sched(args)?;
    // --trace FILE is shorthand for --arrivals trace:FILE
    cfg.arrivals = match (args.get("arrivals"), args.get("trace")) {
        (Some(_), Some(_)) => bail!("--arrivals and --trace conflict; pass exactly one"),
        (None, Some(path)) => ArrivalProcess::parse(&format!("trace:{path}"))
            .map_err(|e| anyhow::anyhow!("bad --trace: {e}"))?,
        (spec, None) => ArrivalProcess::parse(spec.unwrap_or("poisson:100"))
            .map_err(|e| anyhow::anyhow!("bad --arrivals: {e}"))?,
    };
    cfg.duration_s = args.get_parse("duration", cfg.duration_s)?;
    if !(cfg.duration_s.is_finite() && cfg.duration_s > 0.0) {
        bail!("--duration must be finite and > 0 seconds, got {}", cfg.duration_s);
    }
    cfg.slo_ms = args.get_parse("slo", cfg.slo_ms)?;
    if !(cfg.slo_ms.is_finite() && cfg.slo_ms > 0.0) {
        bail!("--slo must be finite and > 0 milliseconds, got {}", cfg.slo_ms);
    }
    if let Some(spec) = args.get("batch") {
        cfg.params.close =
            BatchClose::parse(spec).map_err(|e| anyhow::anyhow!("bad --batch: {e}"))?;
    }
    cfg.params.queue_cap = args.get_parse("queue-cap", cfg.params.queue_cap)?;
    cfg.params.decode_chunk = args.get_parse("decode-chunk", cfg.params.decode_chunk)?;
    if cfg.params.decode_chunk == 0 {
        bail!("--decode-chunk must be >= 1");
    }
    if let Some(spec) = args.get("loads") {
        let mut loads = Vec::new();
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let v: f64 = part
                .trim()
                .parse()
                .with_context(|| format!("bad --loads entry `{part}`"))?;
            if !(v.is_finite() && v > 0.0) {
                bail!("--loads entries must be finite and > 0, got {v}");
            }
            loads.push(v);
        }
        if loads.is_empty() {
            bail!("--loads needs at least one multiplier");
        }
        cfg.loads = loads;
    }
    cfg.budget = args.get_parse("budget", 0)?;
    cfg.iters = args.get_parse("iters", cfg.iters)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.threads = args.get_parse("threads", 0)?;
    cfg.eval = parse_eval(args);

    let outcome = serve::run(&cfg);
    println!("{}", outcome.render_markdown());
    let out_path = args.get_or("out", "SERVE_saturation.json");
    std::fs::write(out_path, outcome.to_json().render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `mozart tenants`: multi-tenant wafer partitioning — carve the chiplet
/// grid among the declared tenants under each partition policy, score the
/// fleet on (worst-tenant SLO violation, total throughput, power), validate
/// every emitted partition against the isolation oracle, and write a
/// `TENANTS_*.json` artifact.
fn cmd_tenants(args: &Args) -> Result<()> {
    let mut cfg = TenantsConfig::paper_default();
    if let Some(spec) = args.get("tenant") {
        cfg.tenants = TenantSpec::parse_list(spec)
            .map_err(|e| anyhow::anyhow!("bad --tenant: {e}"))?;
    }
    cfg.policies = PartitionPolicy::parse_list(args.get_or("policies", "all"))
        .map_err(|e| anyhow::anyhow!("bad --policies: {e}"))?;
    // 0 spells "unbounded" (the internal representation is +inf)
    let budget: f64 = args.get_parse("power-budget", 0.0)?;
    if !(budget.is_finite() && budget >= 0.0) {
        bail!("--power-budget must be >= 0 watts (0 = unbounded), got {budget}");
    }
    cfg.budget_w = if budget == 0.0 { f64::INFINITY } else { budget };
    cfg.dram = parse_dram(args)?;
    cfg.sched = parse_sched(args)?;
    cfg.seq_len = args.get_parse("seq", cfg.seq_len)?;
    cfg.duration_s = args.get_parse("duration", cfg.duration_s)?;
    if !(cfg.duration_s.is_finite() && cfg.duration_s > 0.0) {
        bail!("--duration must be finite and > 0 seconds, got {}", cfg.duration_s);
    }
    cfg.iters = args.get_parse("iters", cfg.iters)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.threads = args.get_parse("threads", 0)?;
    cfg.search_population = args.get_parse("population", cfg.search_population)?;
    cfg.search_generations = args.get_parse("generations", cfg.search_generations)?;
    cfg.eval = parse_eval(args);

    let outcome = tenants::run(&cfg);
    println!("{}", outcome.render_markdown());
    let out_path = args.get_or("out", "TENANTS_partition.json");
    std::fs::write(out_path, outcome.to_json().render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `mozart bench`: time the sweep, explore, and guided-search grids through
/// the sequential reference path and the parallel executor, verify the
/// results are bit-identical, and write a machine-readable
/// `BENCH_sweep.json` so the performance trajectory is tracked from PR to
/// PR.
fn cmd_bench(args: &Args) -> Result<()> {
    let grid = args.get_or("grid", "all").to_ascii_lowercase();
    let iters: usize = args.get_parse("iters", 2)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let reps: usize = args.get_parse("reps", 1)?.max(1);
    let threads: usize = args.get_parse("threads", 0)?;
    let out_path = args.get_or("out", "BENCH_sweep.json").to_string();
    let opts = SweepOptions { threads };

    let mut grids: Vec<(&str, Vec<Cell>)> = Vec::new();
    let mut bench_explore = false;
    let mut bench_search = false;
    let mut bench_degrade = false;
    let mut bench_sched = false;
    let mut bench_serve = false;
    let mut bench_tenants = false;
    match grid.as_str() {
        "table3" => grids.push(("table3", sweep::table3_cells())),
        "appendix" => grids.push(("appendix_seq128", sweep::appendix_cells(128))),
        "explore" => bench_explore = true,
        "search" => bench_search = true,
        "degrade" => bench_degrade = true,
        "sched" => bench_sched = true,
        "serve" => bench_serve = true,
        "tenants" => bench_tenants = true,
        "all" => {
            grids.push(("table3", sweep::table3_cells()));
            grids.push(("appendix_seq128", sweep::appendix_cells(128)));
            bench_explore = true;
            bench_search = true;
            bench_degrade = true;
            bench_sched = true;
            bench_serve = true;
            bench_tenants = true;
        }
        other => {
            bail!(
                "unknown --grid {other} \
                 (table3|appendix|explore|search|degrade|sched|serve|tenants|all)"
            )
        }
    }

    let mut grid_reports: Vec<Json> = Vec::new();
    println!("sweep bench: iters={iters} seed={seed} reps={reps}\n");

    for (name, cells) in &grids {
        let n = cells.len();
        // worker count actually used for THIS grid (capped at its cell count)
        let n_workers = opts.effective_threads(n);
        // keep the last timed pass's results so the determinism check below
        // does not have to re-run the (slow) sweeps a further time
        let mut seq_results = None;
        let seq = bench(&format!("sweep[{name}]: sequential, {n} cells"), reps, || {
            seq_results = Some(run_cells_seq(cells, iters, seed));
        });
        let mut par_results = None;
        let par = bench(&format!("sweep[{name}]: parallel,   {n} cells"), reps, || {
            par_results = Some(run_cells_with(cells, iters, seed, opts));
        });

        // determinism check: the parallel executor must reproduce the
        // sequential results bit for bit
        let a = seq_results.expect("reps >= 1 guarantees one sequential pass");
        let b = par_results.expect("reps >= 1 guarantees one parallel pass");
        let identical = a.len() == b.len()
            && a.iter().zip(b.iter()).all(|(x, y)| {
                x.result.latency == y.result.latency
                    && x.result.c_t == y.result.c_t
                    && x.result.tag_busy == y.result.tag_busy
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> {name}: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );

        grid_reports.push(Json::obj([
            ("name", Json::str(*name)),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel sweep diverged from sequential on grid {name}");
        }
    }

    if bench_explore {
        // explore hot path: a small tiles x dram grid on the fastest model
        // (6 variants + the paper anchor = 7 cells)
        let mut ecfg = ExploreConfig::paper_default();
        ecfg.models = vec![ModelId::OlmoE_1B_7B];
        ecfg.axes = explore::parse_axes("tiles=36:64:100,dram")
            .map_err(|e| anyhow::anyhow!("explore bench axes: {e}"))?;
        ecfg.budget = 0;
        ecfg.seq_len = 128;
        ecfg.iters = iters;
        ecfg.seed = seed;

        let mut seq_cfg = ecfg.clone();
        seq_cfg.threads = 1;
        let mut par_cfg = ecfg;
        par_cfg.threads = threads;

        let mut seq_out = None;
        let seq = bench("explore[tiles x dram]: sequential", reps, || {
            seq_out = Some(explore::explore(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("explore[tiles x dram]: parallel", reps, || {
            par_out = Some(explore::explore(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        // actual cell count (anchor-duplicate combos are skipped inside
        // explore(), so don't re-derive it from the grid shape)
        let n = a.points.len();
        let n_workers = SweepOptions { threads }.effective_threads(n);
        let identical = a.points.len() == b.points.len()
            && a.points.iter().zip(b.points.iter()).all(|(x, y)| {
                x.variant == y.variant
                    && x.latency_s == y.latency_s
                    && x.energy_j == y.energy_j
                    && x.area_mm2 == y.area_mm2
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> explore: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("explore_tiles_dram")),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel explore diverged from sequential");
        }
    }

    if bench_search {
        // guided-search hot path: a small evolutionary run on the fastest
        // model (tiles x dram genome space), sequential vs parallel cell
        // evaluation — the strategy itself runs on the driver thread, so
        // results must be bit-identical either way
        let mut ecfg = ExploreConfig::paper_default();
        ecfg.models = vec![ModelId::OlmoE_1B_7B];
        ecfg.axes = explore::parse_axes("tiles,dram")
            .map_err(|e| anyhow::anyhow!("search bench axes: {e}"))?;
        ecfg.budget = 0;
        ecfg.seq_len = 128;
        ecfg.iters = iters;
        ecfg.seed = seed;
        let population = 4;
        let strategy = SearchStrategy::Evolutionary {
            population,
            generations: 3,
            crossover_rate: 0.6,
            mutation_rate: 0.4,
            seed,
        };

        let seq_cfg = SearchConfig::new(
            ExploreConfig {
                threads: 1,
                ..ecfg.clone()
            },
            strategy,
        );
        let par_cfg = SearchConfig::new(ExploreConfig { threads, ..ecfg }, strategy);

        let mut seq_out = None;
        let seq = bench("search[evolutionary]: sequential", reps, || {
            seq_out = Some(search::search(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("search[evolutionary]: parallel", reps, || {
            par_out = Some(search::search(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        let n = a.cells.len();
        // unlike explore (one big batch), search evaluates per-generation
        // batches, so workers are capped by the largest batch (population
        // proposals x models x methods), not the run's total cell count
        let max_batch = population
            * par_cfg.explore.models.len()
            * par_cfg.explore.methods.len();
        let n_workers = SweepOptions { threads }.effective_threads(max_batch);
        let identical = a.cells.len() == b.cells.len()
            && a.archive == b.archive
            && a.cells.iter().zip(b.cells.iter()).all(|(x, y)| {
                x.variant == y.variant
                    && x.latency_s == y.latency_s
                    && x.energy_j == y.energy_j
                    && x.area_mm2 == y.area_mm2
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> search: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("search_evolutionary")),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel search diverged from sequential");
        }

        // evaluation-reuse throughput grid: a duplicate-heavy batch (a few
        // re-timing-only frequency points, each repeated several times) runs
        // through every memoization x delta-re-timing mode. Both reuse
        // layers are bit-transparent, so every mode must reproduce the
        // baseline latencies bit for bit; only evaluations/second may
        // differ.
        let freqs = [0.8, 1.0, 1.2];
        let repeats = 8;
        let base = cell_config(
            Cell {
                model: ModelId::TinyMoE,
                method: Method::MozartC,
                seq_len: 64,
                dram: DramKind::Hbm2,
            },
            iters,
            seed,
        );
        let cfgs: Vec<ExperimentConfig> = (0..repeats)
            .flat_map(|_| {
                freqs.iter().map(|&f| {
                    let mut c = base.clone();
                    c.hw = c.hw.with_overrides(&[HwOverride::FreqGhz(f)]);
                    c
                })
            })
            .collect();
        let n = cfgs.len();
        let modes: [(&str, EvalOptions); 4] = [
            ("baseline", EvalOptions { cache: false, retime: false, ..Default::default() }),
            ("retime", EvalOptions { cache: false, retime: true, ..Default::default() }),
            ("memo", EvalOptions { cache: true, retime: false, ..Default::default() }),
            ("memo_retime", EvalOptions { cache: true, retime: true, ..Default::default() }),
        ];
        let mut baseline: Option<(f64, Vec<f64>)> = None;
        for (mode, opts) in modes {
            let mut out = None;
            let timing = bench(&format!("eval-reuse[{mode}]: {n} evals"), reps, || {
                let session = EvalSession::new(opts.clone());
                let lats: Vec<f64> = parallel_map_with(
                    &cfgs,
                    1,
                    session.pools(),
                    || session.new_pool(),
                    |pool, cfg| {
                        let mut ctx = session.ctx(pool);
                        ctx.run(cfg).latency
                    },
                );
                out = Some((lats, session.finish()));
            });
            let (lats, stats) = out.expect("reps >= 1 guarantees one pass");
            let evals_per_s = n as f64 / timing.mean_s;
            let (identical, speedup) = if let Some((base_eps, base_lats)) = &baseline {
                (base_lats == &lats, evals_per_s / base_eps)
            } else {
                (true, 1.0)
            };
            if baseline.is_none() {
                baseline = Some((evals_per_s, lats));
            }
            println!(
                "  -> eval-reuse[{mode}]: {evals_per_s:.2} evals/s, \
                 {speedup:.2}x vs baseline, bit-identical: {identical}\n"
            );
            grid_reports.push(Json::obj([
                ("name", Json::str(format!("eval_reuse_{mode}"))),
                ("cells", Json::int(n)),
                ("workers", Json::int(1)),
                ("timing", timing.to_json()),
                ("evals_per_s", Json::num(evals_per_s)),
                ("speedup_vs_baseline", Json::num(speedup)),
                ("cache", stats.to_json()),
                ("bit_identical", Json::Bool(identical)),
            ]));
            if !identical {
                bail!("evaluation-reuse mode {mode} diverged from the baseline");
            }
        }
    }

    if bench_sched {
        // per-policy scheduler throughput over the Table 3 grid. Streaming
        // IS the engine's default dispatch order, so its run must reproduce
        // the plain sweep bit for bit; the other policies only have to pass
        // the schedule-validity oracle (asserted inside the engine in debug
        // builds) and are timed for the policy-overhead comparison.
        let cells = sweep::table3_cells();
        let n = cells.len();
        let n_workers = opts.effective_threads(n);
        let reference = run_cells_with(&cells, iters, seed, opts);
        for policy in SchedPolicy::ALL {
            let mut out = None;
            let timing = bench(
                &format!("sched[{}]: {n} cells", policy.name()),
                reps,
                || out = Some(run_cells_sched(&cells, iters, seed, policy, opts)),
            );
            let results = out.expect("reps >= 1 guarantees one pass");
            let identical = policy != SchedPolicy::Streaming
                || results.iter().zip(reference.iter()).all(|(x, y)| {
                    x.result.latency == y.result.latency
                        && x.result.c_t == y.result.c_t
                        && x.result.tag_busy == y.result.tag_busy
                });
            println!(
                "  -> sched[{}]: {:.2} cells/s, default-identical: {identical}\n",
                policy.name(),
                n as f64 / timing.mean_s
            );
            grid_reports.push(Json::obj([
                ("name", Json::str(format!("sched_{}", policy.name()))),
                ("cells", Json::int(n)),
                ("workers", Json::int(n_workers)),
                ("timing", timing.to_json()),
                ("cells_per_s", Json::num(n as f64 / timing.mean_s)),
                ("bit_identical", Json::Bool(identical)),
            ]));
            if !identical {
                bail!("streaming scheduler diverged from the default sweep path");
            }
        }
    }

    if bench_degrade {
        // degrade hot path: one cell, the default scenario set, two
        // severity steps; sequential vs parallel executor must agree bit
        // for bit (assembly order is deterministic by construction)
        let mut dcfg = DegradeConfig::paper_default();
        dcfg.steps = 2;
        dcfg.seq_len = 128;
        dcfg.iters = iters;
        dcfg.seed = seed;
        dcfg.scenarios = degrade::default_scenarios(seed);
        let mut seq_cfg = dcfg.clone();
        seq_cfg.threads = 1;
        let mut par_cfg = dcfg;
        par_cfg.threads = threads;

        let mut seq_out = None;
        let seq = bench("degrade[severity sweep]: sequential", reps, || {
            seq_out = Some(degrade::run(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("degrade[severity sweep]: parallel", reps, || {
            par_out = Some(degrade::run(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        let n = a.points.len();
        let n_workers = SweepOptions { threads }.effective_threads(n);
        let identical = a.points.len() == b.points.len()
            && a.points.iter().zip(b.points.iter()).all(|(x, y)| {
                x.scenario == y.scenario
                    && x.severity == y.severity
                    && x.latency_s == y.latency_s
                    && x.retained == y.retained
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> degrade: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("degrade_severity")),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel degrade diverged from sequential");
        }
    }

    if bench_serve {
        // serving hot path: a short saturation sweep on the paper default
        // cell; sequential vs parallel load-point evaluation must agree bit
        // for bit (each point derives its own arrival seed from its index)
        let mut scfg = ServeConfig::paper_default();
        scfg.duration_s = 1.0;
        scfg.loads = vec![0.5, 1.0];
        scfg.iters = iters;
        scfg.seed = seed;
        let mut seq_cfg = scfg.clone();
        seq_cfg.threads = 1;
        let mut par_cfg = scfg;
        par_cfg.threads = threads;

        let mut seq_out = None;
        let seq = bench("serve[saturation]: sequential", reps, || {
            seq_out = Some(serve::run(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("serve[saturation]: parallel", reps, || {
            par_out = Some(serve::run(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        let identical = a.points.len() == b.points.len()
            && a.points.iter().zip(b.points.iter()).all(|(x, y)| {
                x.requests == y.requests
                    && x.p99_ms.to_bits() == y.p99_ms.to_bits()
                    && x.goodput_rps.to_bits() == y.goodput_rps.to_bits()
            });
        // throughput unit: simulated requests per wall-clock second
        let n_requests: usize = a.points.iter().map(|p| p.requests).sum();
        let n_workers =
            SweepOptions { threads }.effective_threads(par_cfg.loads.len());
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> serve: {:.2}x speedup, {:.2} requests/s parallel, \
             bit-identical: {identical}\n",
            speedup,
            n_requests as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("serve_saturation")),
            ("cells", Json::int(a.points.len())),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("serve_requests", Json::int(n_requests)),
            (
                "serve_requests_per_s_sequential",
                Json::num(n_requests as f64 / seq.mean_s),
            ),
            (
                "serve_requests_per_s_parallel",
                Json::num(n_requests as f64 / par.mean_s),
            ),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel serve diverged from sequential");
        }
    }

    if bench_tenants {
        // multi-tenant hot path: a two-tenant partition sweep over the
        // deterministic policies; sequential vs parallel tenant evaluation
        // must agree bit for bit (tenant order is fixed by the share map)
        let mut tcfg = TenantsConfig::paper_default();
        tcfg.tenants = TenantSpec::parse_list("train:tiny:c:1,serve:tiny:60:50")
            .map_err(|e| anyhow::anyhow!("tenants bench specs: {e}"))?;
        tcfg.policies = vec![PartitionPolicy::Even, PartitionPolicy::Weighted];
        tcfg.seq_len = 64;
        tcfg.duration_s = 0.5;
        tcfg.iters = iters;
        tcfg.seed = seed;
        let mut seq_cfg = tcfg.clone();
        seq_cfg.threads = 1;
        let mut par_cfg = tcfg;
        par_cfg.threads = threads;

        let mut seq_out = None;
        let seq = bench("tenants[partition sweep]: sequential", reps, || {
            seq_out = Some(tenants::run(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("tenants[partition sweep]: parallel", reps, || {
            par_out = Some(tenants::run(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        let n = a.points.len();
        let n_workers =
            SweepOptions { threads }.effective_threads(par_cfg.tenants.len());
        let identical = a.points.len() == b.points.len()
            && a.points.iter().zip(b.points.iter()).all(|(x, y)| {
                x.shares == y.shares
                    && x.power_w.to_bits() == y.power_w.to_bits()
                    && x.objectives
                        .iter()
                        .zip(y.objectives.iter())
                        .all(|(p, q)| p.to_bits() == q.to_bits())
                    && x.tenants == y.tenants
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> tenants: {:.2}x speedup, {:.2} partitions/s parallel, \
             bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("tenants_partition")),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel tenants diverged from sequential");
        }
    }

    let report = Json::obj([
        ("bench", Json::str("sweep")),
        ("iters", Json::int(iters)),
        // string, not number: JSON numbers are f64 and would corrupt u64
        // seeds above 2^53, breaking reproduction from the artifact
        ("seed", Json::str(seed.to_string())),
        ("reps", Json::int(reps)),
        ("threads_requested", Json::int(threads)),
        ("grids", Json::Arr(grid_reports)),
    ]);
    std::fs::write(&out_path, report.render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_layout(args: &Args) -> Result<()> {
    use mozart::trace::{Priors, TraceGen};
    let model_id = ModelId::from_name(args.get_or("model", "qwen3"))
        .context("unknown --model")?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let model = ModelConfig::preset(model_id);
    let gen = TraceGen::for_model(&model, seed);
    let traces = gen.profile(4096, seed ^ 0x50F1_1E);
    let refs: Vec<&mozart::trace::RoutingTrace> = traces.iter().collect();
    let priors = Priors::from_traces(&refs);
    let layout = mozart::allocation::ExpertLayout::mozart(&priors, 16, 4);
    let contiguous =
        mozart::allocation::ExpertLayout::contiguous(model.n_experts, 16, 4);
    println!("model: {}  experts: {}  top-{}", model_id.name(), model.n_experts, model.top_k);
    println!(
        "intra-cluster collaboration: clustered {:.4} vs contiguous {:.4}",
        layout.clustering.intra_collab(&priors),
        contiguous.clustering.intra_collab(&priors)
    );
    println!(
        "inter-cluster collaboration: clustered {:.4} vs contiguous {:.4}",
        layout.clustering.inter_collab(&priors),
        contiguous.clustering.inter_collab(&priors)
    );
    let wl = layout.clustering.cluster_workloads(&priors);
    let gl = layout.allocation.group_workloads(&wl);
    println!("group workloads after Eq.5 allocation: {gl:?}");
    for (c, members) in layout.clustering.clusters.iter().enumerate() {
        let chiplet = layout.allocation.chiplet_of_cluster()[c];
        println!(
            "cluster {c:>2} -> chiplet {chiplet:>2} (group {}): {:?}",
            chiplet / 4,
            members
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let steps = args.get_parse("steps", 200)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let log_every = args.get_parse("log-every", 10)?;
    let cfg = mozart::train::TrainConfig {
        artifacts_dir: artifacts.to_string(),
        steps,
        log_every,
        seed: args.get_parse("seed", 7)?,
    };
    let summary = mozart::train::run(&cfg)?;
    println!("{}", summary.render());
    Ok(())
}

fn cmd_platform() -> Result<()> {
    println!("PJRT platform: {}", mozart::runtime::platform()?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_lists_every_subcommand() {
        for cmd in SUBCOMMANDS {
            assert!(
                HELP.lines().any(|l| l.trim_start().starts_with(cmd)),
                "subcommand `{cmd}` missing from help text"
            );
        }
    }

    #[test]
    fn help_documents_the_explore_flags() {
        for flag in [
            "--axes",
            "--budget",
            "--out",
            "--model",
            "--models",
            "--method",
            "--methods",
            "--sched",
            "--scheds",
            "--threads",
            "--strategy",
            "--samples",
            "--population",
            "--generations",
            "--mutation",
            "--max-area",
            "--max-power",
            "--min-resilience",
            "--fault",
            "--steps",
            "--surrogate-frac",
            "--no-eval-cache",
            "--no-delta-retime",
            "--cache-file",
        ] {
            assert!(HELP.contains(flag), "flag `{flag}` missing from help text");
        }
    }

    #[test]
    fn help_documents_every_parsed_flag() {
        // single-source enforcement: every option this file reads off `args`
        // must appear as `--name` in HELP, so an undocumented flag fails CI.
        // The scan only matches direct `args.` accessors, not the KvConfig
        // (`kv.`) lookups whose keys are config-file paths, not flags.
        let src = include_str!("main.rs");
        let mut flags: Vec<String> = Vec::new();
        for pat in [
            "args.get_or(\"",
            "args.get_parse(\"",
            "args.get(\"",
            "args.flag(\"",
        ] {
            let mut rest = src;
            while let Some(pos) = rest.find(pat) {
                rest = &rest[pos + pat.len()..];
                let name: String = rest.chars().take_while(|&c| c != '"').collect();
                flags.push(name);
            }
        }
        assert!(
            flags.len() >= 20,
            "flag scan looks broken: only {} matches",
            flags.len()
        );
        for flag in flags {
            assert!(
                HELP.contains(&format!("--{flag}")),
                "flag `--{flag}` is parsed but missing from help text"
            );
        }
    }

    #[test]
    fn help_covers_every_report_name() {
        // the `report <what>` list in HELP must name every report the
        // dispatcher accepts (same list as `report all`)
        for name in [
            "table1", "table2", "table3", "table4", "fig1", "fig3", "fig6b", "fig6c",
            "fig7", "fig8", "fig9", "fig10_13", "fig14_16", "q1", "q2", "q3",
        ] {
            assert!(HELP.contains(name), "report `{name}` missing from help text");
        }
    }
}
